#!/usr/bin/env python
"""Static-verify every bundled netdef across the knob grid — CI gate.

    PYTHONPATH=src python tools/verify_sweep.py [--json | --md]

Compiles each network in ``core.netdefs.NETWORKS`` under every SIMD
method × fuse setting × backend (XLA / Pallas) — plans only, nothing
executes — and runs ``repro.analysis.verifier.verify_plan`` over each.
Exits 1 on ANY finding (any severity): the bundled networks are the
repo's reference configurations and must verify spotless.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import (
    Finding,
    findings_json,
    findings_markdown,
)
from repro.analysis.verifier import verify_plan
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.core.plan import compile_plan

METHODS = (Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8)

#: forced second-generation fused-cell configurations appended to the
#: grid — the sliding-window pool carry (LRN opted out so the carry gate
#: opens), the two-pass channel-halo oc-blocked LRN cell, and the
#: oc-blocked chain final stage.  Each entry is (network, method, extra
#: compile_plan knobs, tag suffix); mirrored by ``tools/sanitize.py``.
EXTRA_CONFIGS = (
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_fuse={"norm1": False, "norm2": False},
          per_layer_pool_carry={"conv1": True, "conv2": True}), "carry"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_fuse={"norm1": False, "norm2": False},
          per_layer_pool_carry={"conv1": True, "conv2": True}), "carry"),
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_lrn_oc_block={"conv1": True, "conv2": True}),
     "lrn-oc-block"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_lrn_oc_block={"conv1": True, "conv2": True}),
     "lrn-oc-block"),
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_oc_block_final={"conv5": 8}), "oc-block-final"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_oc_block_final={"conv5": 4}), "oc-block-final"),
)


def sweep(networks=None):
    """Verify every (network × method × fuse × backend) combination,
    plus the forced second-generation cell configs (``EXTRA_CONFIGS``).

    ``networks`` maps name -> NetworkDef factory; defaults to the
    bundled ``NETWORKS`` registry (tests inject seeded-defect netdefs
    through it)."""
    if networks is None:
        networks = NETWORKS
    findings, combos = [], 0
    for name in sorted(networks):
        net = networks[name]()
        for method in METHODS:
            for fuse in (False, True):
                for use_pallas in (False, True):
                    combos += 1
                    plan = compile_plan(net, method=method, fuse=fuse,
                                        use_pallas=use_pallas, verify=False)
                    tag = (f"{name}/{method.value}/fuse={fuse}/"
                           f"pallas={use_pallas}")
                    for f in verify_plan(plan):
                        findings.append(Finding(
                            f.severity, f"{tag}::{f.step}", f.rule,
                            f.detail))
    for name, method, knobs, suffix in EXTRA_CONFIGS:
        if name not in networks:
            continue
        combos += 1
        plan = compile_plan(networks[name](), method=method, fuse=True,
                            use_pallas=True, verify=False, **knobs)
        tag = f"{name}/{method.value}/fuse=True/pallas=True/{suffix}"
        for f in verify_plan(plan):
            findings.append(Finding(
                f.severity, f"{tag}::{f.step}", f.rule, f.detail))
    return findings, combos


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)

    findings, combos = sweep()
    title = (f"Plan verifier sweep — {combos} configurations, "
             f"{len(findings)} finding(s)")
    if args.json:
        print(findings_json(findings))
    elif args.md:
        print(findings_markdown(findings, title=title), end="")
    else:
        for f in findings:
            print(f)
        print(title)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
