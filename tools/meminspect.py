"""Diagnose per-device memory of one (arch, shape, mesh) dry-run combo:
prints memory_analysis fields, the largest while-loop states, and the
largest non-parameter tensors in the compiled HLO.

Usage: PYTHONPATH=src python tools/meminspect.py <arch> <shape> [--multi-pod]

The HLO-text parsing lives in pure helpers (``while_states`` /
``largest_tensors``) so tests drive them on synthetic HLO without
compiling anything; the 512-device XLA flags are only set on the
compile path.  Unknown arch/shape names exit 2.
"""
from __future__ import annotations

import argparse
import re
import sys
from typing import List, Optional, Tuple

from repro.launch.hlo_analysis import _SHAPE_RE, shape_bytes

#: report thresholds — a while state is interesting from 0.5 GiB, an
#: individual tensor from 0.25 GiB (diagnostic cutoffs, not the kernel
#: VMEM budgets — those live in ``repro.kernels.conv2d.kernels``)
WHILE_STATE_MIN_BYTES = 1 << 29
TENSOR_MIN_BYTES = 1 << 28

_WHILE_RE = re.compile(r"(?:ROOT )?%([\w.\-]+) = (\(.*?\)) while\(")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_INSTR_RE = re.compile(
    r"\s*(?:ROOT )?%([\w.\-]+) = ([^ ]+) ([a-z][a-z0-9\-]*)\(")


def while_states(txt: str, min_bytes: int = WHILE_STATE_MIN_BYTES,
                 ) -> List[Tuple[int, str, Optional[str], list]]:
    """``(total_bytes, name, trip_count, big_components)`` per while
    loop whose carried state exceeds ``min_bytes``, in HLO-text order.
    ``big_components`` lists the ``(bytes, "dt[dims]")`` state tensors
    above ``TENSOR_MIN_BYTES``."""
    out = []
    for line in txt.splitlines():
        m = _WHILE_RE.match(line.strip())
        if not m:
            continue
        total = shape_bytes(m.group(2))
        if total <= min_bytes:
            continue
        trip = _TRIP_RE.search(line)
        parts = []
        for dt, dims in _SHAPE_RE.findall(m.group(2)):
            bb = shape_bytes(f"{dt}[{dims}]")
            if bb > TENSOR_MIN_BYTES:
                parts.append((bb, f"{dt}[{dims}]"))
        out.append((total, m.group(1), trip.group(1) if trip else None,
                    parts))
    return out


def largest_tensors(txt: str, min_bytes: int = TENSOR_MIN_BYTES,
                    top: int = 20) -> List[Tuple[int, str, str, str]]:
    """``(bytes, op, shape_text, name)`` of the ``top`` largest
    non-parameter instruction results above ``min_bytes``."""
    sizes = []
    for line in txt.splitlines():
        m = _INSTR_RE.match(line)
        if m and m.group(3) != "parameter":
            b = shape_bytes(m.group(2))
            if b > min_bytes:
                sizes.append((b, m.group(3), m.group(2)[:70],
                              m.group(1)[:45]))
    return sorted(sizes, reverse=True)[:top]


def _compile(arch: str, shape_name: str, multi: bool):
    """The heavy path: force the 512-device host platform and compile
    the dry-run step.  Deferred imports keep module import side-effect
    free (tests import the parsing helpers above)."""
    import os

    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
        "while-loop-expensive-invariant-code-motion "
    )

    import jax

    from repro.core.config import get_arch, get_shape
    from repro.launch.dryrun import _build_step
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.sharding.auto import rules_for

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_cfg = mesh_config(multi)
    rules, notes = rules_for(cfg, mesh_cfg, shape)
    print("sharding notes:", notes)
    mesh = make_production_mesh(multi_pod=multi)
    fn, args, donate = _build_step(cfg, shape, mesh_cfg, rules)(mesh)
    with mesh:
        return jax.jit(fn, donate_argnums=donate).lower(*args).compile()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    try:
        compiled = _compile(args.arch, args.shape, args.multi_pod)
    except KeyError as e:
        print(f"meminspect: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    mem = compiled.memory_analysis()
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        print(f"{k:28s} {getattr(mem, k)/2**30:9.2f} GiB")
    txt = compiled.as_text()
    print(f"\n=== while states > "
          f"{WHILE_STATE_MIN_BYTES/2**30:.1f} GiB ===")
    for total, name, trip, parts in while_states(txt):
        print(f"{total/2**30:8.2f} GiB {name[:30]} "
              f"trip={trip if trip else '?'}")
        for bb, t in parts:
            print(f"          {bb/2**30:7.2f} GiB {t}")
    print("\n=== largest instruction results (top 20, non-param) ===")
    for b, op, t, _n in largest_tensors(txt):
        print(f"{b/2**30:8.2f} GiB {op:22s} {t}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
