"""Diagnose per-device memory of one (arch, shape, mesh) dry-run combo:
prints memory_analysis fields, the largest while-loop states, and the
largest non-parameter tensors in the compiled HLO.

Usage: PYTHONPATH=src python tools/meminspect.py <arch> <shape> [--multi-pod]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion "
)

import re
import sys

import jax

from repro.core.config import get_arch, get_shape
from repro.launch.dryrun import _build_step
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.sharding.auto import rules_for
from repro.launch.hlo_analysis import shape_bytes, _SHAPE_RE


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    multi = "--multi-pod" in sys.argv
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_cfg = mesh_config(multi)
    rules, notes = rules_for(cfg, mesh_cfg, shape)
    print("sharding notes:", notes)
    mesh = make_production_mesh(multi_pod=multi)
    fn, args, donate = _build_step(cfg, shape, mesh_cfg, rules)(mesh)
    with mesh:
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
    mem = compiled.memory_analysis()
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        print(f"{k:28s} {getattr(mem, k)/2**30:9.2f} GiB")
    txt = compiled.as_text()
    print("\n=== while states > 0.5 GiB ===")
    for line in txt.splitlines():
        ls = line.strip()
        m = re.match(r'(?:ROOT )?%([\w.\-]+) = (\(.*?\)) while\(', ls)
        if m and shape_bytes(m.group(2)) > 2**29:
            trip = re.search(r'known_trip_count[^0-9]*(\d+)', ls)
            print(f"{shape_bytes(m.group(2))/2**30:8.2f} GiB "
                  f"{m.group(1)[:30]} trip={trip.group(1) if trip else '?'}")
            for dt, dims in _SHAPE_RE.findall(m.group(2)):
                bb = shape_bytes(f"{dt}[{dims}]")
                if bb > 2**28:
                    print(f"          {bb/2**30:7.2f} GiB {dt}[{dims}]")
    print("\n=== largest instruction results (top 20, non-param) ===")
    sizes = []
    for line in txt.splitlines():
        m = re.match(r'\s*(?:ROOT )?%([\w.\-]+) = ([^ ]+) ([a-z][a-z0-9\-]*)\(',
                     line)
        if m and m.group(3) not in ("parameter",):
            b = shape_bytes(m.group(2))
            if b > 2**28:
                sizes.append((b, m.group(3), m.group(2)[:70], m.group(1)[:45]))
    for b, op, t, n in sorted(sizes, reverse=True)[:20]:
        print(f"{b/2**30:8.2f} GiB {op:22s} {t}")


if __name__ == "__main__":
    main()
