#!/usr/bin/env python
"""Run the repo lint pass (R-rules) — CI gate and local pre-commit check.

    PYTHONPATH=src python tools/lint.py [--json | --md] \
        [--fail-on-findings] [paths ...]

Defaults to linting ``src/repro``, ``tools`` and ``benchmarks``.
``--fail-on-findings`` exits 1 when anything at all is reported (CI
uses it; locally the table alone is often what you want).  Rule
taxonomy: ``src/repro/analysis/README.md``.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.findings import findings_json, findings_markdown
from repro.analysis.lint import lint_file, lint_tree

DEFAULT_PATHS = ["src/repro", "tools", "benchmarks"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS,
                    help="files or directories to lint "
                         f"(default {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--md", action="store_true",
                    help="emit findings as a markdown table")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 if any finding is reported")
    args = ap.parse_args(argv)

    findings = []
    for p in (args.paths or DEFAULT_PATHS):
        path = Path(p)
        if path.is_dir():
            findings += lint_tree(path)
        else:
            findings += lint_file(path)

    if args.json:
        print(findings_json(findings))
    elif args.md:
        print(findings_markdown(findings, title="Repo lint"), end="")
    else:
        for f in findings:
            print(f)
        print(f"{len(findings)} finding(s)")
    return 1 if (args.fail_on_findings and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
