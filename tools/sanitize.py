#!/usr/bin/env python
"""Sanitize every bundled netdef's Pallas dispatches — CI gate.

    PYTHONPATH=src python tools/sanitize.py [--json | --md] \
        [--fail-on-findings]

Compiles each network in ``core.netdefs.NETWORKS`` under every SIMD
method x fuse setting x backend (the exact ``tools/verify_sweep.py``
grid — plans only, nothing executes), maps each plan step onto the
padded operand shapes its Pallas dispatch would receive (mirroring
``kernels.conv2d.ops`` / ``kernels.pool2d.ops`` / ``matmul_fused.ops``),
and runs ``repro.analysis.sanitizer`` over every dispatch: an AST-level
abstract interpretation of the kernel source that proves in-bounds loads
(K101), exactly-once output coverage (K102), the fp32-accumulate /
single-downcast contract (K103), and zeroed intermediate-padding rows in
chain cells (K104) — without importing the kernel modules it audits.

This CLI additionally cross-checks the sanitizer's independently derived
band geometry against the verifier's resolver-backed derivation
(``analysis.verifier.step_band_params``): the two derivations are
N-version redundant, so any disagreement is itself a finding (K105).
Unlike ``analysis.sanitizer`` — which must stay import-independent of
the kernels — this tool MAY import the verifier: the cross-check is the
point where the two independent derivations meet.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import sanitizer
from repro.analysis.findings import (
    Finding,
    findings_json,
    findings_markdown,
)
from repro.analysis.verifier import _BANDED_METHODS, step_band_params
from repro.core.fusion import _ADVANCED_OC_BLOCK, IM2COL_METHODS
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.core.plan import compile_plan

METHODS = (Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8)

#: the band-geometry fields both derivations must agree on (K105) —
#: ``carry``/``steps`` cover the sliding-window pool accumulator (carried
#: input rows between bands, physical band-axis grid steps)
GEOM_KEYS = ("kind", "blk", "n_tiles", "total", "band", "row_step",
             "in_base", "carry", "steps")

#: batch the sweep sanitizes with (any n >= 2 exercises the frame axis)
BATCH = 2

SUBLANES = 8


def _ceil8(c: int) -> int:
    return -(-c // SUBLANES) * SUBLANES


def _lrn_tuple(kwargs) -> tuple | None:
    if kwargs is None or kwargs.get("lrn_n") is None:
        return None
    return (kwargs["lrn_n"], kwargs["lrn_alpha"], kwargs["lrn_beta"],
            kwargs["lrn_k"])


def sanitize_step(plan, step, label: str):
    """Sanitize one plan step's Pallas dispatch.

    Returns ``(findings, geom)``; ``(None, None)`` when the step has no
    banded Pallas dispatch under this config (reference methods, XLA
    pool/fc legs, pointwise steps).  Operand shapes mirror the host-side
    layout work of the ops wrappers: NCHW -> NHWC, channels padded to
    the sublane multiple (chains also pad per-stage output channels).
    """
    if step.kind == "conv":
        if step.method not in _BANDED_METHODS:
            return None, None
        spec = step.spec
        c, h, w = step.in_shape
        cp = _ceil8(c)
        im2col = step.method in IM2COL_METHODS
        kw_extra = {}
        if im2col:
            kw_extra["oc_block"] = _ADVANCED_OC_BLOCK[step.method]
        return sanitizer.sanitize_conv2d(
            (BATCH, h, w, cp), (spec.kernel[0], spec.kernel[1], cp,
                                spec.out_channels),
            stride=spec.stride, padding=spec.padding, relu=step.relu,
            im2col=im2col, label=label, **kw_extra)
    if step.kind == "fused":
        g = step.group
        cv = g.conv
        c, h, w = step.in_shape
        cp = _ceil8(c)
        im2col = step.method in IM2COL_METHODS
        kw_extra = {}
        if im2col:
            kw_extra["oc_block"] = _ADVANCED_OC_BLOCK[step.method]
        kw = step.kwargs or {}
        return sanitizer.sanitize_conv2d(
            (BATCH, h, w, cp), (cv.kernel[0], cv.kernel[1], cp,
                                cv.out_channels),
            stride=cv.stride, padding=cv.padding, relu=g.relu,
            im2col=im2col, oh_block=step.oh_block,
            pool_kernel=g.pool.kernel, pool_stride=g.pool.stride,
            pool_kind=g.pool.pool_kind, pool_relu=g.pool_relu,
            lrn=_lrn_tuple(step.kwargs),
            pool_carry=kw.get("pool_carry"),
            lrn_oc_block=kw.get("lrn_oc_block"), label=label, **kw_extra)
    if step.kind == "chain":
        g = step.group
        c, h, w = step.in_shape
        cp = _ceil8(c)
        w_shapes, cin = [], cp
        for cv in g.convs:
            ocp = _ceil8(cv.out_channels)
            w_shapes.append((cv.kernel[0], cv.kernel[1], cin, ocp))
            cin = ocp
        pool = g.pool
        return sanitizer.sanitize_chain(
            (BATCH, h, w, cp), w_shapes,
            strides=tuple(cv.stride for cv in g.convs),
            paddings=tuple(cv.padding for cv in g.convs), relus=g.relus,
            im2col=step.method in IM2COL_METHODS, oh_block=step.oh_block,
            pool_kernel=pool.kernel if pool is not None else None,
            pool_stride=pool.stride if pool is not None else None,
            pool_kind=pool.pool_kind if pool is not None else "max",
            pool_relu=g.pool_relu, lrn=_lrn_tuple(step.kwargs),
            oc_block_final=g.oc_block_final, label=label)
    if step.kind == "pool" and plan.use_pallas:
        spec = step.spec
        c, h, w = step.in_shape
        return sanitizer.sanitize_pool2d(
            (BATCH, h, w, _ceil8(c)), kernel=spec.kernel,
            stride=spec.stride, kind=spec.pool_kind,
            relu=spec.relu or step.relu, label=label)
    if (step.kind == "fc" and plan.use_pallas
            and step.method != Method.SEQ_REF):
        return sanitizer.sanitize_matmul(
            (BATCH, step.d_in), (step.d_in, step.spec.out_channels),
            has_bias=True, act="relu" if step.relu else "none",
            label=label)
    return None, None


def _cross_check(geom, plan, step, label: str):
    """K105: the sanitizer's Phase-A geometry vs the resolver-backed
    ``step_band_params`` derivation — field-by-field."""
    if geom is None:
        return []
    trusted, _ = step_band_params(plan, step)
    if trusted is None:
        # the verifier sees no banded geometry where the sanitizer
        # derived one (or vice versa below) — that asymmetry is itself
        # a derivation disagreement
        return [Finding("error", label, "K105",
                        f"sanitizer derived {geom['kind']} band geometry "
                        "but step_band_params reports the step unbanded")]
    diffs = [f"{k}: sanitizer={geom[k]!r} verifier={trusted[k]!r}"
             for k in GEOM_KEYS if geom[k] != trusted[k]]
    if diffs:
        return [Finding("error", label, "K105",
                        "band-geometry derivations disagree — "
                        + "; ".join(diffs))]
    return []


#: forced second-generation fused-cell configurations, appended to the
#: default grid: the sliding-window pool carry (LRN opted out so the
#: carry gate opens), the two-pass channel-halo oc-blocked LRN cell, and
#: the oc-blocked chain final stage.  Each is (network, method, extra
#: compile_plan knobs, tag suffix).
EXTRA_CONFIGS = (
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_fuse={"norm1": False, "norm2": False},
          per_layer_pool_carry={"conv1": True, "conv2": True}), "carry"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_fuse={"norm1": False, "norm2": False},
          per_layer_pool_carry={"conv1": True, "conv2": True}), "carry"),
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_lrn_oc_block={"conv1": True, "conv2": True}),
     "lrn-oc-block"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_lrn_oc_block={"conv1": True, "conv2": True}),
     "lrn-oc-block"),
    ("alexnet", Method.ADVANCED_SIMD_8,
     dict(per_layer_oc_block_final={"conv5": 8}), "oc-block-final"),
    ("alexnet", Method.ADVANCED_SIMD_4,
     dict(per_layer_oc_block_final={"conv5": 4}), "oc-block-final"),
)


def _sanitize_plan(plan, tag, findings):
    n = 0
    for idx, step in enumerate(plan.steps):
        label = f"step{idx}:{'+'.join(step.names)}"
        fs, geom = sanitize_step(plan, step, label)
        if fs is None:
            continue
        n += 1
        fs = list(fs) + _cross_check(geom, plan, step, label)
        for f in fs:
            findings.append(Finding(
                f.severity, f"{tag}::{f.step}", f.rule, f.detail))
    return n


def sweep(networks=None):
    """Sanitize every (network x method x fuse x backend) combination,
    plus the forced second-generation cell configs (``EXTRA_CONFIGS``).

    Same grid and tag format as ``verify_sweep.sweep``; ``networks``
    defaults to the bundled ``NETWORKS`` registry (tests inject seeded
    mutations through the sanitizer's ``sources`` hook instead)."""
    if networks is None:
        networks = NETWORKS
    findings, combos, dispatches = [], 0, 0
    for name in sorted(networks):
        net = networks[name]()
        for method in METHODS:
            for fuse in (False, True):
                for use_pallas in (False, True):
                    combos += 1
                    plan = compile_plan(net, method=method, fuse=fuse,
                                        use_pallas=use_pallas, verify=False)
                    tag = (f"{name}/{method.value}/fuse={fuse}/"
                           f"pallas={use_pallas}")
                    dispatches += _sanitize_plan(plan, tag, findings)
    for name, method, knobs, suffix in EXTRA_CONFIGS:
        if name not in networks:
            continue
        combos += 1
        plan = compile_plan(networks[name](), method=method, fuse=True,
                            use_pallas=True, verify=False, **knobs)
        tag = f"{name}/{method.value}/fuse=True/pallas=True/{suffix}"
        dispatches += _sanitize_plan(plan, tag, findings)
    return findings, combos, dispatches


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 on any finding (any severity)")
    args = ap.parse_args(argv)

    findings, combos, dispatches = sweep()
    title = (f"Kernel sanitizer sweep — {combos} configurations, "
             f"{dispatches} dispatches proven, {len(findings)} finding(s)")
    if args.json:
        print(findings_json(findings))
    elif args.md:
        print(findings_markdown(findings, title=title), end="")
    else:
        for f in findings:
            print(f)
        print(title)
    if args.fail_on_findings and findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
