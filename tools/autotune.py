#!/usr/bin/env python
"""Offline autotuner: search the compiled-plan knob space with the
analytic cost model, persist the winner into a deploy manifest.

Coordinate-descent over ``plan.knob_space`` (per-conv method, per-layer
``oh_block`` band, per-layer fusion opt-outs), starting from the default
heuristic configuration.  Every candidate is compiled through
``compile_plan(verify=True)`` — a knob set whose plan fails the static
verifier with error findings is REJECTED outright, whatever the model
says — and scored by ``repro.core.cost`` under the committed
``COST_MODEL.json``.  Only strict predicted improvements are accepted,
so the tuned plan's modelled cost is ≤ the default plan's by
construction and the searched decisions never regress the heuristics.

The winning knob set is written into the deploy manifest
(``manifest["tuned_plan"]`` via ``deploy.save_model(tuned=...)``) and
the tool re-loads its own artifact to prove the round-trip: the
reconstructed knobs must be byte-exact, the reconstructed plan must
verify with zero error findings, and its modelled cost must not exceed
the default plan's.  Any violation exits non-zero — CI runs this as a
gate, not a report:

    PYTHONPATH=src python tools/autotune.py --net lenet5 --smoke \
        --out tuned-lenet5

Exit codes: 0 = tuned artifact written and self-checked; 1 = a tuned-
plan gate failed; 2 = usage/input error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

from repro.analysis.verifier import PlanVerificationError, verify_plan
from repro.core import deploy
from repro.core.cost import CostModel, PlanCost, plan_cost
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.core.plan import compile_plan, knob_space

#: accept a move only when it improves the prediction by this relative
#: margin — float noise must not churn the tuned configuration
EPSILON = 1e-6


def default_knobs(use_pallas: bool = False) -> Dict:
    """The heuristic configuration every engine starts from — the
    baseline the tuned plan must beat (or match)."""
    return {
        "method": Method.ADVANCED_SIMD_8,
        "per_layer_methods": {},
        "oh_block": None,
        "per_layer_oh_blocks": {},
        "fuse": True,
        "fuse_relu": True,
        "per_layer_fuse": {},
        "per_layer_pool_carry": {},
        "per_layer_lrn_oc_block": {},
        "per_layer_oc_block_final": {},
        "use_pallas": use_pallas,
    }


def score(net, knobs: Dict, model: CostModel,
          batch: int) -> Tuple[Optional[object], Optional[PlanCost]]:
    """Compile + verify + price one candidate.  ``(None, None)`` for a
    candidate the static verifier rejects with error findings — the
    search never considers it, however fast the model thinks it is."""
    try:
        plan = compile_plan(net, verify=True, **knobs)
    except PlanVerificationError:
        return None, None
    return plan, plan_cost(plan, model, batch)


def tune(net, model: CostModel, batch: int = 8, use_pallas: bool = False,
         passes: int = 2) -> Dict:
    """Greedy coordinate descent from the default configuration.  Each
    pass walks every layer's candidate axes (method, oh_block, fuse) and
    keeps a move only when the verified candidate strictly improves the
    predicted cost.  Returns the tune record: knobs, costs, decisions."""
    space = knob_space(net)
    knobs = default_knobs(use_pallas)
    base_plan, base_cost = score(net, knobs, model, batch)
    if base_plan is None:
        raise RuntimeError(
            f"default plan for {net.name} fails static verification")
    best = base_cost.us
    decisions: List[Dict] = []

    def try_move(layer: str, axis: str, value, mutate) -> bool:
        nonlocal best, knobs
        cand = {**knobs,
                "per_layer_methods": dict(knobs["per_layer_methods"]),
                "per_layer_oh_blocks": dict(knobs["per_layer_oh_blocks"]),
                "per_layer_fuse": dict(knobs["per_layer_fuse"]),
                "per_layer_pool_carry": dict(knobs["per_layer_pool_carry"]),
                "per_layer_lrn_oc_block":
                    dict(knobs["per_layer_lrn_oc_block"]),
                "per_layer_oc_block_final":
                    dict(knobs["per_layer_oc_block_final"])}
        mutate(cand)
        _, cost = score(net, cand, model, batch)
        if cost is None or cost.us >= best * (1.0 - EPSILON):
            return False
        decisions.append({"layer": layer, "axis": axis,
                          "value": value if not isinstance(value, Method)
                          else value.value,
                          "us_before": round(best, 1),
                          "us_after": round(cost.us, 1)})
        knobs, best = cand, cost.us
        return True

    for _ in range(max(1, passes)):
        improved = False
        for name, axes in space.items():
            for m in axes.get("methods", ()):
                improved |= try_move(
                    name, "method", m,
                    lambda c, n=name, m=m: c["per_layer_methods"]
                    .__setitem__(n, m))
            for b in axes.get("oh_blocks", ()):
                if b is None:
                    continue  # the default auto band is the start point
                improved |= try_move(
                    name, "oh_block", b,
                    lambda c, n=name, b=b: c["per_layer_oh_blocks"]
                    .__setitem__(n, b))
            if False in axes.get("fuse", ()):
                improved |= try_move(
                    name, "fuse", False,
                    lambda c, n=name: c["per_layer_fuse"]
                    .__setitem__(n, False))
            # second-generation fused-cell axes (None = the resolvers'
            # auto rule IS the start point, so only explicit pins move)
            for v in axes.get("pool_carry", ()):
                if v is None:
                    continue
                improved |= try_move(
                    name, "pool_carry", v,
                    lambda c, n=name, v=v: c["per_layer_pool_carry"]
                    .__setitem__(n, v))
            for v in axes.get("lrn_oc_block", ()):
                if v is None:
                    continue
                improved |= try_move(
                    name, "lrn_oc_block", v,
                    lambda c, n=name, v=v: c["per_layer_lrn_oc_block"]
                    .__setitem__(n, v))
            for v in axes.get("oc_block_final", ()):
                if v is None:
                    continue
                improved |= try_move(
                    name, "oc_block_final", v,
                    lambda c, n=name, v=v: c["per_layer_oc_block_final"]
                    .__setitem__(n, v))
        if not improved:
            break

    plan, cost = score(net, knobs, model, batch)
    return {
        "net": net.name, "batch": batch, "use_pallas": use_pallas,
        "knobs": knobs, "plan": plan, "cost": cost,
        "default_cost": base_cost, "decisions": decisions,
    }


def decision_table(result: Dict, model: CostModel) -> str:
    """The per-layer decision table (markdown) CI posts to the step
    summary: what each step of the tuned plan runs, and the search moves
    that got there."""
    knobs = result["knobs"]
    lines = [f"### Autotune — {result['net']} "
             f"(batch {result['batch']}, "
             f"{'pallas' if result['use_pallas'] else 'xla'}, "
             f"model backend `{model.backend}`)", "",
             "| step | kind | method | oh_block | fused into | pred us |",
             "|---|---|---|---|---|---:|"]
    for step, sc in zip(result["plan"].steps, result["cost"].steps):
        meth = step.method.value if step.method is not None else ""
        ohb = "auto" if step.oh_block is None else str(step.oh_block)
        if step.kind not in ("conv", "fused", "chain"):
            ohb = ""
        grp = "+".join(step.names) if step.kind in ("fused", "chain") else ""
        lines.append(f"| {'+'.join(step.names)} | {step.kind} | {meth} "
                     f"| {ohb} | {grp} | {sc.us:.1f} |")
    d, t = result["default_cost"].us, result["cost"].us
    lines += ["",
              f"- default heuristic plan: **{d:.1f} us** (modelled)",
              f"- tuned plan: **{t:.1f} us** (modelled, "
              f"{d / t if t else 1.0:.2f}x)",
              f"- accepted moves: {len(result['decisions'])}"]
    for mv in result["decisions"]:
        lines.append(f"  - `{mv['layer']}` {mv['axis']} → `{mv['value']}` "
                     f"({mv['us_before']} → {mv['us_after']} us)")
    return "\n".join(lines)


def write_and_check(result: Dict, model: CostModel, out: str) -> int:
    """Persist the tuned artifact and prove the acceptance criteria on
    the RELOADED copy: byte-exact knob round-trip, zero error findings,
    modelled cost ≤ the default plan's.  Returns the exit code."""
    import jax

    from repro.core.engine import CNNEngine

    net = result["plan"].net
    engine = CNNEngine(net)
    params = engine.init(jax.random.PRNGKey(0))
    deploy.save_model(out, net, params, tuned=result["knobs"],
                      extra={"autotune": {
                          "modelled_us": round(result["cost"].us, 1),
                          "default_modelled_us":
                              round(result["default_cost"].us, 1),
                          "batch": result["batch"],
                          "model_backend": model.backend}})

    saved = json.dumps(deploy.knobs_to_manifest(result["knobs"]),
                       sort_keys=True)
    loaded_knobs = deploy.load_tuned_knobs(out)
    loaded = json.dumps(deploy.knobs_to_manifest(loaded_knobs),
                        sort_keys=True)
    if saved != loaded:
        print(f"FAIL: tuned knobs did not round-trip byte-exactly:\n"
              f"  saved:  {saved}\n  loaded: {loaded}", file=sys.stderr)
        return 1
    plan = compile_plan(net, verify=False, **loaded_knobs)
    errors = [f for f in verify_plan(plan) if f.severity == "error"]
    if errors:
        print(f"FAIL: reloaded tuned plan has {len(errors)} error "
              f"finding(s): {errors}", file=sys.stderr)
        return 1
    reloaded_us = plan_cost(plan, model, result["batch"]).us
    default_us = result["default_cost"].us
    if reloaded_us > default_us * (1.0 + EPSILON):
        print(f"FAIL: tuned plan modelled cost {reloaded_us:.1f} us exceeds "
              f"default {default_us:.1f} us", file=sys.stderr)
        return 1
    print(f"tuned artifact written to {out} "
          f"(modelled {reloaded_us:.1f} us vs default {default_us:.1f} us)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--net", default="lenet5",
                    help=f"network to tune ({', '.join(sorted(NETWORKS))})")
    ap.add_argument("--batch", type=int, default=8,
                    help="batch size the cost is modelled at")
    ap.add_argument("--model", default=None,
                    help="COST_MODEL.json path (default: repo root)")
    ap.add_argument("--backend", default="cpu",
                    help="coefficient backend to price with")
    ap.add_argument("--use-pallas", action="store_true",
                    help="tune the Pallas path (band geometry + VMEM "
                         "feasibility enter the search)")
    ap.add_argument("--passes", type=int, default=2,
                    help="coordinate-descent passes over the knob space")
    ap.add_argument("--smoke", action="store_true",
                    help="single-pass quick search (the CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the tuned deploy artifact to this directory "
                         "and self-check the round-trip")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="dump the tune record as JSON to this path")
    args = ap.parse_args(argv)

    if args.net not in NETWORKS:
        print(f"error: unknown network {args.net!r} "
              f"(have: {', '.join(sorted(NETWORKS))})", file=sys.stderr)
        return 2
    try:
        model = CostModel.load(args.model, backend=args.backend)
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"error: cannot load cost model: {e}", file=sys.stderr)
        return 2

    net = NETWORKS[args.net]()
    result = tune(net, model, batch=args.batch, use_pallas=args.use_pallas,
                  passes=1 if args.smoke else args.passes)
    print(decision_table(result, model))

    if args.json_out:
        record = {
            "net": result["net"], "batch": result["batch"],
            "use_pallas": result["use_pallas"],
            "tuned_plan": deploy.knobs_to_manifest(result["knobs"]),
            "modelled_us": round(result["cost"].us, 1),
            "default_modelled_us": round(result["default_cost"].us, 1),
            "decisions": result["decisions"],
        }
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)

    if args.out:
        return write_and_check(result, model, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
