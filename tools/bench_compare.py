#!/usr/bin/env python
"""Bench-trend comparison for ``BENCH_network.json`` artifacts.

Diffs two network-ladder bench files (previous vs current), per network,
per method, per variant (unfused/fused), on ``us_per_call`` — plus the
batched-serving rows (``CNNServer`` p50 latency per max_batch, flattened
as method ``cnn_server`` / variant ``batchN``; throughput and p95 ride
along in the json but the gate compares p50).  Prints a
markdown trend table (CI pipes it into ``$GITHUB_STEP_SUMMARY``) and —
with ``--fail-on-regress`` — exits non-zero when any row slows down by
more than ``--max-regress-pct`` percent.  Rows present on only one side
are reported as ``new``/``removed`` and never fail the gate (a fresh
network or method is a feature, not a regression).  When the two files
were produced with different bench configs (``batch``/``iters``/
``backend``), their us_per_call are not comparable: the previous file is
discarded, every current row reports as ``new``, and the gate passes —
a deliberate config change resets the baseline instead of tripping (or
masking) the regression check.

Fused-group composition rides along: rows whose ``fused_groups`` changed
between the two files are flagged (informational — a re-planned group,
e.g. a conv run newly fused as a chain, is a feature, never a gate), and
the current file's ``fused_geometry`` — each group's chain depth and the
final-row band a Pallas cell resolves — is printed as its own table
under the trend.

Usage:
    python tools/bench_compare.py prev/BENCH_network.json BENCH_network.json \
        --max-regress-pct 25 [--fail-on-regress]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

#: (network, method, variant) -> us_per_call
FlatBench = Dict[Tuple[str, str, str], float]


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


#: bench-config keys that must match for us_per_call to be comparable
CONFIG_KEYS = ("batch", "iters", "backend")


def config_mismatch(prev: dict, cur: dict) -> List[str]:
    """The CONFIG_KEYS on which the two bench files disagree."""
    return [k for k in CONFIG_KEYS if prev.get(k) != cur.get(k)]


def flatten(data: dict) -> FlatBench:
    """``BENCH_network.json`` -> {(network, method, variant): us_per_call}.
    Serving rows flatten to ``(net, "cnn_server", "batchN") -> p50_us``
    so the same trend/gate machinery covers them."""
    flat: FlatBench = {}
    for net, nd in data.get("networks", {}).items():
        for row in nd.get("rows", []):
            for variant in ("unfused", "fused"):
                if variant in row:
                    flat[(net, row["method"], variant)] = (
                        row[variant]["us_per_call"])
        for srow in nd.get("serving", []):
            # absent/zero p50 (e.g. a shed-everything overload row, or a
            # fake-clock run) carries nothing comparable: skip the row
            # rather than divide by it
            p50 = srow.get("p50_us")
            if not p50:
                continue
            mode = srow.get("mode", "normal")
            variant = (f"batch{srow['batch']}" if mode == "normal"
                       else f"batch{srow['batch']}-{mode}")
            flat[(net, "cnn_server", variant)] = p50
    return flat


def strip_serving(data: dict) -> None:
    """Drop the serving rows from a bench dict in place (used when the
    two files' ``serving_config`` disagree: p50 at a different request
    count / batch sweep is not comparable — serving rows report as
    ``new`` while the ladder rows still gate)."""
    for nd in data.get("networks", {}).values():
        nd.pop("serving", None)


def flatten_groups(data: dict) -> Dict[Tuple[str, str], List[str]]:
    """``BENCH_network.json`` -> {(network, method): fused_groups}."""
    out: Dict[Tuple[str, str], List[str]] = {}
    for net, nd in data.get("networks", {}).items():
        for row in nd.get("rows", []):
            if "fused_groups" in row:
                out[(net, row["method"])] = row["fused_groups"]
    return out


def group_changes(prev: dict, cur: dict) -> List[str]:
    """Per-(network, method) fused-group composition diffs — purely
    informational (a re-planned group never gates)."""
    pg, cg = flatten_groups(prev), flatten_groups(cur)
    lines = []
    for key in sorted(set(pg) | set(cg)):
        if pg.get(key) != cg.get(key):
            net, method = key
            old = ", ".join(pg[key]) if key in pg else "—"
            new = ", ".join(cg[key]) if key in cg else "—"
            lines.append(f"- `{net}/{method}` fused groups: {old} → {new}")
    return lines


def render_geometry(data: dict) -> str:
    """The current file's executed chain geometry, as its own markdown
    table (empty string when no row carries ``fused_geometry`` — older
    artifacts stay renderable)."""
    lines = []
    for net, nd in data.get("networks", {}).items():
        for row in nd.get("rows", []):
            for g in row.get("fused_geometry", []):
                lines.append(
                    f"| {net} | {row['method']} | {g['group']} | "
                    f"{g['convs']} | {g['rows_per_cell']} × {g['n_tiles']} | "
                    f"{g['out_hw'][0]}×{g['out_hw'][1]} |")
    if not lines:
        return ""
    return "\n".join([
        "### Executed fusion geometry (current run)",
        "",
        "| network | method | group | convs | rows/cell × tiles | out hw |",
        "|---|---|---|---:|---:|---|",
        *lines,
    ]) + "\n"


def compare(prev: FlatBench, cur: FlatBench,
            max_regress_pct: float) -> List[dict]:
    """Per-row trend verdicts, sorted by (network, method, variant).

    status: ``ok`` (within tolerance, or faster), ``regressed`` (slower
    by more than ``max_regress_pct``), ``new`` (row only in current),
    ``removed`` (row only in previous).
    """
    rows = []
    for key in sorted(set(prev) | set(cur)):
        net, method, variant = key
        row = {"network": net, "method": method, "variant": variant,
               "prev_us": prev.get(key), "cur_us": cur.get(key),
               "delta_pct": None}
        if key not in prev or not prev[key]:
            # a zero previous value (defensive: flatten already drops
            # them) is not a comparable baseline — report "new", never
            # divide by it
            row["status"] = "new"
        elif key not in cur:
            row["status"] = "removed"
        else:
            row["delta_pct"] = 100.0 * (cur[key] - prev[key]) / prev[key]
            row["status"] = ("regressed"
                             if row["delta_pct"] > max_regress_pct else "ok")
        rows.append(row)
    return rows


def render_markdown(rows: List[dict], max_regress_pct: float,
                    note: str = "") -> str:
    """The trend table CI posts to the job summary."""
    n_reg = sum(r["status"] == "regressed" for r in rows)
    lines = [
        "## Bench trend (us_per_call vs previous main)",
        "",
        f"Tolerance: +{max_regress_pct:g}% — "
        + (f"**{n_reg} regression(s)**" if n_reg else "no regressions"),
        *(["", note] if note else []),
        "",
        "| network | method | variant | prev us | cur us | Δ% | status |",
        "|---|---|---|---:|---:|---:|---|",
    ]
    icon = {"ok": "✅", "regressed": "🔺", "new": "🆕", "removed": "➖"}
    for r in rows:
        prev = f"{r['prev_us']:.0f}" if r["prev_us"] is not None else "—"
        cur = f"{r['cur_us']:.0f}" if r["cur_us"] is not None else "—"
        delta = (f"{r['delta_pct']:+.1f}%" if r["delta_pct"] is not None
                 else "—")
        lines.append(f"| {r['network']} | {r['method']} | {r['variant']} | "
                     f"{prev} | {cur} | {delta} | "
                     f"{icon[r['status']]} {r['status']} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", help="previous BENCH_network.json")
    ap.add_argument("cur", help="current BENCH_network.json")
    ap.add_argument("--max-regress-pct", type=float, default=25.0,
                    help="allowed us_per_call growth per row (default 25)")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="exit 1 when any row regresses past the tolerance "
                         "(CI: set on main, leave off on PRs)")
    args = ap.parse_args(argv)
    # a malformed CURRENT file is always an error: the thing under test
    # did not produce a readable artifact
    try:
        cur = load(args.cur)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::error::current bench artifact {args.cur} is unreadable: "
              f"{e}", file=sys.stderr)
        return 2
    # a malformed BASELINE is a gate verdict, not an infrastructure
    # traceback: with --fail-on-regress the gate cannot render its
    # verdict, so it fails loudly; without the flag (PR mode) the
    # baseline resets and every row reports "new"
    try:
        prev = load(args.prev)
    except (OSError, json.JSONDecodeError) as e:
        if args.fail_on_regress:
            print(f"::error::baseline bench artifact {args.prev} is "
                  f"unreadable ({e}) — the regression gate cannot run; "
                  f"regenerate the baseline (workflow_dispatch on main) "
                  f"or re-run without --fail-on-regress", file=sys.stderr)
            return 2
        print(f"::warning::baseline bench artifact {args.prev} is "
              f"unreadable ({e}) — baseline reset, all rows report as new",
              file=sys.stderr)
        prev = {}
    note = ""
    mismatch = config_mismatch(prev, cur)
    if mismatch:
        # different bench config: us_per_call not comparable — reset the
        # baseline (all rows "new") rather than gate on apples-to-oranges
        note = ("⚠️ bench config changed ("
                + ", ".join(f"{k}: {prev.get(k)} → {cur.get(k)}"
                            for k in mismatch)
                + ") — baseline reset, no comparison performed")
        prev = {}
    elif prev.get("serving_config") != cur.get("serving_config"):
        # serving sweep config changed: only the serving rows reset (the
        # ladder rows still compare — their config matched above)
        note = ("⚠️ serving config changed "
                f"({prev.get('serving_config')} → "
                f"{cur.get('serving_config')}) — serving baseline reset")
        strip_serving(prev)
    rows = compare(flatten(prev), flatten(cur), args.max_regress_pct)
    print(render_markdown(rows, args.max_regress_pct, note))
    # no composition diff against a reset/absent baseline — every row
    # would list as "— → …" when nothing was actually re-planned
    changes = group_changes(prev, cur) if prev.get("networks") else []
    if changes:
        print("### Fused-group composition changes (informational)\n")
        print("\n".join(changes) + "\n")
    geometry = render_geometry(cur)
    if geometry:
        print(geometry)
    regressed = [r for r in rows if r["status"] == "regressed"]
    for r in regressed:
        print(f"::warning::bench regression: {r['network']}/{r['method']}"
              f"/{r['variant']} {r['prev_us']:.0f} -> {r['cur_us']:.0f} us "
              f"({r['delta_pct']:+.1f}% > +{args.max_regress_pct:g}%)",
              file=sys.stderr)
    return 1 if (regressed and args.fail_on_regress) else 0


if __name__ == "__main__":
    sys.exit(main())
