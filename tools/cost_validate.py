#!/usr/bin/env python
"""Cost-model accuracy gate: predicted vs measured rank correlation.

For every ladder row in a measured ``BENCH_network.json`` (network ×
method × fused/unfused), recompile the plan exactly as the bench ran it,
price it with the committed ``COST_MODEL.json``, and compute the
Spearman rank correlation between predicted and measured
``us_per_call`` across ALL rows.  The model's job is to ORDER candidate
plans for the autotuner — rank fidelity is the contract, absolute
microseconds are not.  Serving rows (``cnn_server``) are queue p50s,
not per-call kernel time, and are excluded.

CI runs this after the smoke bench: ``--warn-only`` on PRs (a drifting
model warns), gating on main (a drifting model fails — refit with
``python -m benchmarks.cost_fit`` and commit the refreshed model):

    PYTHONPATH=src python tools/cost_validate.py BENCH_network.json \
        --threshold 0.8 --md

Exit codes: 0 = rank correlation meets the threshold (or --warn-only);
1 = below threshold; 2 = unreadable inputs.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core.cost import CostModel  # noqa: E402

from benchmarks.cost_fit import bench_backend, ladder_points  # noqa: E402


def validate(bench: dict, model: CostModel) -> dict:
    """Predicted-vs-measured record for every ladder row, plus the
    overall and per-network Spearman rank correlations."""
    from repro.core.cost import spearman

    pts = ladder_points(bench)
    rows = []
    for p in pts:
        pred = model.predict(p["flops_by_key"], p["hbm_bytes"],
                             p["dispatches"])
        rows.append({"id": p["id"], "predicted_us": pred,
                     "measured_us": p["us"]})
    rho = spearman([r["predicted_us"] for r in rows],
                   [r["measured_us"] for r in rows])
    per_net = {}
    for net in sorted({r["id"].split("/")[0] for r in rows}):
        sub = [r for r in rows if r["id"].split("/")[0] == net]
        per_net[net] = spearman([r["predicted_us"] for r in sub],
                                [r["measured_us"] for r in sub])
    return {"rows": rows, "spearman": rho, "per_network": per_net}


def markdown(report: dict, threshold: float, backend: str,
             fallback_from: str | None = None) -> str:
    ok = report["spearman"] >= threshold
    lines = [f"### Cost-model accuracy gate (backend `{backend}`)", ""]
    if fallback_from:
        lines += [f"> **Note**: bench measured backend `{fallback_from}` "
                  f"has no fitted coefficients — validated against the "
                  f"`{backend}` model (cross-backend fallback).", ""]
    lines += [f"Spearman rank correlation over {len(report['rows'])} bench "
              f"rows: **{report['spearman']:.4f}** "
              f"(threshold {threshold}) — "
              f"{'PASS' if ok else '**FAIL**'}", ""]
    for net, rho in report["per_network"].items():
        lines.append(f"- `{net}`: {rho:.4f}")
    lines += ["", "| row | predicted us | measured us | ratio |",
              "|---|---:|---:|---:|"]
    for r in sorted(report["rows"], key=lambda r: r["measured_us"]):
        ratio = (r["predicted_us"] / r["measured_us"]
                 if r["measured_us"] else float("inf"))
        lines.append(f"| {r['id']} | {r['predicted_us']:.0f} "
                     f"| {r['measured_us']:.0f} | {ratio:.2f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="?", default="BENCH_network.json",
                    help="measured bench artifact to validate against")
    ap.add_argument("--model", default=None,
                    help="COST_MODEL.json path (default: repo root)")
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="minimum acceptable Spearman rank correlation")
    ap.add_argument("--warn-only", action="store_true",
                    help="report a failure but exit 0 (the PR-side mode)")
    ap.add_argument("--md", action="store_true",
                    help="emit the full markdown table (else a summary "
                         "line)")
    args = ap.parse_args(argv)

    try:
        with open(args.bench) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read bench file {args.bench}: {e}",
              file=sys.stderr)
        return 2
    backend, _ = bench_backend(bench)
    try:
        model = CostModel.load(args.model, backend=backend)
    except (OSError, json.JSONDecodeError, KeyError) as e:
        print(f"error: cannot load cost model: {e}", file=sys.stderr)
        return 2

    if model.fallback_from:
        # the committed model has no entry for the bench's backend —
        # say so loudly instead of validating borrowed coefficients as
        # if they were calibrated for this backend
        print(f"::warning::no fitted cost model for backend "
              f"{model.fallback_from!r} — falling back to "
              f"{model.backend!r} coefficients (rank decisions usually "
              f"transfer; magnitudes do not)")

    report = validate(bench, model)
    if args.md:
        print(markdown(report, args.threshold, model.backend,
                       model.fallback_from))
    else:
        fb = (f" [fallback from {model.fallback_from}]"
              if model.fallback_from else "")
        print(f"cost-model spearman={report['spearman']:.4f} over "
              f"{len(report['rows'])} rows (threshold {args.threshold}) "
              f"backend={model.backend}{fb}")

    if report["spearman"] >= args.threshold:
        return 0
    if args.warn_only:
        # the ::warning:: line surfaces in the PR checks UI without
        # failing the job — drift is visible before it gates on main
        print(f"::warning::cost model rank correlation "
              f"{report['spearman']:.4f} below threshold {args.threshold} "
              f"— refit with benchmarks.cost_fit")
        return 0
    print(f"::error::cost model rank correlation {report['spearman']:.4f} "
          f"below threshold {args.threshold} — refit with "
          f"benchmarks.cost_fit and commit COST_MODEL.json")
    return 1


if __name__ == "__main__":
    sys.exit(main())
