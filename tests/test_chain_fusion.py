"""Fused conv→conv chain tests: planner chain grouping + shorter-chain
fallback, chain-kernel correctness (interpret-mode Pallas vs the
per-layer ladder), the one-NHWC-pass XLA analogue, and the shared VMEM
working-set model (monotonicity + planner↔kernel agreement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import CNNEngine, _lrn
from repro.core.fusion import (
    FusedLayerSpec,
    chain_working_set,
    fused_working_set,
    fusion_summary,
    layers_as_chain,
    plan_fusion,
)
from repro.core.methods import Method, conv2d_chain_fused
from repro.core.netdefs import NETWORKS, LayerSpec, NetworkDef
from repro.kernels.conv2d import kernels as K
from repro.kernels.conv2d.ops import SUBLANES, conv2d_chain
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.pool2d.ref import pool2d_ref

SIMD = Method.ADVANCED_SIMD_8


# ---------------------------------------------------------------------------
# planner: chain grouping
# ---------------------------------------------------------------------------


def _conv(name, oc, k=3, pad=1, relu=True):
    return LayerSpec("conv", name, out_channels=oc, kernel=(k, k),
                     padding=(pad, pad), relu=relu)


def test_planner_chains_alexnet_conv3_to_pool5():
    """The MAC-heaviest stretch of the paper's Table 2 networks fuses as
    ONE group: conv3→conv4→conv5+pool5."""
    plan = plan_fusion(NETWORKS["alexnet"](), method_for=lambda n: SIMD)
    groups = fusion_summary(plan)
    assert ("conv3", "conv4", "conv5", "pool5") in groups
    (chain,) = [it for it in plan if isinstance(it, FusedLayerSpec)
                and len(it.convs) > 1]
    assert [cv.name for cv in chain.convs] == ["conv3", "conv4", "conv5"]
    assert chain.relus == (True, True, True)
    assert chain.pool is not None and chain.pool.name == "pool5"


def test_planner_chain_without_pool_tail():
    net = NetworkDef("t", (3, 16, 16), 4, (
        _conv("c1", 8), _conv("c2", 8),
        LayerSpec("flatten", "flatten"),
        LayerSpec("fc", "f1", out_channels=4),
    ))
    plan = plan_fusion(net, method_for=lambda n: SIMD)
    assert fusion_summary(plan) == [("c1", "c2")]
    (g,) = [it for it in plan if isinstance(it, FusedLayerSpec)]
    assert g.pool is None and len(g.convs) == 2


def test_planner_lone_conv_never_groups():
    net = NetworkDef("t", (3, 16, 16), 4, (
        _conv("c1", 8),
        LayerSpec("flatten", "flatten"),
        LayerSpec("fc", "f1", out_channels=4),
    ))
    assert fusion_summary(plan_fusion(net, method_for=lambda n: SIMD)) == []


def test_planner_chain_absorbs_standalone_relus():
    net = NetworkDef("t", (3, 16, 16), 4, (
        _conv("c1", 8, relu=False), LayerSpec("relu", "r1"),
        _conv("c2", 8, relu=False), LayerSpec("relu", "r2"),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
    ))
    plan = plan_fusion(net, method_for=lambda n: SIMD)
    assert fusion_summary(plan) == [("c1", "r1", "c2", "r2", "p")]
    (g,) = plan
    assert g.relus == (True, True)


def test_planner_chain_breaks_on_opt_out_and_method_mismatch():
    net = NETWORKS["alexnet"]()
    # conv4 opted out: conv3 is a lone conv (no group), conv4 per-layer,
    # conv5+pool5 still fuse
    groups = fusion_summary(plan_fusion(net, method_for=lambda n: SIMD,
                                        no_fuse={"conv4"}))
    assert ("conv5", "pool5") in groups
    assert not any("conv3" in g or "conv4" in g for g in groups)
    # a method change between conv4 and conv5 splits the chain there
    meth = lambda n: Method.BASIC_SIMD if n == "conv5" else SIMD
    groups = fusion_summary(plan_fusion(net, method_for=meth))
    assert ("conv3", "conv4") in groups
    assert ("conv5", "pool5") in groups


def test_planner_unfoldable_relu_ends_chain_before_pool():
    net = NetworkDef("t", (3, 16, 16), 4, (
        _conv("c1", 8), _conv("c2", 8), LayerSpec("relu", "r"),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
    ))
    # fuse_relu=False: the chain may not absorb r, so the pool (behind
    # it) stays out — but the conv→conv chain itself still fuses
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        fuse_relu=False)) == [("c1", "c2")]


def test_planner_falls_back_to_shorter_chain():
    """When the full chain's floor cell busts the budget, trailing convs
    are dropped one at a time — the detached tail re-enters the scan and
    groups among itself — before fusion is declined outright."""
    net = NetworkDef("t", (64, 16, 64), 4, (
        _conv("c1", 64), _conv("c2", 64), _conv("c3", 64),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
    ))
    full = fusion_summary(plan_fusion(net, method_for=lambda n: SIMD))
    assert full == [("c1", "c2", "c3", "p")]
    # budget that fits a 2-chain floor cell but not the 3-chain's, not
    # even with the oc-blocked final stage (the new admission rung sits
    # between "full chain" and "drop the trailing conv")
    convs = [l for l in net.layers if l.kind == "conv"]
    pool = net.layers[-1]
    need3 = chain_working_set(convs, pool, SIMD, 64, 16, 64)
    need3_blocked = chain_working_set(convs, pool, SIMD, 64, 16, 64,
                                      oc_block_final=8)
    need2 = chain_working_set(convs[:2], None, SIMD, 64, 16, 64)
    assert need2 < need3_blocked < need3
    groups = fusion_summary(plan_fusion(net, method_for=lambda n: SIMD,
                                        vmem_budget=(need2 + need3) // 2))
    assert groups == [("c1", "c2"), ("c3", "p")]
    # a budget below every floor cell declines fusion entirely
    assert fusion_summary(plan_fusion(net, method_for=lambda n: SIMD,
                                      vmem_budget=1024)) == []
    # the XLA analogue has no VMEM ceiling: vmem_check=False keeps the
    # full chain regardless of budget
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD, vmem_check=False)) == full


def test_planner_blocks_final_stage_before_dropping_conv():
    """A budget too small for the full chain but large enough for its
    oc-blocked-final-stage variant keeps the WHOLE chain, with
    ``oc_block_final`` recorded on the group — the new admission rung
    fires before any trailing conv is popped."""
    net = NetworkDef("t", (64, 16, 64), 4, (
        _conv("c1", 64), _conv("c2", 64), _conv("c3", 64),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
    ))
    convs = [l for l in net.layers if l.kind == "conv"]
    pool = net.layers[-1]
    need3 = chain_working_set(convs, pool, SIMD, 64, 16, 64)
    need3_blocked = chain_working_set(convs, pool, SIMD, 64, 16, 64,
                                      oc_block_final=8)
    assert need3_blocked < need3
    plan = plan_fusion(net, method_for=lambda n: SIMD,
                       vmem_budget=(need3_blocked + need3) // 2)
    assert fusion_summary(plan) == [("c1", "c2", "c3", "p")]
    (g,) = [it for it in plan if isinstance(it, FusedLayerSpec)]
    assert g.oc_block_final == 8


def test_chain_cell_bytes_shrinks_with_oc_block_final():
    """Blocking the final stage must shrink the modelled cell: the final
    weight block and the final accumulator/output tiles drop from
    full-width oc to the block."""
    chain = ((3, 3, 1, 1, 1, 1), (3, 3, 1, 1, 1, 1), (3, 3, 1, 1, 1, 1))
    ocs = (384, 384, 256)
    for pool in ((3, 3, 2, 2), None):
        for im2col in (True, False):
            full = K.chain_cell_bytes(2, 13, 13, 256, chain, ocs, pool,
                                      im2col=im2col)
            blocked = K.chain_cell_bytes(2, 13, 13, 256, chain, ocs, pool,
                                         im2col=im2col, oc_block_final=8)
            assert blocked < full
            # monotone in the block width, capped at full width
            sizes = [K.chain_cell_bytes(2, 13, 13, 256, chain, ocs, pool,
                                        im2col=im2col, oc_block_final=b)
                     for b in (8, 32, 128, 256)]
            assert sizes == sorted(sizes)
            assert sizes[-1] == full


# ---------------------------------------------------------------------------
# chain Pallas kernel vs the per-layer reference (interpret mode)
# ---------------------------------------------------------------------------


def _chain_case(n, c, h, w_, ocs, ks, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, c, h, w_),
                          jnp.float32)
    ws, bs = [], []
    ci = c
    for i, (oc, k) in enumerate(zip(ocs, ks)):
        ws.append(jax.random.normal(jax.random.PRNGKey(seed + 10 + i),
                                    (oc, ci, k, k)) * 0.1)
        bs.append(jax.random.normal(jax.random.PRNGKey(seed + 20 + i),
                                    (oc,)))
        ci = oc
    return x, tuple(ws), tuple(bs)


def _ref_chain(x, ws, bs, strides, pads, relus):
    for w, b, s, p, r in zip(ws, bs, strides, pads, relus):
        x = conv2d_ref(x, w, b, s, p, relu=r)
    return x


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
@pytest.mark.parametrize("depth", [2, 3])
@pytest.mark.parametrize("pool", [None, ("max", (3, 3), (2, 2)),
                                  ("avg", (2, 2), (2, 2))])
def test_chain_kernel_matches_per_layer(method, depth, pool):
    """methods × chain lengths 2–3 × with/without pool tail (the ISSUE's
    acceptance matrix), against the per-layer reference ladder."""
    ocs = (7, 6, 9)[:depth]
    ks = (3, 3, 5)[:depth]
    strides = (((1, 1),) * depth)
    pads = tuple((k // 2, k // 2) for k in ks)
    relus = (True,) * (depth - 1) + (False,)
    x, ws, bs = _chain_case(2, 5, 20, 18, ocs, ks)
    ref = _ref_chain(x, ws, bs, strides, pads, relus)
    kwargs = {}
    if pool is not None:
        kind, pk, ps = pool
        ref = pool2d_ref(ref, pk, ps, kind)
        kwargs = dict(pool_kernel=pk, pool_stride=ps, pool_kind=kind)
    out = conv2d_chain(x, ws, bs, strides, pads, relus, method=method,
                       interpret=True, **kwargs)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
def test_chain_kernel_multi_tile_strided(method):
    """A tiny oh_block forces several bands per frame: the composed halo,
    the intermediate vertical-padding masking, and a strided middle stage
    must all band correctly."""
    x, ws, bs = _chain_case(1, 4, 33, 21, (6, 5), (3, 5), seed=3)
    strides = ((1, 1), (2, 2))
    pads = ((1, 1), (2, 2))
    relus = (True, True)
    ref = _ref_chain(x, ws, bs, strides, pads, relus)
    for ohb in (4, 1):
        out = conv2d_chain(x, ws, bs, strides, pads, relus, method=method,
                           interpret=True, oh_block=ohb)
        assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
@pytest.mark.parametrize("lrn_n", [4, 5])  # even n: asymmetric padding
def test_chain_lrn_tail(method, lrn_n):
    """conv→conv→pool→LRN in one cell, including `engine._lrn`'s even-n
    asymmetric window padding."""
    lrn_kw = dict(lrn_alpha=2e-2, lrn_beta=0.75, lrn_k=2.0)
    x, ws, bs = _chain_case(1, 4, 18, 16, (6, 7), (3, 3), seed=5)
    strides, pads, relus = ((1, 1),) * 2, ((1, 1),) * 2, (True, True)
    ref = pool2d_ref(_ref_chain(x, ws, bs, strides, pads, relus),
                     (3, 3), (2, 2), "max")
    ref = _lrn(ref, LayerSpec("lrn", "n", lrn_n=lrn_n, **lrn_kw))
    out = conv2d_chain(x, ws, bs, strides, pads, relus, method=method,
                       interpret=True, pool_kernel=(3, 3),
                       pool_stride=(2, 2), lrn_n=lrn_n, **lrn_kw)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("obf", [4, 8])
@pytest.mark.parametrize("pool", [None, ("max", (3, 3), (2, 2))])
def test_chain_oc_block_final_matches_per_layer(obf, pool):
    """The oc-blocked final stage: the outer oc-tile grid axis recomputes
    the upstream stages per tile but must reproduce the full-width chain
    exactly (same fp32 accumulation order per output element)."""
    x, ws, bs = _chain_case(2, 5, 20, 18, (7, 6, 9), (3, 3, 5), seed=11)
    strides = ((1, 1),) * 3
    pads = ((1, 1), (1, 1), (2, 2))
    relus = (True, True, False)
    ref = _ref_chain(x, ws, bs, strides, pads, relus)
    kwargs = {}
    if pool is not None:
        kind, pk, ps = pool
        ref = pool2d_ref(ref, pk, ps, kind)
        kwargs = dict(pool_kernel=pk, pool_stride=ps, pool_kind=kind)
    for ohb in (None, 4):
        out = conv2d_chain(x, ws, bs, strides, pads, relus,
                           method="advanced_simd_128", interpret=True,
                           oh_block=ohb, oc_block_final=obf, **kwargs)
        assert out.shape == ref.shape
        assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_chain_oc_block_final_rejects_lrn():
    x, ws, bs = _chain_case(1, 3, 12, 12, (4, 4), (3, 3))
    strides, pads, relus = ((1, 1),) * 2, ((1, 1),) * 2, (True, True)
    with pytest.raises(ValueError, match="LRN"):
        conv2d_chain(x, ws, bs, strides, pads, relus,
                     method="advanced_simd_128", interpret=True,
                     pool_kernel=(2, 2), pool_stride=(2, 2), lrn_n=5,
                     oc_block_final=4)


def test_chain_rejects_non_simd_and_bare_lrn():
    x, ws, bs = _chain_case(1, 3, 8, 8, (4, 4), (3, 3))
    strides, pads, relus = ((1, 1),) * 2, ((1, 1),) * 2, (True, True)
    with pytest.raises(ValueError, match="SIMD"):
        conv2d_chain(x, ws, bs, strides, pads, relus,
                     method="basic_parallel", interpret=True)
    with pytest.raises(ValueError, match="pool"):
        conv2d_chain(x, ws, bs, strides, pads, relus,
                     method="basic_simd", interpret=True, lrn_n=5)
    with pytest.raises(ValueError, match="SIMD"):
        conv2d_chain_fused(x, ws, bs, Method.SEQ_REF, strides, pads, relus)


# ---------------------------------------------------------------------------
# the one-NHWC-pass XLA analogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", [Method.BASIC_SIMD, Method.ADVANCED_SIMD_4,
                                    Method.ADVANCED_SIMD_8])
@pytest.mark.parametrize("pool", [None, ("max", (3, 3), (2, 2))])
def test_chain_fused_xla_matches_per_layer(method, pool):
    x, ws, bs = _chain_case(2, 5, 20, 18, (7, 6, 9), (3, 3, 5), seed=7)
    strides = ((1, 1),) * 3
    pads = ((1, 1), (1, 1), (2, 2))
    relus = (True, True, True)
    ref = _ref_chain(x, ws, bs, strides, pads, relus)
    kwargs = {}
    if pool is not None:
        kind, pk, ps = pool
        ref = pool2d_ref(ref, pk, ps, kind)
        kwargs = dict(pool_kernel=pk, pool_stride=ps, pool_kind=kind)
    out = conv2d_chain_fused(x, ws, bs, method, strides, pads, relus,
                             use_pallas=False, **kwargs)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


# ---------------------------------------------------------------------------
# the shared VMEM working-set model
# ---------------------------------------------------------------------------


def test_fused_cell_bytes_monotone_in_phb_and_oc_block():
    """More pooled rows or a wider oc tile can only grow the modelled
    cell — the auto walks rely on it."""
    pool = (3, 3, 2, 2)
    args = dict(ow=54, wp=58, c=96, kh=5, kw=5, sy=1, pool=pool)
    for im2col in (True, False):
        sizes = [K.fused_cell_bytes(phb, oc_block=8, im2col=im2col, **args)
                 for phb in (1, 2, 4, 8, 16)]
        assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)
        sizes = [K.fused_cell_bytes(4, oc_block=ocb, im2col=im2col, **args)
                 for ocb in (4, 8, 32, 128)]
        assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)


def test_chain_cell_bytes_monotone_in_blk():
    chain = ((3, 3, 1, 1, 1, 1), (3, 3, 1, 1, 1, 1), (3, 3, 1, 1, 1, 1))
    ocs = (384, 384, 256)
    for pool in ((3, 3, 2, 2), None):
        for im2col in (True, False):
            sizes = [K.chain_cell_bytes(blk, 13, 13, 256, chain, ocs, pool,
                                        im2col=im2col)
                     for blk in (1, 2, 3, 4, 6)]
            assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes)


@pytest.mark.parametrize("net_name", ["lenet5", "cifar10", "alexnet"])
@pytest.mark.parametrize("method", [Method.BASIC_SIMD, Method.ADVANCED_SIMD_4,
                                    Method.ADVANCED_SIMD_8])
def test_planner_kernel_agreement(net_name, method):
    """Every planner-approved group must resolve a band the kernel can
    actually stage: the executed block is ≥ 1 and its modelled cell fits
    the same budget the planner checked against."""
    net = NETWORKS[net_name]()
    eng = CNNEngine(net, method=method, use_pallas=True)
    plan = eng.plan(True)
    report = {g["group"]: g for g in eng.fusion_report()}
    c, h, w = net.input_shape
    for it in plan:
        if not isinstance(it, FusedLayerSpec):
            if it.kind == "conv":
                kh, kw = it.kernel
                h = (h + 2 * it.padding[0] - kh) // it.stride[0] + 1
                w = (w + 2 * it.padding[1] - kw) // it.stride[1] + 1
                c = it.out_channels
            elif it.kind == "pool":
                h = (h - it.kernel[0]) // it.stride[0] + 1
                w = (w - it.kernel[1]) // it.stride[1] + 1
            continue
        geo = report[it.name]
        assert geo["rows_per_cell"] >= 1 and geo["n_tiles"] >= 1
        im2col = method in (Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8)
        if len(it.convs) > 1:
            chain, ocs = layers_as_chain(it.convs)
            cp = -(-c // SUBLANES) * SUBLANES
            pool_t = (it.pool.kernel[0], it.pool.kernel[1],
                      it.pool.stride[0], it.pool.stride[1]) \
                if it.pool is not None else None
            assert K.chain_cell_bytes(
                geo["rows_per_cell"], h, w, cp, chain, ocs, pool_t,
                im2col=im2col) <= K.CHAIN_VMEM_BUDGET_BYTES
        else:
            # the planner's floor check implies the executed (equalized)
            # band also fits the soft budget — same model, larger-or-
            # equal band never smaller than floor ⇒ verify directly
            assert fused_working_set(
                it.convs[0], it.pool, method, c, w,
                lrn=it.lrn is not None) <= K.VMEM_BUDGET_BYTES
        for cv in it.convs:
            kh, kw = cv.kernel
            h = (h + 2 * cv.padding[0] - kh) // cv.stride[0] + 1
            w = (w + 2 * cv.padding[1] - kw) // cv.stride[1] + 1
        c = it.convs[-1].out_channels
        if it.pool is not None:
            h = (h - it.pool.kernel[0]) // it.pool.stride[0] + 1
            w = (w - it.pool.kernel[1]) // it.pool.stride[1] + 1


# ---------------------------------------------------------------------------
# whole-network: the alexnet chain end-to-end
# ---------------------------------------------------------------------------


def test_alexnet_chain_single_dispatch_interpret():
    """conv3→conv4→conv5+pool5 executes as ONE fused group on the Pallas
    path and the fused forward matches the sequential reference."""
    net = NETWORKS["alexnet"]()
    eng = CNNEngine(net, method=SIMD, use_pallas=True)
    groups = fusion_summary(eng.plan(True))
    assert ("conv3", "conv4", "conv5", "pool5") in groups
    ref_eng = CNNEngine(net, method=Method.SEQ_REF)
    params = ref_eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, *net.input_shape),
                          jnp.float32)
    ref = ref_eng.forward(params, x)
    out = eng.forward(params, x, fuse=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
