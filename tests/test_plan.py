"""ExecutionPlan IR tests: compile-time resolution (shapes, standalone-
ReLU folding, fusion grouping), plan↔legacy forward equivalence across
the paper networks × methods × fuse settings, the batch-bucketed jit
cache's compile bound, and knob-setter cache invalidation (the stale-plan
bugfix)."""
import jax
import jax.numpy as jnp
import pytest

import repro.core.plan as plan_mod
from repro.core.engine import CNNEngine
from repro.core.fusion import fusion_summary
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS, LayerSpec, NetworkDef
from repro.core.plan import compile_plan, infer_param_shapes

SIMD = Method.ADVANCED_SIMD_8


# ---------------------------------------------------------------------------
# compile-time resolution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("net_name", ["lenet5", "cifar10", "alexnet"])
def test_plan_steps_fully_resolved(net_name):
    """Every step carries resolved input/output shapes; fused steps carry
    their method, band override and LRN constants — nothing is left for
    forward to decide."""
    net = NETWORKS[net_name]()
    plan = compile_plan(net, method=SIMD, fuse=True)
    shapes = infer_param_shapes(net)
    cur = tuple(net.input_shape)
    for step in plan.steps:
        assert step.in_shape == cur
        cur = step.out_shape
        if step.kind in ("fused", "chain"):
            assert step.method is SIMD
            assert step.group is not None and step.kwargs is not None
            assert "lrn_n" in step.kwargs
        elif step.kind == "fc":
            assert step.d_in == shapes[step.spec.name][0]
        # the paper nets express activations as conv/pool relu flags, so
        # a fully-folded plan has no standalone relu steps
        assert step.kind != "relu"
    assert plan.steps[-1].kind == "softmax"
    assert cur == (net.num_classes,)
    # every original layer is covered exactly once, in order
    covered = [n for s in plan.steps for n in s.names]
    assert covered == [l.name for l in net.layers]


def _relu_net():
    return NetworkDef("t", (3, 16, 16), 4, (
        LayerSpec("conv", "c", out_channels=4, kernel=(3, 3)),
        LayerSpec("relu", "r"),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
        LayerSpec("relu", "r2"),
    ))


def test_standalone_relu_folds_at_compile_time():
    plan = compile_plan(_relu_net(), method=SIMD, fuse=False)
    assert [s.kind for s in plan.steps] == ["conv", "pool"]
    assert plan.steps[0].relu and plan.steps[0].names == ("c", "r")
    assert plan.steps[1].relu and plan.steps[1].names == ("p", "r2")
    # fuse_relu=False: the activations stay their own steps, un-reordered
    plan_nf = compile_plan(_relu_net(), method=SIMD, fuse=False,
                           fuse_relu=False)
    assert [s.kind for s in plan_nf.steps] == ["conv", "relu", "pool",
                                               "relu"]
    assert not plan_nf.steps[0].relu


def test_collect_sees_folded_relu_names():
    """Folded standalone ReLUs still report under their own layer name in
    ``collect`` (instrumentation parity with the per-layer interpreter)."""
    net = _relu_net()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *net.input_shape),
                          jnp.float32)
    acts = {}
    eng.forward(params, x, collect=acts)
    assert set(acts) == {"c", "r", "p", "r2"}
    assert jnp.array_equal(acts["c"], acts["r"])  # conv records post-fold


def test_planner_runs_once_per_config(monkeypatch):
    """compile_plan subsumes plan_fusion: the planner runs once per
    (config, fuse) — repeated forwards re-use the compiled plan, and only
    a knob mutation forces a re-plan."""
    calls = {"n": 0}
    real = plan_mod.plan_fusion

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(plan_mod, "plan_fusion", counting)
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, *net.input_shape), jnp.float32)
    eng.forward(params, x)
    eng.forward(params, x)
    eng.fusion_report()
    assert calls["n"] == 1
    eng.per_layer_fuse["conv1"] = False  # knob mutation -> re-plan
    eng.forward(params, x)
    assert calls["n"] == 2


# ---------------------------------------------------------------------------
# plan ↔ legacy forward equivalence (3 nets × methods × fuse settings)
# ---------------------------------------------------------------------------

_NET_BATCH = {"lenet5": 3, "cifar10": 3, "alexnet": 1}  # ragged on purpose


@pytest.fixture(scope="module", params=["lenet5", "cifar10", "alexnet"])
def net_params_ref(request):
    net = NETWORKS[request.param]()
    eng = CNNEngine(net, method=Method.SEQ_REF)
    params = eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (_NET_BATCH[request.param], *net.input_shape),
                          jnp.float32)
    return net, params, x, eng.forward(params, x)


@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("method", [Method.SEQ_REF, Method.BASIC_SIMD,
                                    Method.ADVANCED_SIMD_8])
def test_plan_forward_matches_reference(net_params_ref, method, fuse):
    net, params, x, ref = net_params_ref
    eng = CNNEngine(net, method=method)
    out = eng.forward(params, x, fuse=fuse)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


# ---------------------------------------------------------------------------
# batch-bucketed jit cache
# ---------------------------------------------------------------------------


def test_batch_bucket_rounding():
    assert [CNNEngine.batch_bucket(n) for n in range(1, 10)] == \
        [1, 2, 4, 4, 8, 8, 8, 8, 16]
    with pytest.raises(ValueError):
        CNNEngine.batch_bucket(0)


def test_bucketed_cache_compile_bound():
    """Batch sizes 1..max_batch compile at most log2(max_batch)+1 jitted
    variants, repeat sizes within a bucket add zero, and the padded rows
    never leak into the sliced-back outputs."""
    max_batch = 8
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    xs = jax.random.normal(jax.random.PRNGKey(1),
                           (max_batch, *net.input_shape), jnp.float32)
    for n in range(1, max_batch + 1):
        out = eng.forward_batched(params, xs[:n])
        assert out.shape == (n, net.num_classes)
    stats = eng.bucket_stats()
    assert stats["compiles"] <= max_batch.bit_length()  # log2(8)+1 = 4
    assert stats["buckets"] == [(True, 1), (True, 2), (True, 4), (True, 8)]
    # repeat every size: zero recompiles (the bucket jits are warm)
    for n in range(1, max_batch + 1):
        eng.forward_batched(params, xs[:n])
    assert eng.bucket_stats()["compiles"] == stats["compiles"]
    # each bucket jit only ever saw its one padded shape
    for fn in eng._bucket_jits.values():
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() == 1
    # padding correctness: a frame's row is byte-identical whatever its
    # batchmates within a bucket (zero-pad rows are just batchmates)
    a = eng.forward_batched(params, xs[:3])  # bucket 4, one pad row
    b = eng.forward_batched(params, xs[:4])  # bucket 4, no pad
    assert jnp.array_equal(a, b[:3])
    # and the sliced result agrees with the eager per-plan forward
    eager = eng.forward(params, xs[:3])
    assert jnp.max(jnp.abs(a - eager)) < 1e-5


# ---------------------------------------------------------------------------
# knob invalidation (the stale-plan bugfix)
# ---------------------------------------------------------------------------


def test_knob_setters_invalidate_plan_and_jits():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, *net.input_shape), jnp.float32)
    eng.forward_batched(params, x)
    p0 = eng.plan(True)
    jf0 = eng.jit_forward(True)
    assert eng.bucket_stats()["buckets"]
    eng.oh_block = 4  # scalar knob assignment
    assert eng.plan(True) is not p0
    assert eng.jit_forward(True) is not jf0
    assert eng.bucket_stats()["buckets"] == []  # bucket jits dropped too


def test_noop_knob_writes_keep_warm_caches():
    """Idempotently re-asserting the current config (same scalar value,
    same-key setdefault, equal-content update) must NOT drop the warm
    plans/jits — the steady-state serving loop depends on never
    recompiling."""
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD, oh_block=4,
                    per_layer_oh_blocks={"conv1": 2})
    p0 = eng.plan(True)
    jf0 = eng.jit_forward(True)
    eng.method = SIMD
    eng.oh_block = 4
    eng.per_layer_oh_blocks["conv1"] = 2            # same value
    eng.per_layer_oh_blocks.setdefault("conv1", 9)  # pure read
    eng.per_layer_oh_blocks.update({"conv1": 2})    # equal content
    eng.per_layer_fuse |= {}                        # empty merge
    assert eng.plan(True) is p0 and eng.jit_forward(True) is jf0
    eng.oh_block = 8  # a REAL change still invalidates
    assert eng.plan(True) is not p0


def test_per_layer_fuse_mutation_replans():
    """Mutating per_layer_fuse after the first forward used to keep
    serving the memoized old plan; it must re-plan."""
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, *net.input_shape), jnp.float32)
    eng.forward(params, x)  # memoizes the fused plan
    assert ("conv1", "pool1") in fusion_summary(eng.plan(True))
    eng.per_layer_fuse["conv1"] = False  # in-place dict mutation
    assert all("conv1" not in g for g in fusion_summary(eng.plan(True)))
    eng.forward(params, x)  # and the new plan actually executes
    # |= through an alias must invalidate too (dict.__ior__ would
    # bypass the overridden update())
    alias = eng.per_layer_fuse
    alias |= {"conv2": False}
    assert fusion_summary(eng.plan(True)) == []


def test_per_layer_method_mutation_replans():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    assert fusion_summary(eng.plan(True))
    eng.per_layer_methods.update({"conv1": Method.BASIC_PARALLEL})
    groups = fusion_summary(eng.plan(True))
    assert all("conv1" not in g for g in groups)
    eng.method = Method.BASIC_PARALLEL  # engine-wide method reassignment
    assert fusion_summary(eng.plan(True)) == []


def test_clear_caches_covers_bucket_cache():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    params = eng.init(jax.random.PRNGKey(0))
    eng.forward_batched(params, jnp.ones((3, *net.input_shape), jnp.float32))
    assert eng._plans and eng._bucket_jits
    assert eng.bucket_stats()["compiles"] == 1
    eng.clear_caches()
    assert not eng._plans and not eng._jit_cache and not eng._bucket_jits
    # the compile counter tracks the live cache: a post-invalidation
    # sweep starts the bound from zero instead of double-counting
    assert eng.bucket_stats()["compiles"] == 0


# ---------------------------------------------------------------------------
# fusion report reads straight off the plan
# ---------------------------------------------------------------------------


def test_fusion_report_off_plan():
    net = NETWORKS["alexnet"]()
    eng = CNNEngine(net, method=SIMD, use_pallas=True)
    report = eng.fusion_report()
    assert [g["group"] for g in report] == \
        ["+".join(g) for g in fusion_summary(eng.plan(True))]
    for g in report:
        assert g["rows_per_cell"] >= 1 and g["n_tiles"] >= 1
        assert len(g["out_hw"]) == 2
