"""Paper-core tests: the CNNdroid engine, method ladder, and deployment."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.deploy import save_model, load_model
from repro.core.engine import CNNEngine
from repro.core.methods import Method, LADDER, conv2d, fc_seq_ref, fc_fused
from repro.core.netdefs import NETWORKS
from repro.core.layout import (
    nchw_to_nhwc, nhwc_to_nchw, oihw_to_hwio, hwio_to_oihw, pad_axis,
    unpad_axis,
)


@pytest.fixture(scope="module", params=["lenet5", "cifar10"])
def net_and_params(request):
    net = NETWORKS[request.param]()
    eng = CNNEngine(net, method=Method.SEQ_REF)
    params = eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, *net.input_shape),
                          jnp.float32)
    ref = eng.forward(params, x)
    return net, params, x, ref


@pytest.mark.parametrize("method", LADDER[1:])
def test_ladder_methods_match_sequential(net_and_params, method):
    """Every acceleration method computes the same network output as the
    §4.1 sequential reference (the paper's correctness contract)."""
    net, params, x, ref = net_and_params
    out = CNNEngine(net, method=method).forward(params, x)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_output_is_distribution(net_and_params):
    net, params, x, ref = net_and_params
    assert ref.shape == (4, net.num_classes)
    assert jnp.allclose(jnp.sum(ref, axis=-1), 1.0, atol=1e-5)


def test_per_layer_method_selection(net_and_params):
    net, params, x, ref = net_and_params
    conv_names = [l.name for l in net.layers if l.kind == "conv"]
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8,
                    per_layer_methods={conv_names[0]: Method.BASIC_SIMD})
    assert jnp.max(jnp.abs(eng.forward(params, x) - ref)) < 1e-4


def test_deploy_roundtrip(tmp_path, net_and_params):
    net, params, x, ref = net_and_params
    save_model(tmp_path / "m", net, params, {"note": "test"})
    net2, params2, extra = load_model(tmp_path / "m")
    assert extra["note"] == "test"
    out = CNNEngine(net2, method=Method.ADVANCED_SIMD_4).forward(params2, x)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_deploy_detects_corruption(tmp_path, net_and_params):
    import numpy as np

    net, params, x, ref = net_and_params
    save_model(tmp_path / "m", net, params)
    data = dict(np.load(tmp_path / "m" / "weights.npz"))
    key = sorted(data)[0]
    data[key] = data[key] + 1.0
    np.savez(tmp_path / "m" / "weights.npz", **data)
    with pytest.raises(ValueError, match="checksum"):
        load_model(tmp_path / "m")


def test_deploy_detects_dtype_corruption(tmp_path, net_and_params):
    """A weights.npz re-saved at a different dtype — with the checksum
    refreshed to match, so the integrity check alone cannot catch it —
    must still be rejected against the manifest's recorded dtype."""
    import hashlib
    import json

    import numpy as np

    net, params, x, ref = net_and_params
    save_model(tmp_path / "m", net, params)
    net2, params2, _ = load_model(tmp_path / "m")  # round-trip still loads
    data = dict(np.load(tmp_path / "m" / "weights.npz"))
    key = sorted(data)[0]
    data[key] = data[key].astype(np.float16)
    np.savez(tmp_path / "m" / "weights.npz", **data)
    digest = hashlib.sha256()
    for k in sorted(data):
        digest.update(k.encode())
        digest.update(data[k].tobytes())
    manifest = json.loads((tmp_path / "m" / "manifest.json").read_text())
    manifest["weights_sha256"] = digest.hexdigest()
    (tmp_path / "m" / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="dtype"):
        load_model(tmp_path / "m")


def test_fc_after_conv_without_flatten():
    """An fc straight after a conv/pool (no flatten layer) must consume
    the whole c*h*w activation — sizing it from the channel count alone
    silently dropped the spatial extent."""
    from repro.core.netdefs import LayerSpec, NetworkDef

    def build(with_flatten):
        mid = ((LayerSpec("flatten", "flatten"),) if with_flatten else ())
        return NetworkDef("t", (3, 12, 12), 5, (
            LayerSpec("conv", "c1", out_channels=6, kernel=(3, 3),
                      relu=True),
            LayerSpec("pool", "p1", kernel=(2, 2), stride=(2, 2)),
            *mid,
            LayerSpec("fc", "f1", out_channels=5),
        ))

    eng = CNNEngine(build(False), method=Method.SEQ_REF)
    # conv 12->10, pool 10->5: the fc must see 6*5*5, not 6
    assert eng._shapes["f1"] == (6 * 5 * 5, 5)
    params = eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 12, 12), jnp.float32)
    out = eng.forward(params, x)
    assert out.shape == (2, 5)
    # identical to the same net with an explicit flatten layer
    ref = CNNEngine(build(True), method=Method.SEQ_REF).forward(params, x)
    assert jnp.max(jnp.abs(out - ref)) == 0.0


def test_alexnet_shapes():
    net = NETWORKS["alexnet"]()
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)
    params = eng.init(jax.random.PRNGKey(0))
    out = eng.forward(params, jnp.ones((1, *net.input_shape), jnp.float32))
    assert out.shape == (1, 1000)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_fc_ladder():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.1
    b = jnp.ones((32,))
    assert jnp.max(jnp.abs(fc_fused(x, w, b, relu=True)
                           - fc_seq_ref(x, w, b, relu=True))) < 1e-5


def test_layout_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 5, 7))
    assert jnp.array_equal(nhwc_to_nchw(nchw_to_nhwc(x)), x)
    k = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 5, 5))
    assert jnp.array_equal(hwio_to_oihw(oihw_to_hwio(k)), k)
    xp, orig = pad_axis(nchw_to_nhwc(x), 3, 8)
    assert xp.shape[3] == 8 and orig == 3
    assert jnp.array_equal(unpad_axis(xp, 3, orig), nchw_to_nhwc(x))
