"""tools/meminspect.py: pure HLO-parsing helpers + CLI exit codes.

The helpers are driven on synthetic HLO text (no compilation); the CLI
is only exercised on its failure paths — unknown arch/shape must exit 2
without touching the 512-device compile path."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
try:
    import meminspect
finally:
    sys.path.pop(0)

# f32[1024,1024,256] = 1 GiB; f32[512,1024,256] = 0.5 GiB;
# f32[1024,256] = 1 MiB
_BIG = "f32[1024,1024,256]"
_HALF = "f32[512,1024,256]"
_SMALL = "f32[1024,256]"

_HLO = f"""\
ENTRY %main (p0: {_BIG}) -> {_BIG} {{
  %p0 = {_BIG} parameter(0)
  %big.state = ({_BIG}, {_HALF}, {_SMALL}) while(%tuple.1), \
known_trip_count={{n: 7}}
  %small.state = ({_SMALL}) while(%tuple.2)
  %huge.add = {_BIG} add(%p0, %p0)
  %tiny.mul = {_SMALL} multiply(%p0, %p0)
  ROOT %out = {_BIG} copy(%huge.add)
}}
"""

GIB = 1 << 30


def test_while_states_thresholds():
    states = meminspect.while_states(_HLO)
    # only the 1.5 GiB state passes the 0.5 GiB floor; the 1 MiB one is
    # dropped
    assert len(states) == 1
    total, name, trip, parts = states[0]
    assert name == "big.state"
    assert trip == "7"
    assert total == GIB + GIB // 2 + (1 << 20)
    # component cutoff: only the >= TENSOR_MIN_BYTES members are listed
    assert [(b, t) for b, t in parts] == [(GIB, _BIG), (GIB // 2, _HALF)]


def test_largest_tensors_skips_parameters():
    tensors = meminspect.largest_tensors(_HLO)
    names = [n for _b, _op, _t, n in tensors]
    assert "p0" not in names  # parameters are never "largest tensors"
    assert "tiny.mul" not in names  # below TENSOR_MIN_BYTES
    ops = [op for _b, op, _t, _n in tensors]
    assert ops[0] in ("add", "copy")  # both 1 GiB, sorted first
    assert {"add", "copy"} <= set(ops)


def test_largest_tensors_top_limit():
    many = "\n".join(f"  %t{i} = {_BIG} add(%a, %b)" for i in range(30))
    assert len(meminspect.largest_tensors(many, top=5)) == 5


def test_constants_are_named():
    # the R005 lint fix: the thresholds are named module constants and
    # the comparisons go through them
    assert meminspect.WHILE_STATE_MIN_BYTES == 1 << 29
    assert meminspect.TENSOR_MIN_BYTES == 1 << 28


def test_cli_unknown_arch_exits_2(capsys):
    assert meminspect.main(["no-such-arch", "no-such-shape"]) == 2
    err = capsys.readouterr().err
    assert "no-such-arch" in err


def test_cli_smoke_runs_parsers(monkeypatch, capsys):
    """Drive main() end-to-end with a stubbed compile result — the
    report path must consume the helpers without error."""

    class _Mem:
        argument_size_in_bytes = GIB
        output_size_in_bytes = GIB // 2
        temp_size_in_bytes = 0
        alias_size_in_bytes = 0

    class _Compiled:
        def memory_analysis(self):
            return _Mem()

        def as_text(self):
            return _HLO

    monkeypatch.setattr(meminspect, "_compile",
                        lambda *a, **k: _Compiled())
    assert meminspect.main(["tiny", "tiny"]) == 0
    out = capsys.readouterr().out
    assert "big.state" in out and "trip=7" in out
    assert "while states" in out


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
