import os

# Tests run on the single CPU device (smoke tests and benches must see 1
# device; only launch/dryrun.py forces 512 — see the assignment contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
