"""Chunked flash attention (jnp twin): forward, custom-VJP gradients,
masks, GQA, decode paths, int8 KV cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.nn.attention import (
    chunked_attention,
    reference_attention,
    decode_attention,
    decode_attention_quant,
    cache_update,
    quantize_kv,
)


def _qkv(b=2, s=37, h=8, kvh=4, hd=16, skv=None):
    skv = skv or s
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, kvh, hd))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 9])
@pytest.mark.parametrize("cap", [0.0, 5.0])
def test_forward_and_grads_match_reference(causal, window, cap):
    q, k, v = _qkv()

    def f(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(
            q, k, v, causal=causal, window=window, attn_softcap=cap)))

    f1 = f(lambda *a, **kw: chunked_attention(*a, chunk_q=8, chunk_kv=8, **kw))
    f2 = f(reference_attention)
    assert abs(float(f1(q, k, v) - f2(q, k, v))) < 1e-4
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b_)) < 1e-4


def test_rectangular_cross_attention_grads():
    q, k, v = _qkv(s=13, skv=29)
    f1 = lambda q, k, v: jnp.sum(chunked_attention(
        q, k, v, causal=False, chunk_q=8, chunk_kv=8) ** 2)
    f2 = lambda q, k, v: jnp.sum(reference_attention(q, k, v, causal=False) ** 2)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b_)) < 1e-4


def test_decode_matches_full_attention():
    """Decoding token t against a cache == row t of full causal attention."""
    b, s, h, kvh, hd = 2, 10, 4, 2, 16
    q, k, v = _qkv(b, s, h, kvh, hd)
    full = reference_attention(q, k, v, causal=True)
    kc = jnp.zeros((b, s, kvh, hd))
    vc = jnp.zeros((b, s, kvh, hd))
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        kc, vc = cache_update(kc, vc, k[:, t:t+1], v[:, t:t+1], pos)
        out = decode_attention(q[:, t:t+1], kc, vc, pos)
        assert jnp.max(jnp.abs(out[:, 0] - full[:, t])) < 1e-5


def test_ring_buffer_decode_matches_windowed_attention():
    b, s, h, kvh, hd, w = 1, 12, 2, 2, 8, 4
    q, k, v = _qkv(b, s, h, kvh, hd)
    full = reference_attention(q, k, v, causal=True, window=w)
    kc = jnp.zeros((b, w, kvh, hd))
    vc = jnp.zeros((b, w, kvh, hd))
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        kc, vc = cache_update(kc, vc, k[:, t:t+1], v[:, t:t+1], pos, window=w)
        out = decode_attention(q[:, t:t+1], kc, vc, pos, window=w)
        assert jnp.max(jnp.abs(out[:, 0] - full[:, t])) < 1e-5, t


def test_quantized_decode_close_to_fp():
    b, s, h, kvh, hd = 2, 16, 4, 2, 32
    q, k, v = _qkv(b, s, h, kvh, hd)
    pos = jnp.full((b,), s - 1, jnp.int32)
    fp = decode_attention(q[:, -1:], k, v, pos)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    qt = decode_attention_quant(q[:, -1:], kq, ks, vq, vs, pos, block=8)
    assert jnp.max(jnp.abs(fp - qt)) < 0.05


def test_quantize_kv_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 16)) * 3.0
    qv, sc = quantize_kv(x)
    deq = qv.astype(jnp.float32) * sc.astype(jnp.float32)[..., None]
    # rounding error is at most half a quantization step per element
    bound = sc.astype(jnp.float32)[..., None] * 0.5 + 1e-5
    assert bool(jnp.all(jnp.abs(deq - x) <= bound + 1e-3))
