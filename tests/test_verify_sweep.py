"""Tests for the CI plan-verifier sweep gate (``tools/verify_sweep.py``).

The sweep is the static gate that keeps every bundled reference network
verifying spotless across the full method × fuse × backend grid.  These
tests pin its contract: exit 0 and an empty finding list on the bundled
registry, exit 1 the moment ANY finding appears (exercised with a
seeded-defect netdef injected through ``sweep(networks=...)``), and the
markdown table CI posts to the step summary.
"""
import dataclasses
import importlib.util
import json
import pathlib

from repro.core.netdefs import NETWORKS

_TOOL = pathlib.Path(__file__).resolve().parent.parent / "tools" / \
    "verify_sweep.py"
_spec = importlib.util.spec_from_file_location("verify_sweep", _TOOL)
verify_sweep = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(verify_sweep)


def _broken_lenet5():
    """lenet5 with a num_classes the fc tail cannot land on — every
    compiled plan draws the V102 classifier-tail warning."""
    net = NETWORKS["lenet5"]()
    return dataclasses.replace(net, num_classes=7)


# ---------------------------------------------------------------- sweep

def test_sweep_single_injected_net_is_clean():
    findings, combos = verify_sweep.sweep({"lenet5": NETWORKS["lenet5"]})
    # 3 methods × 2 fuse × 2 backends
    assert combos == 12
    assert findings == []


def test_sweep_defaults_to_bundled_registry():
    findings, combos = verify_sweep.sweep()
    # the base grid plus the forced second-generation cell configs
    # (carry / channel-halo LRN / oc-blocked chain final stage)
    assert combos == 12 * len(NETWORKS) + len(verify_sweep.EXTRA_CONFIGS)
    assert findings == []


def test_sweep_seeded_defect_yields_findings():
    findings, combos = verify_sweep.sweep({"bad": _broken_lenet5})
    assert combos == 12
    # every configuration of the defective net trips the V102 tail check
    assert len(findings) == 12
    assert all(f.rule == "V102" for f in findings)
    assert all(f.severity == "warning" for f in findings)
    # the finding location carries the sweep tag so one table row
    # identifies the exact failing configuration
    assert any(f.step.startswith("bad/basic_simd/fuse=False/pallas=False")
               for f in findings)


# ----------------------------------------------------------- exit codes

def test_main_clean_registry_exits_zero(capsys):
    assert verify_sweep.main([]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out


def test_main_seeded_defect_exits_one(capsys, monkeypatch):
    monkeypatch.setattr(verify_sweep, "NETWORKS", {"bad": _broken_lenet5})
    assert verify_sweep.main([]) == 1
    out = capsys.readouterr().out
    assert "12 finding(s)" in out
    assert "V102" in out


# ------------------------------------------------------------ rendering

def test_main_md_table(capsys, monkeypatch):
    monkeypatch.setattr(verify_sweep, "NETWORKS", {"bad": _broken_lenet5})
    assert verify_sweep.main(["--md"]) == 1
    out = capsys.readouterr().out
    assert out.startswith("### Plan verifier sweep")
    assert "| severity | rule | where | detail |" in out
    assert "| warning | V102 |" in out


def test_main_md_clean(capsys):
    assert verify_sweep.main(["--md"]) == 0
    out = capsys.readouterr().out
    assert "No findings." in out


def test_main_json_output(capsys, monkeypatch):
    monkeypatch.setattr(verify_sweep, "NETWORKS", {"bad": _broken_lenet5})
    assert verify_sweep.main(["--json"]) == 1
    rows = json.loads(capsys.readouterr().out)
    assert len(rows) == 12
    assert {r["rule"] for r in rows} == {"V102"}
