"""Mutation corpus for the kernel sanitizer (K1xx rules).

Each test seeds exactly one defect into the kernel SOURCE TEXT (via the
sanitizer's ``sources`` injection hook — the files on disk are never
touched), re-runs the abstract interpreter, and asserts the matching
K-rule fires.  A clean-pass test drives the full ``tools/sanitize.py``
sweep grid, and an independence test asserts the sanitizer derives its
band intervals without importing the resolver functions the verifier
trusts (the N-version-programming contract)."""
import ast
from pathlib import Path

import pytest

from repro.analysis import sanitizer
from repro.analysis.sanitizer import (
    sanitize_chain,
    sanitize_conv2d,
    sanitize_matmul,
    sanitize_pool2d,
)

KERNELS_ROOT = (Path(sanitizer.__file__).resolve().parent.parent
                / "kernels")


def _mutate(old: str, new: str, module: str = "conv2d", count: int = 0):
    """Seed one defect into a kernel source; returns the ``sources``
    mapping for the sanitize_* calls."""
    src = (KERNELS_ROOT / sanitizer.KERNEL_SOURCES[module]).read_text()
    assert old in src, f"mutation anchor not found: {old!r}"
    mutated = src.replace(old, new) if count == 0 else \
        src.replace(old, new, count)
    assert mutated != src
    return {module: mutated}


def _rules(findings):
    return {f.rule for f in findings}


def _sanitize_carry(sources=None):
    """A fused conv→pool dispatch whose geometry opens the carry gate
    (overlapping pool, 4 bands) with the knob forced on."""
    return sanitize_conv2d((2, 33, 21, 8), (3, 3, 8, 16), padding=(1, 1),
                           relu=True, im2col=True, oh_block=5,
                           pool_kernel=(3, 3), pool_stride=(2, 2),
                           pool_carry=True, sources=sources)


def _sanitize_halo(sources=None):
    """A fused conv→pool→LRN dispatch forced onto the two-pass
    channel-halo cell: oc_block 4 against 16 output channels gives 4 oc
    tiles, each reading lrn_n - 1 = 4 halo weight columns."""
    return sanitize_conv2d((2, 20, 18, 8), (5, 5, 8, 16), padding=(2, 2),
                           relu=True, im2col=True, oc_block=4,
                           pool_kernel=(3, 3), pool_stride=(2, 2),
                           lrn=(5, 2e-2, 0.75, 2.0), lrn_oc_block=True,
                           sources=sources)


# -- clean kernels prove clean ----------------------------------------------


def test_clean_full_sweep_grid():
    """The bundled kernels prove clean across the exact netdef x method
    x fuse x backend grid CI gates on — zero findings, including the
    K105 cross-check against the verifier's derivation."""
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "tools"))
    try:
        import sanitize as sanitize_cli
    finally:
        sys.path.pop(0)
    findings, combos, dispatches = sanitize_cli.sweep()
    # 3 nets x 3 methods x 2 fuse x 2 backends, plus the forced
    # second-generation cell configs (carry / channel-halo LRN /
    # oc-blocked chain final stage)
    assert combos == 36 + len(sanitize_cli.EXTRA_CONFIGS)
    assert dispatches > 100
    assert findings == []


def test_clean_single_dispatches():
    for f, geom in (
        sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), padding=(2, 2),
                        relu=True, im2col=True, oh_block=5),
        sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), padding=(2, 2),
                        im2col=False, oh_block=5),
        sanitize_pool2d((2, 24, 24, 16), kernel=(3, 3), stride=(2, 2),
                        oh_block=4),
        sanitize_matmul((7, 130), (130, 33)),
        sanitize_chain((2, 28, 28, 8), [(3, 3, 8, 16), (3, 3, 16, 16)],
                       strides=[(1, 1), (1, 1)],
                       paddings=[(1, 1), (1, 1)], relus=[True, True],
                       pool_kernel=(2, 2), pool_stride=(2, 2),
                       oh_block=4),
        # second-generation cells: sliding-window pool carry, two-pass
        # channel-halo LRN, oc-blocked chain final stage
        _sanitize_carry(),
        _sanitize_halo(),
        sanitize_chain((2, 28, 28, 8), [(3, 3, 8, 16), (3, 3, 16, 16)],
                       strides=[(1, 1), (1, 1)],
                       paddings=[(1, 1), (1, 1)], relus=[True, True],
                       pool_kernel=(2, 2), pool_stride=(2, 2),
                       oh_block=4, oc_block_final=8),
    ):
        assert f == []


# -- K101: out-of-bounds loads ----------------------------------------------


def test_k101_index_map_offset():
    """+1 on the halo-band element offset walks the last band off the
    padded frame."""
    sources = _mutate("lambda i, t, o: (i, t * row_step, 0, 0)",
                      "lambda i, t, o: (i, t * row_step + 1, 0, 0)")
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=True,
                           sources=sources)
    assert "K101" in _rules(f)


def test_k101_body_load():
    """A pl.ds(1, 1) slice on the size-1 frame axis reads past it."""
    sources = _mutate("x = x_ref[0]", "x = x_ref[pl.ds(1, 1)][0]",
                      count=1)  # first hit: the basic_simd kernel body
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=False,
                           sources=sources)
    assert "K101" in _rules(f)


# -- K102: output coverage --------------------------------------------------


def test_k102_grid_undercount():
    """Dropping one band tile leaves output rows never stored."""
    sources = _mutate("grid=(n, n_tiles),", "grid=(n, n_tiles - 1),",
                      count=1)  # first hit: conv2d_basic_simd
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), padding=(2, 2),
                           im2col=False, oh_block=5, sources=sources)
    assert "K102" in _rules(f)


# -- K103: precision flow ---------------------------------------------------


def test_k103_f64_accumulate():
    sources = _mutate("patches.astype(ACC_DTYPE)",
                      "patches.astype(jnp.float64)")
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=True,
                           sources=sources)
    assert "K103" in _rules(f)


def test_k103_double_downcast():
    sources = _mutate(
        "o_ref[...] = acc.reshape(ohh, oww, ocb).astype(o_ref.dtype)",
        "o_ref[...] = acc.astype(o_ref.dtype)"
        ".reshape(ohh, oww, ocb).astype(o_ref.dtype)")
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=True,
                           sources=sources)
    assert "K103" in _rules(f)


# -- K104: chain intermediate-padding masks ---------------------------------

_CHAIN_MASK = "band = jnp.where((rows >= 0) & (rows < oh_valid), out, 0.0)"


def test_k104_missing_mask():
    """Padded 2-stage chain: stage 0's halo rows reach above the frame
    (b0 < 0), so dropping the row mask lets stage 1 consume garbage."""
    sources = _mutate(_CHAIN_MASK, "band = out")
    f, _ = sanitize_chain((2, 28, 28, 8), [(3, 3, 8, 8), (3, 3, 8, 8)],
                          strides=[(1, 1), (1, 1)],
                          paddings=[(1, 1), (1, 1)],
                          relus=[True, True], oh_block=4,
                          sources=sources)
    assert "K104" in _rules(f)


def test_k104_mask_not_required_when_no_garbage():
    """Same mutation on an unpadded single-tile chain: no halo row can
    hold garbage, so the missing mask is provably harmless."""
    sources = _mutate(_CHAIN_MASK, "band = out")
    f, _ = sanitize_chain((2, 16, 16, 8), [(3, 3, 8, 8), (3, 3, 8, 8)],
                          strides=[(1, 1), (1, 1)],
                          paddings=[(0, 0), (0, 0)],
                          relus=[True, True], sources=sources)
    assert "K104" not in _rules(f)


# -- K105: cross-derivation disagreement ------------------------------------


def test_k105_geometry_disagreement():
    """Tampering one field of the sanitizer's geometry dict must surface
    as a K105 against the verifier's resolver-backed derivation."""
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "tools"))
    try:
        import sanitize as sanitize_cli
    finally:
        sys.path.pop(0)
    from repro.core.methods import Method
    from repro.core.netdefs import NETWORKS
    from repro.core.plan import compile_plan

    plan = compile_plan(NETWORKS["lenet5"](), method=Method.BASIC_SIMD,
                        fuse=False, use_pallas=True, verify=False)
    step = next(s for s in plan.steps if s.kind == "conv")
    _, geom = sanitize_cli.sanitize_step(plan, step, "step")
    assert sanitize_cli._cross_check(geom, plan, step, "step") == []
    geom = dict(geom, band=geom["band"] + 1)
    bad = sanitize_cli._cross_check(geom, plan, step, "step")
    assert [f.rule for f in bad] == ["K105"]


# -- K106: VMEM scratch carry discipline ------------------------------------


def test_k106_stale_carry_rows():
    """Storing the HEAD of the fresh band instead of its tail leaves the
    next band step consuming rows that are not the boundary rows — the
    carry-discipline proof must fire exactly K106."""
    sources = _mutate(
        "jax.lax.slice_in_dim(fresh, r_rows - k_rows, r_rows, axis=0)",
        "jax.lax.slice_in_dim(fresh, 0, k_rows, axis=0)")
    f, _ = _sanitize_carry(sources=sources)
    assert _rules(f) == {"K106"}


def test_k106_carry_axis_not_arbitrary():
    """The carried (band) grid axis must be 'arbitrary': a parallel axis
    gives the compiler licence to reorder band steps and the scratch
    hand-off breaks."""
    sources = _mutate(
        'dimension_semantics=("parallel", "parallel", "arbitrary")',
        'dimension_semantics=("parallel", "parallel", "parallel")')
    f, _ = _sanitize_carry(sources=sources)
    assert "K106" in _rules(f)


def test_k106_needs_a_carry_dispatch():
    """The classic (no-scratch) fused cell must never draw K106."""
    f, _ = sanitize_conv2d((2, 33, 21, 8), (3, 3, 8, 16), padding=(1, 1),
                           relu=True, im2col=True, oh_block=5,
                           pool_kernel=(3, 3), pool_stride=(2, 2),
                           pool_carry=False)
    assert f == []


# -- K101 on the channel-halo cell: oc-tile under-fetch ----------------------


def test_k101_halo_weight_underfetch():
    """Dropping the host-side halo widening of the weight matrix leaves
    the unblocked weight spec reading ``lrn_n - 1`` columns past the
    operand for the last oc tile — a spec-level K101 under-fetch."""
    sources = _mutate("wmat = jnp.pad(wmat, ((0, 0), (halo_lo, halo_hi)))",
                      "wmat = jnp.pad(wmat, ((0, 0), (0, 0)))")
    f, _ = _sanitize_halo(sources=sources)
    assert "K101" in _rules(f)


# -- Phase-A re-derivations track the trusted resolvers ----------------------


@pytest.mark.parametrize("pool_carry", [None, True, False])
@pytest.mark.parametrize("pool,phb,n_tiles", [
    ((3, 3, 2, 2), 5, 4), ((3, 3, 2, 2), 1, 2), ((2, 2, 2, 2), 4, 3),
    ((3, 3, 1, 1), 2, 5), ((5, 5, 2, 2), 1, 3), ((3, 3, 2, 2), 5, 1),
])
def test_phase_a_pool_carry_matches_resolver(pool_carry, pool, phb,
                                             n_tiles):
    """The sanitizer's from-scratch carry gate must agree with the
    trusted kernel resolver over the whole config space (the K105
    N-version contract, checked directly)."""
    from repro.kernels.conv2d import kernels as K

    for im2col in (True, False):
        for lrn in (None, (5, 2e-2, 0.75, 2.0)):
            assert sanitizer._a_resolve_pool_carry(
                pool_carry, im2col, lrn, pool, phb, n_tiles) \
                == K.resolve_pool_carry(pool_carry, im2col, lrn, pool,
                                        phb, n_tiles)


@pytest.mark.parametrize("lrn_oc_block", [None, True, False])
@pytest.mark.parametrize("oc,oc_block", [
    (96, 8), (96, 128), (16, 4), (8, 8), (2048, 8), (7, 4),
])
def test_phase_a_lrn_ocb_matches_resolver(lrn_oc_block, oc, oc_block):
    from repro.kernels.conv2d import kernels as K

    pool = (3, 3, 2, 2)
    for lrn in (None, (5, 2e-2, 0.75, 2.0), (4, 2e-2, 0.75, 2.0)):
        for ow, wp, c in ((54, 58, 8), (13, 17, 2048)):
            args = (oc, oc_block, lrn, lrn_oc_block, ow, wp, c, 5, 5, 1,
                    pool)
            assert sanitizer._a_resolve_lrn_ocb(*args) \
                == K.resolve_lrn_ocb(*args)


# -- K100: unproven dispatches fail loudly ----------------------------------


def test_k100_unsupported_construct():
    sources = _mutate("patches = jnp.concatenate(cols, axis=-1)",
                      "patches = jnp.stack(cols)")
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=True,
                           sources=sources)
    assert _rules(f) == {"K100"}


def test_k100_entry_raise():
    f, geom = sanitize_conv2d((2, 24, 24, 8), (3, 3, 8, 32),
                              padding=(1, 1),
                              lrn=(5, 2.0, 1e-4, 0.75), im2col=True)
    assert _rules(f) == {"K100"}  # LRN without pool: the entry's raise


# -- independence: no trusted-resolver imports ------------------------------


def test_sanitizer_import_independence():
    """The sanitizer must derive every band interval itself: its module
    may import ONLY the stdlib and the findings taxonomy — never the
    kernel modules, fusion planner, or verifier it cross-checks."""
    tree = ast.parse(Path(sanitizer.__file__).read_text())
    imported = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            imported += [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom):
            imported.append(node.module or "")
    assert "repro.analysis.findings" in imported
    for mod in imported:
        assert not mod.startswith(("repro.kernels", "repro.core")), mod
        assert "verifier" not in mod and "fusion" not in mod, mod
    # and the trusted resolvers specifically must not be reachable
    banned = ("group_band_params", "band_intervals", "resolve_oh_block",
              "step_band_params")
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                assert a.name not in banned, a.name


def test_mutations_are_rule_precise():
    """A seeded K101 must not drag in unrelated K102/K103 noise (the
    interpreter clamps and continues after a violation)."""
    sources = _mutate("lambda i, t, o: (i, t * row_step, 0, 0)",
                      "lambda i, t, o: (i, t * row_step + 1, 0, 0)")
    f, _ = sanitize_conv2d((2, 28, 28, 8), (5, 5, 8, 16), im2col=True,
                           sources=sources)
    assert _rules(f) == {"K101"}


if __name__ == "__main__":
    import sys

    sys.exit(pytest.main([__file__, "-q"]))
