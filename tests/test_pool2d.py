"""Pallas pool2d kernels vs the reduce_window reference (interpret mode):
max/avg × stride/kernel combos, explicit/ragged/auto oh-bands, ReLU
epilogue, and the NCHW ops wrapper's channel padding."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.pool2d.kernels import auto_oh_block_pool
from repro.kernels.pool2d.ops import pool2d
from repro.kernels.pool2d.ref import pool2d_ref


def _x(n, c, h, w, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, c, h, w),
                             jnp.float32)


@pytest.mark.parametrize("kind", ["max", "avg"])
@pytest.mark.parametrize("kernel,stride", [((2, 2), (2, 2)),
                                           ((3, 3), (2, 2)),
                                           ((3, 2), (1, 2))])
def test_pool2d_matches_reference(kind, kernel, stride):
    x = _x(2, 5, 17, 13)  # 5 channels: exercises the sublane padding
    ref = pool2d_ref(x, kernel, stride, kind)
    out = pool2d(x, kernel, stride, kind, interpret=True)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


@pytest.mark.parametrize("kind", ["max", "avg"])
@pytest.mark.parametrize("oh_block", [1, 2, 3, 64])
def test_pool2d_oh_bands(kind, oh_block):
    """Every band size — ragged last tiles included — matches the untiled
    reference; band offsets are stride-aware."""
    x = _x(1, 6, 23, 11)
    ref = pool2d_ref(x, (3, 3), (2, 2), kind, relu=True)
    out = pool2d(x, (3, 3), (2, 2), kind, relu=True, oh_block=oh_block,
                 interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_pool2d_negative_inputs_max():
    """Max pooling must not leak the zero channel padding or the -inf
    accumulator init into all-negative inputs."""
    x = -jnp.abs(_x(1, 3, 8, 8)) - 1.0
    ref = pool2d_ref(x, (2, 2), (2, 2), "max")
    out = pool2d(x, (2, 2), (2, 2), "max", interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-6
    assert bool(jnp.all(out < 0))


def test_auto_oh_block_pool_respects_budget():
    # tiny budget forces single-row bands; big budget takes the whole frame
    assert auto_oh_block_pool(64, 64, 64, 8, 3, 2, budget=4096) == 1
    assert auto_oh_block_pool(64, 64, 64, 8, 3, 2,
                              budget=1 << 30) == 64


def test_pool2d_rejects_oversized_window():
    with pytest.raises(ValueError, match="larger than"):
        pool2d(_x(1, 3, 4, 4), (5, 5), (2, 2), "max", interpret=True)
