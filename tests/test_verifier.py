"""Static plan verifier: clean sweep + mutation detection.

The mutation tests seed each known-bad-geometry class the verifier
exists to catch — off-by-one halo, gapped/overlapping output bands, a
budget-busting chain the planner wrongly admitted, an un-equalized
ragged band (the PR 3 over-fetch regression) — and assert the RIGHT
rule ID fires.  Geometry defects are injected by monkeypatching the
kernel geometry helpers the resolvers run through, so the whole
re-derivation path (fusion.group_band_params → kernels.band_intervals)
is exercised, not just the pure checker.
"""
import dataclasses

import pytest

from repro.analysis.findings import Finding, PlanVerificationError, RULES
from repro.analysis.verifier import check_band_coverage, verify_plan
from repro.core.methods import Method
from repro.core.netdefs import LayerSpec, NetworkDef, NETWORKS
from repro.core.plan import compile_plan
import repro.core.fusion as fusion_mod
import repro.kernels.conv2d.kernels as K

METHODS = (Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8)


def rules_of(findings):
    return {f.rule for f in findings}


# -- clean sweep ------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(NETWORKS))
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_bundled_nets_verify_clean(name, method, fuse, use_pallas):
    net = NETWORKS[name]()
    plan = compile_plan(net, method=method, fuse=fuse,
                        use_pallas=use_pallas, verify=False)
    assert verify_plan(plan) == []


def test_compile_plan_verifies_by_default(monkeypatch):
    """compile_plan(verify=True) is the default and raises on errors."""
    calls = []
    import repro.analysis.verifier as verifier_mod

    real = verifier_mod.verify_plan
    monkeypatch.setattr(verifier_mod, "verify_plan",
                        lambda p: calls.append(1) or real(p))
    compile_plan(NETWORKS["lenet5"]())
    assert calls  # the verifier ran without being asked for


# -- pure coverage checker (hand-built geometries) --------------------------

def _geo(**over):
    """A consistent fused-style geometry: blk=4 pooled rows × 3 bands over
    total=12, effective stride 2 / window 3."""
    base = dict(kind="fused", blk=4, n_tiles=3, total=12, band=9,
                row_step=8, in_base=0, stride_eff=2, window_eff=3,
                padded_h=26, cell_bytes=0, floor_bytes=0, budget=1,
                out_hw=[12, 12])
    base.update(over)
    return base


def test_checker_accepts_consistent_geometry():
    assert check_band_coverage(_geo(), "t") == []


def test_checker_flags_gapped_bands():
    # one band too few: rows [8, 12) are never produced
    findings = check_band_coverage(_geo(n_tiles=2), "t", equalized=False)
    assert rules_of(findings) == {"V201"}


def test_checker_flags_surplus_bands_as_unequalized():
    # one band too many: partition still closes (empty last band) but the
    # fair-share invariant is broken — the over-fetch detector fires
    findings = check_band_coverage(_geo(n_tiles=4), "t")
    assert "V204" in rules_of(findings)


def test_checker_flags_shrunk_halo():
    # band one row short of (blk-1)*stride + window: scalar inconsistency
    # AND the per-band window containment both fire
    findings = check_band_coverage(_geo(band=8), "t")
    assert {"V203", "V205"} <= rules_of(findings)


def test_checker_flags_drifting_row_step():
    # row_step != blk*stride: later bands start short of what their
    # output rows read
    findings = check_band_coverage(_geo(row_step=7), "t")
    assert {"V203", "V205"} <= rules_of(findings)


def test_checker_flags_band_above_frame(monkeypatch):
    # an input interval starting above the pre-padded origin
    real = K.band_intervals

    def shifted(n_tiles, blk, total, row_step, band, base=0):
        out_iv, in_iv = real(n_tiles, blk, total, row_step, band, base=base)
        in_iv = [(s - 1, r) for s, r in in_iv]
        return out_iv, in_iv

    monkeypatch.setattr(K, "band_intervals", shifted)
    findings = check_band_coverage(_geo(), "t")
    assert "V202" in rules_of(findings)


def test_checker_flags_overlapping_bands(monkeypatch):
    real = K.band_intervals

    def overlapping(n_tiles, blk, total, row_step, band, base=0):
        out_iv, in_iv = real(n_tiles, blk, total, row_step, band, base=base)
        out_iv = [(max(0, s - 1), r) for s, r in out_iv]  # bands collide
        return out_iv, in_iv

    monkeypatch.setattr(K, "band_intervals", overlapping)
    findings = check_band_coverage(_geo(), "t")
    assert "V201" in rules_of(findings)


# -- end-to-end mutations through compiled plans ----------------------------

def _pool_net(h=56):
    """conv(SAME, k5) → oh 56 → pool 3/2 → ph 27: the PR 3 regression
    vector (27 does not divide evenly into 23-row-derived bands)."""
    return NetworkDef("t", (3, h, h), 4, (
        LayerSpec("conv", "c1", out_channels=16, kernel=(5, 5),
                  padding=(2, 2), relu=True),
        LayerSpec("pool", "p1", kernel=(3, 3), stride=(2, 2)),
    ))


def test_pr3_ragged_band_overfetch_regression(monkeypatch):
    """Un-equalized ragged pooled bands (the PR 3 _plan_pool_tiles bug):
    with band equalization knocked out, an explicit oh_block=23 over
    ph=27 resolves to 11-row bands whose last band is mostly pad —
    V204 must catch it statically."""
    def unequalized(blk, target):
        blk = max(1, min(blk, target))
        return blk, -(-target // blk)   # no fair-share re-snap

    monkeypatch.setattr(K, "_equalize_bands", unequalized)
    plan = compile_plan(_pool_net(), method=Method.ADVANCED_SIMD_8,
                        fuse=True, use_pallas=True, oh_block=23,
                        verify=False)
    assert [s.kind for s in plan.steps] == ["fused"]
    findings = verify_plan(plan)
    assert rules_of(findings) == {"V204"}
    # and the default compile path refuses the plan outright
    with pytest.raises(PlanVerificationError) as exc:
        compile_plan(_pool_net(), method=Method.ADVANCED_SIMD_8,
                     fuse=True, use_pallas=True, oh_block=23)
    assert "V204" in str(exc.value)


def test_unsnapped_pool_band_detected(monkeypatch):
    """A pool band resolver that ignores the pool-stride snap entirely
    (hands back the raw conv oh_block) breaks the fair-share invariant."""
    def unsnapped(ph, oh, ow, wp, c, kh, kw, sy, ocb, pool, oh_block,
                  im2col=True, oc_halo=0):
        ohb = max(1, min(oh_block, ph))
        return ohb, -(-ph // ohb)

    monkeypatch.setattr(K, "resolve_ph_block", unsnapped)
    plan = compile_plan(_pool_net(), method=Method.ADVANCED_SIMD_8,
                        fuse=True, use_pallas=True, oh_block=23,
                        verify=False)
    findings = verify_plan(plan)
    assert "V204" in rules_of(findings)


def test_off_by_one_halo_detected(monkeypatch):
    """Every halo band staged one input row short — the classic
    under-fetch that only corrupts the last output row of each band."""
    real = K.band_intervals

    def short_halo(n_tiles, blk, total, row_step, band, base=0):
        out_iv, in_iv = real(n_tiles, blk, total, row_step, band, base=base)
        return out_iv, [(s, r - 1) for s, r in in_iv]

    monkeypatch.setattr(K, "band_intervals", short_halo)
    plan = compile_plan(_pool_net(), method=Method.ADVANCED_SIMD_8,
                        fuse=True, use_pallas=True, verify=False)
    findings = verify_plan(plan)
    assert rules_of(findings) == {"V203"}


def _chain_net():
    """Two wide back-to-back convs whose chain cell cannot fit VMEM even
    at the one-row floor (resident weights alone ≈ 19 MB > 14 MB)."""
    return NetworkDef("t", (512, 16, 16), 4, (
        LayerSpec("conv", "c1", out_channels=512, kernel=(3, 3),
                  padding=(1, 1), relu=True),
        LayerSpec("conv", "c2", out_channels=512, kernel=(3, 3),
                  padding=(1, 1), relu=True),
    ))


def test_budget_busting_chain_detected(monkeypatch):
    """A fusion planner that stops checking VMEM admits a chain whose
    floor cell busts the budget — the verifier audits it back out."""
    monkeypatch.setattr(fusion_mod, "_fits_vmem",
                        lambda *a, **k: True)
    plan = compile_plan(_chain_net(), method=Method.ADVANCED_SIMD_8,
                        fuse=True, use_pallas=True, verify=False)
    assert [s.kind for s in plan.steps] == ["chain"]
    findings = verify_plan(plan)
    assert {"V302", "V303"} <= rules_of(findings)
    assert all(f.severity == "error" for f in findings)
    with pytest.raises(PlanVerificationError):
        compile_plan(_chain_net(), method=Method.ADVANCED_SIMD_8,
                     fuse=True, use_pallas=True)


def test_budget_findings_downgrade_off_pallas(monkeypatch):
    """The same busted chain on the XLA path is advisory only: there is
    no VMEM ceiling to violate, so compile does NOT raise."""
    monkeypatch.setattr(fusion_mod, "_fits_vmem",
                        lambda *a, **k: True)
    plan = compile_plan(_chain_net(), method=Method.ADVANCED_SIMD_8,
                        fuse=True, use_pallas=False)  # verify=True: no raise
    findings = verify_plan(plan)
    assert {"V302", "V303"} <= rules_of(findings)
    assert all(f.severity == "info" for f in findings)


def test_shape_corruption_detected():
    """A step whose recorded shapes disagree with the layer math: V101 on
    the corrupt step, V102 where the chain breaks downstream."""
    plan = compile_plan(_pool_net(), method=Method.SEQ_REF, fuse=False,
                        verify=False)
    step0 = plan.steps[0]
    bad = dataclasses.replace(step0, out_shape=(step0.out_shape[0],
                                                step0.out_shape[1] + 1,
                                                step0.out_shape[2]))
    plan = dataclasses.replace(plan, steps=(bad,) + plan.steps[1:])
    findings = verify_plan(plan)
    assert {"V101", "V102"} <= rules_of(findings)


def test_param_shape_mismatch_detected():
    """Verifying a plan against an independently-trusted NetworkDef with
    different channel counts: the parameter-geometry cross-check fires."""
    plan = compile_plan(_pool_net(), method=Method.SEQ_REF, fuse=False,
                        verify=False)
    other = NetworkDef("t", (3, 56, 56), 4, (
        LayerSpec("conv", "c1", out_channels=32, kernel=(5, 5),
                  padding=(2, 2), relu=True),
        LayerSpec("pool", "p1", kernel=(3, 3), stride=(2, 2)),
    ))
    findings = verify_plan(plan, net=other)
    assert "V103" in rules_of(findings)


def test_findings_are_structured():
    f = Finding("error", "step0:c1", "V201", "gap")
    assert f.rule in RULES and "V201" in str(f)
    with pytest.raises(ValueError):
        Finding("fatal", "s", "V201", "bad severity")
    with pytest.raises(ValueError):
        Finding("error", "s", "V999", "unknown rule")


def test_engine_verify_convenience():
    from repro.core.engine import CNNEngine

    eng = CNNEngine(NETWORKS["lenet5"](), method=Method.ADVANCED_SIMD_4)
    assert eng.verify() == []


def test_deploy_detects_manifest_geometry_tamper(tmp_path):
    """A manifest whose layer table was edited (conv kernel 5→3) no
    longer sizes the shipped tensors — load must fail, not run."""
    import json

    import jax

    from repro.core.deploy import load_model, save_model
    from repro.core.engine import CNNEngine

    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=Method.SEQ_REF)
    params = eng.init(jax.random.PRNGKey(0))
    save_model(tmp_path / "m", net, params)
    load_model(tmp_path / "m")  # intact artifact loads
    manifest = json.loads((tmp_path / "m" / "manifest.json").read_text())
    conv = next(l for l in manifest["network"]["layers"]
                if l["kind"] == "conv")
    conv["kernel"] = [3, 3]
    (tmp_path / "m" / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="geometry"):
        load_model(tmp_path / "m")
