"""Serving-engine sampling semantics: per-request temperature and
per-slot/per-step PRNG key usage."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.config import get_arch
from repro.models.registry import get_model
from repro.serving import engine as serving_engine
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_arch("gemma2-2b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _run(model, params, reqs, seed=0):
    eng = ServingEngine(model, params, max_batch=2, max_len=64, seed=seed)
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    return eng.run_until_drained()


def test_oversized_prompt_rejected(model_and_params):
    """A prompt with len >= max_len would overflow the slot's KV rows at
    prefill (and _decode_step would then write past max_len): the engine
    must reject it up front.  len == max_len - 1 is the last admissible
    size (one row left for the first decode step)."""
    model, params = model_and_params
    eng = ServingEngine(model, params, max_batch=2, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, list(range(8)), max_new_tokens=1))
    with pytest.raises(ValueError, match="max_len"):
        eng._prefill_into_slot(0, Request(1, list(range(9)),
                                          max_new_tokens=1))
    # boundary: max_len - 1 tokens still admits (and finishes) cleanly
    eng.submit(Request(2, list(range(7)), max_new_tokens=1))
    done = eng.run_until_drained()
    assert 2 in done and len(done[2]) >= 1


def test_temperature_zero_is_deterministic(model_and_params):
    """Greedy requests must not depend on the engine's PRNG seed."""
    model, params = model_and_params
    reqs = [Request(0, [3, 1, 4], max_new_tokens=5, temperature=0.0)]
    a = _run(model, params, reqs, seed=0)
    b = _run(model, params, reqs, seed=123)
    assert a == b


def test_temperature_used_and_keys_distinct(model_and_params, monkeypatch):
    """Sampling must use each request's own temperature, and every sampled
    step must consume a fresh key (no key shared across slots or steps)."""
    model, params = model_and_params
    calls = []
    real_sample = serving_engine.sample

    def spy(logits, key, temperature=0.0, top_k=0):
        calls.append((tuple(np.asarray(key).ravel().tolist()), temperature))
        return real_sample(logits, key, temperature=temperature, top_k=top_k)

    monkeypatch.setattr(serving_engine, "sample", spy)
    reqs = [
        Request(0, [3, 1, 4], max_new_tokens=4, temperature=0.7),
        Request(1, [2, 7, 1], max_new_tokens=4, temperature=1.3),
    ]
    done = _run(model, params, reqs)
    assert sorted(done) == [0, 1]
    # each request's actual temperature reached the sampler
    temps_seen = {t for _, t in calls}
    assert temps_seen == {0.7, 1.3}
    # every sampling call consumed a distinct key
    keys_seen = [k for k, _ in calls]
    assert len(keys_seen) == len(set(keys_seen))
    # both requests sampled every generated token (prefill + 3 decode steps)
    assert len(calls) == 8


def test_greedy_request_never_samples(model_and_params, monkeypatch):
    model, params = model_and_params

    def boom(*a, **kw):  # pragma: no cover - failure path
        raise AssertionError("greedy request must not hit the sampler")

    monkeypatch.setattr(serving_engine, "sample", boom)
    done = _run(model, params,
                [Request(0, [1, 2, 3], max_new_tokens=4, temperature=0.0)])
    assert len(done[0]) == 4
