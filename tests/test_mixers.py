"""Sequence-mixer correctness: SSD (Mamba2), WKV6 (RWKV), MoE dispatch —
chunked/parallel forms vs per-step or dense oracles, including streaming
decode equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.config import ModelConfig, MoEConfig, SSMConfig, RWKVConfig
from repro.nn.ssm import _ssd_chunked, ssd_reference, ssm_spec, ssm_apply
from repro.nn.rwkv import _wkv6_chunked, wkv6_reference
from repro.nn.moe import moe_spec, moe_apply, moe_reference
from repro.nn.param import init_tree


def test_ssd_chunked_matches_recurrence():
    b, s, h, p, n = 2, 50, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y1, S1 = _ssd_chunked(x, dt, A, B, C, chunk=16)
    y2, S2 = ssd_reference(x, dt, A, B, C)
    assert jnp.max(jnp.abs(y1 - y2)) < 1e-4
    assert jnp.max(jnp.abs(S1 - S2)) < 1e-4


def test_ssm_streaming_decode_matches_full():
    """Prefill then per-token decode == one full forward (conv + SSD state
    handoff)."""
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=0, num_kv_heads=0, d_ff=64, vocab_size=64,
                      head_dim=8, dtype="float32", param_dtype="float32",
                      ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                                    chunk_size=8))
    params = init_tree(ssm_spec(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    y_full, _ = ssm_apply(params, x, cfg, mode="full")
    d_inner = cfg.ssm.expand * cfg.d_model
    h = d_inner // cfg.ssm.head_dim
    cache = {"conv": jnp.zeros((2, cfg.ssm.d_conv - 1, d_inner + 2 * cfg.ssm.d_state)),
             "state": jnp.zeros((2, h, cfg.ssm.head_dim, cfg.ssm.d_state))}
    y_pre, cache = ssm_apply(params, x[:, :6], cfg, mode="full", cache=cache)
    assert jnp.max(jnp.abs(y_pre - y_full[:, :6])) < 1e-4
    for t in range(6, 12):
        y_t, cache = ssm_apply(params, x[:, t:t+1], cfg, mode="decode",
                               cache=cache)
        assert jnp.max(jnp.abs(y_t[:, 0] - y_full[:, t])) < 1e-4, t


def test_wkv6_chunked_matches_recurrence():
    b, s, h, e = 2, 50, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, s, h, e))
    k = jax.random.normal(ks[1], (b, s, h, e))
    v = jax.random.normal(ks[2], (b, s, h, e))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e)) * 0.5)
    u = jax.random.normal(ks[4], (h, e))
    o1, S1 = _wkv6_chunked(r, k, v, logw, u, chunk=16)
    o2, S2 = wkv6_reference(r, k, v, logw, u)
    assert jnp.max(jnp.abs(o1 - o2)) < 1e-4
    assert jnp.max(jnp.abs(S1 - S2)) < 1e-4


def test_wkv6_chunked_state_handoff():
    """Chunked processing with a carried-in state equals one long pass."""
    b, s, h, e = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (b, s, h, e))
    k = jax.random.normal(ks[1], (b, s, h, e))
    v = jax.random.normal(ks[2], (b, s, h, e))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, e)) * 0.5)
    u = jax.random.normal(ks[4], (h, e))
    o_full, S_full = _wkv6_chunked(r, k, v, logw, u, chunk=8)
    o1, S_mid = _wkv6_chunked(r[:, :16], k[:, :16], v[:, :16], logw[:, :16],
                              u, chunk=8)
    o2, S_end = _wkv6_chunked(r[:, 16:], k[:, 16:], v[:, 16:], logw[:, 16:],
                              u, chunk=8, state=S_mid)
    assert jnp.max(jnp.abs(jnp.concatenate([o1, o2], 1) - o_full)) < 1e-4
    assert jnp.max(jnp.abs(S_end - S_full)) < 1e-4


@pytest.fixture(scope="module")
def moe_cfg():
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=128, dtype="float32",
        param_dtype="float32",
        moe=MoEConfig(num_experts=4, num_experts_per_token=2, d_ff_expert=16,
                      capacity_factor=8.0, eval_capacity_factor=8.0))


def test_moe_matches_dense_reference(moe_cfg):
    params = init_tree(moe_spec(moe_cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    ref = moe_reference(params, x, moe_cfg)
    for dp in (1, 2, 4):
        out, aux = moe_apply(params, x, moe_cfg, dp_size=dp, mode="prefill")
        assert jnp.max(jnp.abs(out - ref)) < 1e-5, dp
    out, _ = moe_apply(params, x, moe_cfg, dp_size=3, mode="decode")
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_moe_capacity_drops_are_bounded(moe_cfg):
    """With cf=0.5 at most half the assignments survive; output must stay
    finite and the load-balance loss well-defined."""
    cfg = dataclasses.replace(
        moe_cfg, moe=dataclasses.replace(moe_cfg.moe, capacity_factor=0.5))
    params = init_tree(moe_spec(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_apply(params, x, cfg, dp_size=1, mode="train")
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux["load_balance_loss"]) > 0


def test_moe_grads_flow(moe_cfg):
    params = init_tree(moe_spec(moe_cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))

    def loss(p):
        out, aux = moe_apply(p, x, moe_cfg, dp_size=1, mode="train")
        return jnp.sum(out ** 2) + aux["load_balance_loss"]

    g = jax.grad(loss)(params)
    gr = g["router"]
    assert bool(jnp.any(gr != 0)), "router must receive gradient"
    assert all(bool(jnp.all(jnp.isfinite(v)))
               for v in jax.tree_util.tree_leaves(g))
