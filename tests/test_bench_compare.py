"""Unit tests for the CI bench-trend gate (``tools/bench_compare.py``)."""
import importlib.util
import json
import pathlib

import pytest

_TOOL = pathlib.Path(__file__).resolve().parent.parent / "tools" / \
    "bench_compare.py"
_spec = importlib.util.spec_from_file_location("bench_compare", _TOOL)
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)


def _bench(rows_by_net):
    """{'net': {'method': {'unfused': us, 'fused': us}}} -> bench JSON."""
    return {
        "bench": "network_ladder",
        "networks": {
            net: {"rows": [
                {"method": m,
                 **{variant: {"us_per_call": us, "fps": 1.0}
                    for variant, us in variants.items()}}
                for m, variants in methods.items()
            ]}
            for net, methods in rows_by_net.items()
        },
    }


PREV = _bench({
    "lenet5": {"basic_simd": {"unfused": 1000.0, "fused": 800.0}},
    "cifar10": {"advanced_simd_8": {"unfused": 5000.0}},
})


def _by_key(rows):
    return {(r["network"], r["method"], r["variant"]): r for r in rows}


def test_regression_detected():
    cur = _bench({
        "lenet5": {"basic_simd": {"unfused": 1000.0, "fused": 1100.0}},
        "cifar10": {"advanced_simd_8": {"unfused": 5000.0}},
    })
    rows = bench_compare.compare(bench_compare.flatten(PREV),
                                 bench_compare.flatten(cur),
                                 max_regress_pct=25.0)
    by = _by_key(rows)
    assert by[("lenet5", "basic_simd", "fused")]["status"] == "regressed"
    assert by[("lenet5", "basic_simd", "fused")]["delta_pct"] == \
        pytest.approx(37.5)
    assert by[("lenet5", "basic_simd", "unfused")]["status"] == "ok"
    assert by[("cifar10", "advanced_simd_8", "unfused")]["status"] == "ok"


def test_within_tolerance_and_speedup_are_ok():
    cur = _bench({
        "lenet5": {"basic_simd": {"unfused": 1200.0,   # +20% < 25%
                                  "fused": 400.0}},    # faster
        "cifar10": {"advanced_simd_8": {"unfused": 5000.0}},
    })
    rows = bench_compare.compare(bench_compare.flatten(PREV),
                                 bench_compare.flatten(cur), 25.0)
    assert all(r["status"] == "ok" for r in rows)


def test_new_and_removed_rows_never_gate():
    cur = _bench({
        "lenet5": {"basic_simd": {"unfused": 1000.0, "fused": 800.0}},
        "alexnet": {"advanced_simd_8": {"unfused": 9000.0,
                                        "fused": 7000.0}},
    })
    rows = bench_compare.compare(bench_compare.flatten(PREV),
                                 bench_compare.flatten(cur), 25.0)
    by = _by_key(rows)
    assert by[("alexnet", "advanced_simd_8", "fused")]["status"] == "new"
    assert by[("cifar10", "advanced_simd_8", "unfused")]["status"] == \
        "removed"
    assert not any(r["status"] == "regressed" for r in rows)


def test_main_exit_codes_and_table(tmp_path, capsys):
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text(json.dumps(PREV))
    cur_p.write_text(json.dumps(_bench({
        "lenet5": {"basic_simd": {"unfused": 2000.0, "fused": 800.0}},
        "cifar10": {"advanced_simd_8": {"unfused": 5000.0}},
    })))
    # warn-only (PR mode): regression reported, exit 0
    assert bench_compare.main([str(prev_p), str(cur_p)]) == 0
    out = capsys.readouterr().out
    assert "| lenet5 | basic_simd | unfused |" in out
    assert "regressed" in out and "+100.0%" in out
    # gate mode (main): same comparison exits 1
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 1
    # wider tolerance passes the gate
    assert bench_compare.main([str(prev_p), str(cur_p), "--fail-on-regress",
                               "--max-regress-pct", "150"]) == 0


def test_group_changes_and_geometry_reported_not_gated(tmp_path, capsys):
    """A re-planned fused-group composition (e.g. a conv run newly fused
    as a chain) and the executed chain geometry are carried into the
    report, but never gate; files without the new fields stay
    renderable."""
    prev = _bench({
        "alexnet": {"advanced_simd_8": {"unfused": 9000.0, "fused": 8000.0}},
    })
    prev["networks"]["alexnet"]["rows"][0]["fused_groups"] = ["conv5+pool5"]
    cur = _bench({
        "alexnet": {"advanced_simd_8": {"unfused": 9000.0, "fused": 7000.0}},
    })
    cur["networks"]["alexnet"]["rows"][0]["fused_groups"] = [
        "conv3+conv4+conv5+pool5"]
    cur["networks"]["alexnet"]["rows"][0]["fused_geometry"] = [
        {"group": "conv3+conv4+conv5+pool5", "convs": 3,
         "rows_per_cell": 2, "n_tiles": 3, "out_hw": [6, 6]},
    ]
    changes = bench_compare.group_changes(prev, cur)
    assert changes == ["- `alexnet/advanced_simd_8` fused groups: "
                       "conv5+pool5 → conv3+conv4+conv5+pool5"]
    geo = bench_compare.render_geometry(cur)
    assert "conv3+conv4+conv5+pool5" in geo and "2 × 3" in geo
    # an old-format file (no fused_geometry) renders to nothing, silently
    assert bench_compare.render_geometry(prev) == ""
    # end-to-end: the change is reported and the gate still passes
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text(json.dumps(prev))
    cur_p.write_text(json.dumps(cur))
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 0
    out = capsys.readouterr().out
    assert "Fused-group composition changes" in out
    assert "Executed fusion geometry" in out


def _with_serving(bench, net, rows, config=None):
    bench = json.loads(json.dumps(bench))  # deep copy
    bench["networks"].setdefault(net, {"rows": []})["serving"] = [
        {"batch": b, "throughput_rps": 100.0, "p50_us": p50,
         "p95_us": p50 * 1.2, "mean_batch": float(b)} for b, p50 in rows]
    bench["serving_config"] = config or {"batches": [b for b, _ in rows],
                                         "requests": 16}
    return bench


def test_serving_rows_flattened_and_gated():
    """CNNServer rows ride the same trend machinery: p50 per max_batch,
    flattened under method 'cnn_server'."""
    prev = _with_serving(PREV, "lenet5", [(1, 1000.0), (8, 4000.0)])
    cur = _with_serving(PREV, "lenet5", [(1, 1000.0), (8, 6000.0)])
    flat = bench_compare.flatten(cur)
    assert flat[("lenet5", "cnn_server", "batch8")] == 6000.0
    rows = bench_compare.compare(bench_compare.flatten(prev), flat, 25.0)
    by = _by_key(rows)
    assert by[("lenet5", "cnn_server", "batch1")]["status"] == "ok"
    assert by[("lenet5", "cnn_server", "batch8")]["status"] == "regressed"


def test_serving_config_change_resets_only_serving(tmp_path, capsys):
    """A different serving sweep (requests/batches) resets the serving
    baseline (rows 'new') while the ladder rows still compare — and an
    old-format prev file (no serving rows at all) never gates."""
    prev = _with_serving(PREV, "lenet5", [(8, 4000.0)],
                         config={"batches": [8], "requests": 16})
    cur = _with_serving(PREV, "lenet5", [(8, 9999.0)],
                        config={"batches": [8], "requests": 64})
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text(json.dumps(prev))
    cur_p.write_text(json.dumps(cur))
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 0
    out = capsys.readouterr().out
    assert "serving config changed" in out
    assert "| lenet5 | cnn_server | batch8 |" in out and "🆕 new" in out
    # old-format prev (pre-serving artifact): rows are new, gate passes
    prev_p.write_text(json.dumps(PREV))
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 0


def test_config_change_resets_baseline(tmp_path, capsys):
    """Different batch/iters/backend make us_per_call incomparable: the
    baseline resets (all rows 'new') instead of gating apples-to-oranges."""
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text(json.dumps({**PREV, "batch": 8}))
    slower = _bench({"lenet5": {"basic_simd": {"unfused": 9000.0,
                                               "fused": 9000.0}}})
    cur_p.write_text(json.dumps({**slower, "batch": 16}))
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 0
    out = capsys.readouterr().out
    assert "bench config changed" in out and "batch: 8 → 16" in out
    assert "regressed" not in out and "🆕 new" in out


def test_zero_or_absent_p50_serving_rows_are_skipped():
    """Shed-everything overload rows (or fake-clock runs) carry p50 0.0
    or no p50 at all — flatten must skip them, and a zero baseline must
    never be divided by."""
    cur = json.loads(json.dumps(PREV))
    cur["networks"]["lenet5"]["serving"] = [
        {"batch": 8, "throughput_rps": 50.0, "p50_us": 4000.0,
         "p95_us": 5000.0, "mean_batch": 8.0},
        {"batch": 8, "mode": "degraded", "throughput_rps": 0.0,
         "p50_us": 0.0, "p95_us": 0.0, "mean_batch": 0.0},   # all shed
        {"batch": 16, "throughput_rps": 10.0},               # no p50 key
    ]
    flat = bench_compare.flatten(cur)
    assert ("lenet5", "cnn_server", "batch8") in flat
    assert ("lenet5", "cnn_server", "batch8-degraded") not in flat
    assert ("lenet5", "cnn_server", "batch16") not in flat
    # a zero prev value reaching compare() reports "new", never divides
    rows = bench_compare.compare(
        {("lenet5", "cnn_server", "batch8"): 0.0},
        {("lenet5", "cnn_server", "batch8"): 4000.0}, 25.0)
    assert rows[0]["status"] == "new" and rows[0]["delta_pct"] is None


def test_degraded_mode_serving_rows_get_own_variant():
    """Overload rows flatten as 'batchN-degraded' — trended separately
    from the normal-mode row at the same max_batch."""
    def mk(normal_p50, degraded_p50):
        b = json.loads(json.dumps(PREV))
        b["networks"]["lenet5"]["serving"] = [
            {"batch": 8, "p50_us": normal_p50, "throughput_rps": 100.0},
            {"batch": 8, "mode": "degraded", "p50_us": degraded_p50,
             "throughput_rps": 20.0, "shed": 48, "degraded": 1},
        ]
        b["serving_config"] = {"batches": [8], "requests": 16,
                               "overload": {"batch": 8, "requests": 64}}
        return b

    prev, cur = mk(4000.0, 8000.0), mk(4000.0, 12000.0)
    by = _by_key(bench_compare.compare(bench_compare.flatten(prev),
                                       bench_compare.flatten(cur), 25.0))
    assert by[("lenet5", "cnn_server", "batch8")]["status"] == "ok"
    assert by[("lenet5", "cnn_server", "batch8-degraded")]["status"] == \
        "regressed"


def test_malformed_baseline_fails_gate_mode(tmp_path, capsys):
    """--fail-on-regress against an unreadable baseline must exit
    non-zero with a ::error:: verdict — a gate that cannot read its
    baseline has not passed."""
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text("{not json")
    cur_p.write_text(json.dumps(PREV))
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 2
    err = capsys.readouterr().err
    assert "::error::" in err and "baseline" in err


def test_malformed_baseline_resets_in_pr_mode(tmp_path, capsys):
    """Without the gate flag a bad baseline warns, resets, and every
    current row reports 'new' — the PR job stays green."""
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text("{not json")
    cur_p.write_text(json.dumps(PREV))
    assert bench_compare.main([str(prev_p), str(cur_p)]) == 0
    captured = capsys.readouterr()
    assert "::warning::" in captured.err
    assert "baseline reset" in captured.err
    rows = [line for line in captured.out.splitlines()
            if line.startswith("| lenet5") or line.startswith("| cifar10")]
    assert rows and all("new" in r for r in rows)


def test_missing_baseline_same_as_malformed(tmp_path):
    cur_p = tmp_path / "cur.json"
    cur_p.write_text(json.dumps(PREV))
    missing = tmp_path / "nope.json"
    assert bench_compare.main([str(missing), str(cur_p),
                               "--fail-on-regress"]) == 2
    assert bench_compare.main([str(missing), str(cur_p)]) == 0


def test_malformed_current_always_fails(tmp_path, capsys):
    """An unreadable CURRENT artifact is an error in any mode — the run
    under test produced no readable bench."""
    prev_p, cur_p = tmp_path / "prev.json", tmp_path / "cur.json"
    prev_p.write_text(json.dumps(PREV))
    cur_p.write_text("[")
    assert bench_compare.main([str(prev_p), str(cur_p)]) == 2
    assert "::error::" in capsys.readouterr().err
    assert bench_compare.main([str(prev_p), str(cur_p),
                               "--fail-on-regress"]) == 2
