"""Fusion subsystem tests: planner grouping/fallbacks, fused-kernel
correctness (interpret-mode Pallas), and whole-network fused-vs-unfused
equivalence across the three paper networks × methods."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import CNNEngine, _lrn
from repro.core.fusion import (
    FusedLayerSpec,
    fusion_summary,
    group_band_params,
    plan_fusion,
)
from repro.core.methods import Method, conv2d_pool_fused
from repro.core.netdefs import NETWORKS, LayerSpec, NetworkDef
from repro.kernels.conv2d.ops import conv2d as conv2d_pallas
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.pool2d.ref import pool2d_ref

SIMD = Method.ADVANCED_SIMD_8


# ---------------------------------------------------------------------------
# planner: groups formed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("net_name,expected", [
    ("lenet5", [("conv1", "pool1"), ("conv2", "pool2")]),
    ("cifar10", [("conv1", "pool1"), ("conv2", "pool2"),
                 ("conv3", "pool3")]),
    ("alexnet", [("conv1", "pool1", "norm1"), ("conv2", "pool2", "norm2"),
                 ("conv3", "conv4", "conv5", "pool5")]),
])
def test_planner_groups(net_name, expected):
    plan = plan_fusion(NETWORKS[net_name](), method_for=lambda n: SIMD)
    assert fusion_summary(plan) == expected


def test_planner_preserves_ungrouped_layers():
    net = NETWORKS["alexnet"]()
    plan = plan_fusion(net, method_for=lambda n: SIMD)
    kinds = [it.kind for it in plan]
    # conv3/conv4 join the conv5+pool5 group as a chain: no conv is left
    # on the per-layer ladder
    assert kinds.count("conv") == 0 and kinds.count("fused") == 3
    assert kinds.count("lrn") == 0  # both pool→norm tails absorbed
    # every original layer is accounted for exactly once
    covered = [n for it in plan
               for n in (it.names if isinstance(it, FusedLayerSpec)
                         else (it.name,))]
    assert covered == [l.name for l in net.layers]


def test_planner_absorbs_standalone_relu():
    net = NetworkDef("t", (3, 16, 16), 4, (
        LayerSpec("conv", "c", out_channels=4, kernel=(3, 3)),
        LayerSpec("relu", "r"),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
        LayerSpec("relu", "r2"),
    ))
    plan = plan_fusion(net, method_for=lambda n: SIMD)
    assert len(plan) == 1
    (g,) = plan
    assert g.names == ("c", "r", "p", "r2") and g.relu and g.pool_relu


def test_planner_fallbacks():
    net = NETWORKS["lenet5"]()
    # non-SIMD method: per-layer ladder kept
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: Method.BASIC_PARALLEL)) == []
    # per-layer opt-out (conv or pool name)
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        no_fuse={"conv1"})) == [("conv2", "pool2")]
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        no_fuse={"pool2"})) == [("conv1", "pool1")]
    # a standalone ReLU we may not fold blocks the group
    net_r = NetworkDef("t", (3, 16, 16), 4, (
        LayerSpec("conv", "c", out_channels=4, kernel=(3, 3)),
        LayerSpec("relu", "r"),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2)),
    ))
    assert fusion_summary(plan_fusion(
        net_r, method_for=lambda n: SIMD, fuse_relu=False)) == []


def test_planner_unsupported_shapes_fall_back():
    # unsupported pool kind
    net = NetworkDef("t", (3, 16, 16), 4, (
        LayerSpec("conv", "c", out_channels=4, kernel=(3, 3)),
        LayerSpec("pool", "p", kernel=(2, 2), stride=(2, 2),
                  pool_kind="stochastic"),
    ))
    assert fusion_summary(plan_fusion(net, method_for=lambda n: SIMD)) == []
    # pool window larger than the conv output (14x14 conv out, 15x15 pool)
    net2 = NetworkDef("t", (3, 16, 16), 4, (
        LayerSpec("conv", "c", out_channels=4, kernel=(3, 3)),
        LayerSpec("pool", "p", kernel=(15, 15), stride=(1, 1)),
    ))
    assert fusion_summary(plan_fusion(net2, method_for=lambda n: SIMD)) == []


def test_planner_lrn_opt_out_keeps_pool_fusion():
    plan = plan_fusion(NETWORKS["alexnet"](), method_for=lambda n: SIMD,
                       no_fuse={"norm1"})
    groups = fusion_summary(plan)
    # the opted-out LRN drops out of the group; conv1+pool1 still fuse
    assert ("conv1", "pool1") in groups
    assert ("conv2", "pool2", "norm2") in groups


def test_planner_declines_over_budget_shape():
    """The floor fused cell (ONE pool window of conv rows) of this shape
    stages an im2col matrix far past the soft VMEM budget — the planner
    must keep the pair un-fused instead of compiling a cell that can't
    fit."""
    net = NetworkDef("t", (512, 16, 2048), 4, (
        LayerSpec("conv", "c", out_channels=512, kernel=(3, 3),
                  padding=(1, 1), relu=True),
        LayerSpec("pool", "p", kernel=(3, 3), stride=(2, 2)),
    ))
    assert fusion_summary(plan_fusion(net, method_for=lambda n: SIMD)) == []
    # a generous budget override restores the group: the working-set
    # check (not any shape rule) is what declined
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        vmem_budget=1 << 40)) == [("c", "p")]
    # the XLA analogue has no VMEM ceiling: vmem_check=False (what the
    # engine passes for use_pallas=False) fuses the same shape
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        vmem_check=False)) == [("c", "p")]


def test_planner_keeps_lrn_tail_via_channel_halo_blocking():
    """The full-width oc tile the LRN epilogue needs busts the budget for
    a 4096-channel conv; the channel-halo cell oc-blocks the epilogue so
    the planner keeps the LRN tail it used to drop.  Only when even the
    blocked floor cell busts does the drop-LRN rung fire."""
    net = NetworkDef("t", (64, 16, 128), 4, (
        LayerSpec("conv", "c", out_channels=4096, kernel=(3, 3),
                  padding=(1, 1), relu=True),
        LayerSpec("pool", "p", kernel=(3, 3), stride=(2, 2)),
        LayerSpec("lrn", "n"),
    ))
    groups = plan_fusion(net, method_for=lambda n: SIMD)
    assert fusion_summary(groups) == [("c", "p", "n")]
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        vmem_budget=1 << 40)) == [("c", "p", "n")]
    # below the blocked floor the LRN tail still drops (old behaviour)
    geo = group_band_params(groups[0], SIMD, (64, 16, 128), None)
    assert fusion_summary(plan_fusion(
        net, method_for=lambda n: SIMD,
        vmem_budget=geo["floor_bytes"] - 1)) == [("c", "p")]


# ---------------------------------------------------------------------------
# fused Pallas kernels vs the per-layer reference (interpret mode)
# ---------------------------------------------------------------------------


def _case(n, c, h, w_, oc, k, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, c, h, w_),
                          jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (oc, c, k, k)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(seed + 2), (oc,))
    return x, w, b


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
@pytest.mark.parametrize("kind", ["max", "avg"])
@pytest.mark.parametrize("conv_stride,pad,pk,ps", [
    ((1, 1), (2, 2), (3, 3), (2, 2)),   # overlapping pool (paper nets)
    ((2, 2), (0, 0), (2, 2), (2, 2)),   # strided conv + disjoint pool
])
def test_fused_kernel_matches_per_layer(method, kind, conv_stride, pad,
                                        pk, ps):
    x, w, b = _case(2, 5, 20, 18, 7, 5)
    ref = pool2d_ref(conv2d_ref(x, w, b, conv_stride, pad, relu=True),
                     pk, ps, kind)
    out = conv2d_pallas(x, w, b, conv_stride, pad, relu=True, method=method,
                        interpret=True, pool_kernel=pk, pool_stride=ps,
                        pool_kind=kind)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
def test_fused_kernel_multi_tile(method):
    """Tiny oh_block forces multiple pooled bands per frame; the band
    snapping (conv rows per pooled row) and pool_relu epilogue hold."""
    x, w, b = _case(1, 4, 33, 21, 6, 3)
    ref = pool2d_ref(conv2d_ref(x, w, b, (1, 1), (1, 1), relu=False),
                     (3, 3), (2, 2), "max", relu=True)
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=False, method=method,
                        interpret=True, oh_block=5, pool_kernel=(3, 3),
                        pool_stride=(2, 2), pool_kind="max", pool_relu=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_fused_rejects_basic_parallel():
    x, w, b = _case(1, 3, 8, 8, 4, 3)
    with pytest.raises(ValueError, match="SIMD"):
        conv2d_pallas(x, w, b, method="basic_parallel", interpret=True,
                      pool_kernel=(2, 2), pool_stride=(2, 2))
    with pytest.raises(ValueError, match="SIMD"):
        conv2d_pool_fused(x, w, b, Method.SEQ_REF)


# ---------------------------------------------------------------------------
# fused LRN epilogue (conv→ReLU→pool→LRN in one cell)
# ---------------------------------------------------------------------------

_LRN = dict(lrn_alpha=2e-2, lrn_beta=0.75, lrn_k=2.0)


def _lrn_ref(x, lrn_n):
    return _lrn(x, LayerSpec("lrn", "n", lrn_n=lrn_n, **_LRN))


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
@pytest.mark.parametrize("lrn_n", [4, 5])  # even n: asymmetric padding
def test_fused_lrn_kernel_matches_per_layer(method, lrn_n):
    """conv→relu→pool→LRN in one Pallas cell vs the per-layer reference
    chain, including `engine._lrn`'s even-n asymmetric window padding."""
    x, w, b = _case(2, 5, 20, 18, 7, 5)
    ref = _lrn_ref(pool2d_ref(conv2d_ref(x, w, b, (1, 1), (2, 2), relu=True),
                              (3, 3), (2, 2), "max"), lrn_n)
    out = conv2d_pallas(x, w, b, (1, 1), (2, 2), relu=True, method=method,
                        interpret=True, pool_kernel=(3, 3),
                        pool_stride=(2, 2), pool_kind="max", lrn_n=lrn_n,
                        **_LRN)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ["basic_simd", "advanced_simd_128"])
def test_fused_lrn_multi_tile(method):
    """A tiny oh_block forces several pooled bands per frame; LRN is
    per-pooled-row so banding must not change it."""
    x, w, b = _case(1, 4, 33, 21, 6, 3)
    ref = _lrn_ref(pool2d_ref(conv2d_ref(x, w, b, (1, 1), (1, 1),
                                         relu=True), (3, 3), (2, 2), "max"),
                   5)
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=True, method=method,
                        interpret=True, oh_block=5, pool_kernel=(3, 3),
                        pool_stride=(2, 2), pool_kind="max", lrn_n=5, **_LRN)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_fused_lrn_requires_pool():
    x, w, b = _case(1, 3, 8, 8, 4, 3)
    with pytest.raises(ValueError, match="pool"):
        conv2d_pallas(x, w, b, method="advanced_simd_128", interpret=True,
                      lrn_n=5)
    with pytest.raises(ValueError, match="SIMD"):
        conv2d_pallas(x, w, b, method="basic_parallel", interpret=True,
                      lrn_n=5)


# ---------------------------------------------------------------------------
# second-generation cells: sliding-window pool carry + channel-halo LRN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["max", "avg"])
@pytest.mark.parametrize("conv_stride,pad", [((1, 1), (1, 1)),
                                             ((2, 2), (0, 0))])
def test_fused_carry_matches_per_layer(kind, conv_stride, pad):
    """The sliding-window pool accumulator: adjacent oh-bands share the
    pool-halo conv rows through VMEM scratch (one sacrificial prologue
    band seeds the carry) and must reproduce the classic fused cell."""
    from repro.kernels.conv2d import kernels as K

    x, w, b = _case(2, 4, 33, 21, 6, 3, seed=9)
    ref = pool2d_ref(conv2d_ref(x, w, b, conv_stride, pad, relu=True),
                     (3, 3), (2, 2), kind)
    # the gate must actually open for this geometry (overlapping pool,
    # several bands) — otherwise this test silently runs the classic cell
    oh = (33 + 2 * pad[0] - 3) // conv_stride[0] + 1
    ph = (oh - 3) // 2 + 1
    n_tiles = -(-ph // 5)
    assert K.resolve_pool_carry(True, True, None, (3, 3, 2, 2), 5, n_tiles)
    out = conv2d_pallas(x, w, b, conv_stride, pad, relu=True,
                        method="advanced_simd_128", interpret=True,
                        oh_block=5, pool_kernel=(3, 3), pool_stride=(2, 2),
                        pool_kind=kind, pool_carry=True)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_fused_carry_gate_declines_disjoint_pool():
    """A disjoint pool (stride == window) has no halo rows to carry: the
    resolver must decline even when the knob is forced on, and the output
    must still be exact."""
    from repro.kernels.conv2d import kernels as K

    assert not K.resolve_pool_carry(True, True, None, (2, 2, 2, 2), 4, 3)
    x, w, b = _case(1, 4, 32, 16, 6, 3, seed=2)
    ref = pool2d_ref(conv2d_ref(x, w, b, (1, 1), (1, 1), relu=True),
                     (2, 2), (2, 2), "max")
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=True,
                        method="advanced_simd_128", interpret=True,
                        oh_block=4, pool_kernel=(2, 2), pool_stride=(2, 2),
                        pool_carry=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("lrn_n", [4, 5])  # even n: asymmetric halo split
def test_fused_lrn_oc_block_matches_per_layer(lrn_n):
    """The two-pass channel-halo cell: oc-blocked grid with window-widened
    weight tiles, each tile normalizing its core channels against the
    halo — vs the full-width per-layer reference chain."""
    x, w, b = _case(2, 5, 20, 18, 7, 5)
    ref = _lrn_ref(pool2d_ref(conv2d_ref(x, w, b, (1, 1), (2, 2), relu=True),
                              (3, 3), (2, 2), "max"), lrn_n)
    # oc_block 4 < oc 7: genuinely blocked (2 oc tiles with halo columns)
    out = conv2d_pallas(x, w, b, (1, 1), (2, 2), relu=True,
                        method="advanced_simd_4", interpret=True,
                        pool_kernel=(3, 3), pool_stride=(2, 2),
                        pool_kind="max", lrn_n=lrn_n, lrn_oc_block=True,
                        **_LRN)
    assert out.shape == ref.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_fused_lrn_oc_block_multi_tile():
    """Channel-halo LRN cell banded over oh as well: both grid axes
    (band tiles × oc tiles) active at once."""
    x, w, b = _case(1, 4, 33, 21, 6, 3)
    ref = _lrn_ref(pool2d_ref(conv2d_ref(x, w, b, (1, 1), (1, 1),
                                         relu=True), (3, 3), (2, 2), "max"),
                   5)
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=True,
                        method="advanced_simd_4", interpret=True,
                        oh_block=5, pool_kernel=(3, 3), pool_stride=(2, 2),
                        pool_kind="max", lrn_n=5, lrn_oc_block=True, **_LRN)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_engine_second_gen_knobs_match_ref():
    """Per-layer second-generation knobs thread engine → plan → methods →
    kernels and stay numerically exact on a multi-band net."""
    net = NetworkDef("t", (3, 33, 21), 4, (
        LayerSpec("conv", "c1", out_channels=6, kernel=(3, 3),
                  padding=(1, 1), relu=True),
        LayerSpec("pool", "p1", kernel=(3, 3), stride=(2, 2)),
        LayerSpec("conv", "c2", out_channels=7, kernel=(3, 3),
                  padding=(1, 1), relu=True),
        LayerSpec("pool", "p2", kernel=(3, 3), stride=(2, 2)),
        LayerSpec("lrn", "n2", lrn_n=5, **_LRN),
        LayerSpec("flatten", "flatten"),
        LayerSpec("fc", "f1", out_channels=4),
    ))
    ref_eng = CNNEngine(net, method=Method.SEQ_REF)
    params = ref_eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *net.input_shape),
                          jnp.float32)
    ref = ref_eng.forward(params, x)
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_4, use_pallas=True,
                    per_layer_oh_blocks={"c1": 5},
                    per_layer_pool_carry={"c1": True},
                    per_layer_lrn_oc_block={"c2": True})
    assert fusion_summary(eng.plan(True)) == [("c1", "p1"),
                                              ("c2", "p2", "n2")]
    out = eng.forward(params, x, fuse=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


# ---------------------------------------------------------------------------
# whole-network fused vs unfused (all three paper networks × methods)
# ---------------------------------------------------------------------------

_NET_BATCH = {"lenet5": 4, "cifar10": 4, "alexnet": 1}


@pytest.fixture(scope="module", params=["lenet5", "cifar10", "alexnet"])
def net_params_ref(request):
    net = NETWORKS[request.param]()
    eng = CNNEngine(net, method=Method.SEQ_REF)
    params = eng.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (_NET_BATCH[request.param], *net.input_shape),
                          jnp.float32)
    return net, params, x, eng.forward(params, x)


@pytest.mark.parametrize("method", [Method.BASIC_SIMD,
                                    Method.ADVANCED_SIMD_4,
                                    Method.ADVANCED_SIMD_8])
def test_network_fused_matches_unfused(net_params_ref, method):
    net, params, x, ref = net_params_ref
    eng = CNNEngine(net, method=method, fuse_pool=True)
    assert fusion_summary(eng.plan(True))  # groups actually formed
    out = eng.forward(params, x, fuse=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
    # un-fused path of the same engine agrees too
    out_u = eng.forward(params, x, fuse=False)
    assert jnp.max(jnp.abs(out - out_u)) < 1e-4


def test_network_fused_pallas_interpret(net_params_ref):
    net, params, x, ref = net_params_ref
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8, use_pallas=True)
    out = eng.forward(params, x, fuse=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_per_layer_fuse_opt_out(net_params_ref):
    net, params, x, ref = net_params_ref
    conv_names = [l.name for l in net.layers if l.kind == "conv"]
    eng = CNNEngine(net, method=SIMD,
                    per_layer_fuse={conv_names[0]: False})
    groups = fusion_summary(eng.plan(True))
    assert all(conv_names[0] not in g for g in groups)
    assert jnp.max(jnp.abs(eng.forward(params, x) - ref)) < 1e-4


def test_collect_forces_per_layer_path(net_params_ref):
    """Instrumentation still sees every layer's activation when fused."""
    net, params, x, ref = net_params_ref
    eng = CNNEngine(net, method=SIMD, fuse_pool=True)
    acts = {}
    out = eng.forward(params, x, collect=acts)
    assert set(acts) == {l.name for l in net.layers}
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_jit_forward_memoized():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=SIMD)
    assert eng.jit_forward() is eng.jit_forward()
    assert eng.jit_forward(True) is eng.jit_forward(True)
    assert eng.jit_forward(True) is not eng.jit_forward(False)
    params = eng.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, *net.input_shape), jnp.float32)
    assert jnp.max(jnp.abs(eng.jit_forward(True)(params, x)
                           - eng.jit_forward(False)(params, x))) < 1e-4


@pytest.mark.parametrize("lrn_n", [4, 5])  # even n needs asymmetric padding
def test_lrn_vectorized_matches_loop(lrn_n):
    spec = LayerSpec("lrn", "n", lrn_n=lrn_n, lrn_alpha=1e-4, lrn_beta=0.75,
                     lrn_k=2.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 13, 6, 6), jnp.float32)
    # the pre-vectorization reference: n shifted slice+adds
    sq = x.astype(jnp.float32) ** 2
    pad = spec.lrn_n // 2
    sq_p = jnp.pad(sq, ((0, 0), (pad, pad), (0, 0), (0, 0)))
    acc = sum(jax.lax.slice_in_dim(sq_p, i, i + x.shape[1], axis=1)
              for i in range(spec.lrn_n))
    ref = x / (spec.lrn_k + spec.lrn_alpha * acc) ** spec.lrn_beta
    out = _lrn(x, spec)
    assert out.shape == x.shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-6


def test_fused_pool_stride_defaults_to_kernel():
    x, w, b = _case(1, 4, 16, 16, 6, 3)
    ref = pool2d_ref(conv2d_ref(x, w, b, relu=True), (2, 2), (2, 2), "max")
    out = conv2d_pallas(x, w, b, relu=True, method="advanced_simd_128",
                        interpret=True, pool_kernel=(2, 2))  # no pool_stride
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
