"""§Perf variant correctness: head padding must be semantics-preserving,
int8 KV bounded, variant plumbing sound."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.config import SINGLE_POD, get_arch
from repro.launch.variants import VARIANTS, apply_variants, head_pad
from repro.models.registry import get_model
from repro.sharding.auto import rules_for


def test_head_pad_preserves_semantics():
    """A model with heads padded to the axis multiple, whose padded q/k/v
    columns and wo rows are zero, computes the same logits as the original."""
    cfg = dataclasses.replace(get_arch("qwen1.5-32b").reduced(),
                              dtype="float32", param_dtype="float32",
                              num_heads=3, num_kv_heads=3)  # odd, like 40
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cfg_p, _, note = head_pad(cfg, rules_for(cfg, SINGLE_POD, None)[0],
                              model_size=4)
    assert cfg_p.num_heads == 4 and "head_pad" in note
    model_p = get_model(cfg_p)
    params_p = model_p.init(jax.random.PRNGKey(1))

    hd = cfg.head_dim
    qd, qd_p = cfg.q_dim, cfg_p.q_dim

    def pad_layer(p_small, p_big):
        out = dict(p_big)
        for name, d_out in (("wq", qd), ("wk", qd), ("wv", qd)):
            w = jnp.zeros_like(p_big[name]["w"])
            w = w.at[..., :d_out].set(p_small[name]["w"])
            entry = {"w": w}
            if "b" in p_small[name]:
                b = jnp.zeros_like(p_big[name]["b"]).at[..., :d_out].set(
                    p_small[name]["b"])
                entry["b"] = b
            out[name] = entry
        wo = jnp.zeros_like(p_big["wo"]["w"])  # [L, q_dim_padded, d]
        wo = wo.at[:, :qd, :].set(p_small["wo"]["w"])
        out["wo"] = {"w": wo}
        return out

    def graft(ps, pb):
        out = dict(pb)
        out["embed"] = ps["embed"]
        out["ln_f"] = ps["ln_f"]
        out["layers"] = dict(pb["layers"])
        for k in ("ln_attn", "ln_mlp", "mlp"):
            out["layers"][k] = ps["layers"][k]
        out["layers"]["attn"] = pad_layer(ps["layers"]["attn"],
                                          pb["layers"]["attn"])
        return out

    params_grafted = graft(params, params_p)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    lg_small, _ = model.forward(params, {"tokens": toks}, mode="prefill")
    lg_big, _ = model_p.forward(params_grafted, {"tokens": toks},
                                mode="prefill")
    err = float(jnp.max(jnp.abs(lg_small[..., : cfg.vocab_size]
                                - lg_big[..., : cfg.vocab_size])))
    assert err < 1e-4, err


def test_variant_chain_application():
    cfg = get_arch("qwen1.5-32b")
    rules, _ = rules_for(cfg, SINGLE_POD, None)
    cfg2, rules2, notes, mb = apply_variants(
        ("head_pad", "int8kv", "mb4"), cfg, rules, 16)
    assert cfg2.num_heads == 48 and cfg2.num_kv_heads == 48
    assert cfg2.kv_quant
    assert mb == 4
    assert len(notes) == 3


def test_all_variants_registered_and_callable():
    cfg = get_arch("internlm2-20b")
    rules, _ = rules_for(cfg, SINGLE_POD, None)
    for name, fn in VARIANTS.items():
        cfg2, rules2, note = fn(cfg, rules, 16)
        assert isinstance(note, str) and note
