"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.layout import nchw_to_nhwc, nhwc_to_nchw, pad_axis, unpad_axis
from repro.core.methods import Method, conv2d
from repro.nn.attention import chunked_attention, reference_attention
from repro.nn.attention import quantize_kv
from repro.train.step import cross_entropy

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(n=st.integers(1, 3), c=st.integers(1, 6), h=st.integers(5, 12),
       oc=st.integers(1, 6), k=st.sampled_from([1, 3, 5]),
       stride=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_conv_ladder_agreement_property(n, c, h, oc, k, stride, seed):
    """For any shape, every ladder method equals the sequential reference."""
    if h < k:
        return
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n, c, h, h), jnp.float32)
    w = jax.random.normal(ks[1], (oc, c, k, k)) * 0.2
    b = jax.random.normal(ks[2], (oc,))
    ref = conv2d(x, w, b, Method.SEQ_REF, (stride, stride), (0, 0), True)
    for m in (Method.BASIC_SIMD, Method.ADVANCED_SIMD_8):
        out = conv2d(x, w, b, m, (stride, stride), (0, 0), True)
        assert jnp.max(jnp.abs(out - ref)) < 1e-3


@given(b=st.integers(1, 3), s=st.integers(2, 40),
       chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_chunked_attention_chunk_invariance(b, s, chunk, seed):
    """Output must not depend on the chunking used (any chunk size equals
    the reference)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h, kvh, hd = 4, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kvh, hd))
    v = jax.random.normal(ks[2], (b, s, kvh, hd))
    out = chunked_attention(q, k, v, chunk_q=chunk, chunk_kv=chunk)
    ref = reference_attention(q, k, v)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 2**31 - 1))
def test_attention_softmax_scale_invariance(scale, seed):
    """Adding a per-row constant to scores (here via v-independent shift of
    all logits by duplicating q) never changes softmax output: attention of
    (q, k, v) equals attention of (q, k, v) computed at a different max —
    regression proxy: outputs are bounded by max |v|."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = scale * jax.random.normal(ks[0], (1, 9, 2, 8))
    k = jax.random.normal(ks[1], (1, 9, 2, 8))
    v = jax.random.normal(ks[2], (1, 9, 2, 8))
    out = chunked_attention(q, k, v, chunk_q=4, chunk_kv=4)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


@given(seed=st.integers(0, 2**31 - 1), mag=st.floats(0.1, 50.0))
def test_kv_quantization_error_bound(seed, mag):
    x = mag * jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 2, 16))
    qv, sc = quantize_kv(x)
    deq = qv.astype(jnp.float32) * sc.astype(jnp.float32)[..., None]
    bound = sc.astype(jnp.float32)[..., None] * 0.5
    assert bool(jnp.all(jnp.abs(deq - x) <= bound + 1e-4 * mag))


@given(b=st.integers(1, 3), s=st.integers(1, 8), v=st.integers(2, 40),
       seed=st.integers(0, 2**31 - 1))
def test_cross_entropy_matches_naive(b, s, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = jax.random.normal(ks[0], (b, s, v))
    labels = jax.random.randint(ks[1], (b, s), 0, v)
    ce = cross_entropy(logits, labels, v)
    logp = jax.nn.log_softmax(logits, axis=-1)
    naive = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    assert abs(float(ce - naive)) < 1e-4


@given(axis_len=st.integers(1, 20), mult=st.sampled_from([4, 8, 128]))
def test_pad_unpad_roundtrip(axis_len, mult):
    x = jnp.arange(2 * axis_len, dtype=jnp.float32).reshape(2, axis_len)
    xp, orig = pad_axis(x, 1, mult)
    assert xp.shape[1] % mult == 0
    assert jnp.array_equal(unpad_axis(xp, 1, orig), x)
