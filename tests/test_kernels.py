"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes per the assignment contract."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.conv2d.ops import conv2d as conv2d_pallas
from repro.kernels.conv2d.ref import conv2d_ref
from repro.kernels.matmul_fused.ops import matmul_fused
from repro.kernels.matmul_fused.ref import matmul_fused_ref
from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import flash_attention_ref

CONV_METHODS = ("basic_parallel", "basic_simd", "advanced_simd_4",
                "advanced_simd_128")
CONV_SHAPES = [
    # (n, c, h, w, oc, k, stride, pad)
    (2, 3, 16, 16, 8, 3, 1, 1),
    (1, 4, 12, 12, 6, 5, 2, 0),
    (1, 3, 28, 28, 20, 5, 1, 0),  # LeNet conv1
    (2, 16, 13, 13, 32, 3, 1, 1),
    (1, 8, 9, 9, 8, 1, 1, 0),  # 1x1 conv
]


@pytest.mark.parametrize("method", CONV_METHODS)
@pytest.mark.parametrize("shape", CONV_SHAPES)
def test_conv2d_kernel_vs_ref(method, shape):
    n, c, h, w_, oc, k, s, p = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (n, c, h, w_), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (oc, c, k, k)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (oc,))
    ref = conv2d_ref(x, w, b, (s, s), (p, p), relu=True)
    out = conv2d_pallas(x, w, b, (s, s), (p, p), relu=True, method=method,
                        interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_conv2d_kernel_dtypes(dtype):
    dt = jnp.dtype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 10, 10)).astype(dt)
    w = (jax.random.normal(jax.random.PRNGKey(1), (8, 4, 3, 3)) * 0.1).astype(dt)
    b = jnp.zeros((8,), jnp.float32)
    ref = conv2d_ref(x, w, b, (1, 1), (1, 1))
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), method="advanced_simd_128",
                        interpret=True)
    tol = 1e-4 if dtype == "float32" else 5e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


@pytest.mark.parametrize("mkn", [(64, 64, 64), (100, 300, 200), (7, 9, 11),
                                 (1, 1024, 1), (128, 128, 384)])
@pytest.mark.parametrize("act", ["none", "relu", "silu", "gelu"])
def test_matmul_fused_vs_ref(mkn, act):
    m, k, n = mkn
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    b = jax.random.normal(jax.random.PRNGKey(2), (n,))
    out = matmul_fused(x, w, b, act=act, interpret=True)
    ref = matmul_fused_ref(x, w, b, act=act)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_matmul_fused_bf16_and_nobias():
    x = jax.random.normal(jax.random.PRNGKey(0), (33, 65)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (65, 17)).astype(jnp.bfloat16)
    out = matmul_fused(x, w, None, interpret=True)
    ref = matmul_fused_ref(x, w, None)
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < 0.15


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 17])
@pytest.mark.parametrize("cap", [0.0, 8.0])
def test_flash_attention_kernel(causal, window, cap):
    b, s, h, kvh, hd = 2, 100, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          attn_softcap=cap, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              attn_softcap=cap)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("shape", [(1, 64, 8, 8, 16), (3, 33, 2, 1, 64)])
def test_flash_attention_kernel_shapes(shape):
    b, s, h, kvh, hd = shape
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
    out = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("shape", [(2, 50, 3, 16), (1, 32, 2, 64),
                                   (3, 17, 1, 8)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_wkv6_kernel_vs_recurrence(shape, chunk):
    """WKV6 chunked kernel (interpret) vs the per-timestep oracle, including
    non-multiple sequence lengths (ring padding must not perturb the state)."""
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_reference

    b, s, h, e = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], shape)
    v = jax.random.normal(ks[2], shape)
    logw = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)
    u = jax.random.normal(ks[4], (h, e))
    out = wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    ref, _ = wkv6_reference(r, k, v, logw, u)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
