"""Sharding-rule unit + property tests: specs never duplicate a mesh axis,
drop non-divisible dims, and adapt to tiny-batch long-context shapes."""
import dataclasses

import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.config import (
    MULTI_POD,
    SINGLE_POD,
    ModelConfig,
    MoEConfig,
    get_arch,
    get_shape,
    list_archs,
)
from repro.nn.param import Param, is_param, axes_tree
from repro.models.registry import get_model
from repro.sharding.auto import rules_for
from repro.sharding.rules import DEFAULT_RULES, logical_to_spec
from repro.train.optimizer import adamw_init_spec


def _no_dup(spec: P):
    seen = []
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            assert a not in seen, spec
            seen.append(a)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", [SINGLE_POD, MULTI_POD])
@pytest.mark.parametrize("shape_name", ["train_4k", "long_500k"])
def test_param_and_cache_specs_valid(arch, mesh, shape_name):
    """Every parameter/cache PartitionSpec is duplicate-free and divides the
    tensor shape."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    rules, _ = rules_for(cfg, mesh, shape)
    model = get_model(cfg)
    sizes = dict(zip(mesh.axes, mesh.shape))

    def check(spec_tree):
        for path, p in jax.tree_util.tree_flatten_with_path(
                spec_tree, is_leaf=is_param)[0]:
            spec = logical_to_spec(p.axes, mesh.axes, rules)
            _no_dup(spec)
            for dim, entry in zip(p.shape, spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = 1
                for a in axes:
                    n *= sizes[a]
                assert dim % n == 0, (path, p.shape, spec)

    check(model.param_spec())
    window = model.effective_window(shape)
    check(model.cache_spec(shape.global_batch, shape.seq_len, window))
    if shape.kind == "train":
        fsdp = dict(rules.table).get("embed") is not None
        check(adamw_init_spec(model.param_spec(), zero1=True,
                              dp_size=mesh.dp_size, fsdp=fsdp))


@given(heads=st.integers(1, 64), kv=st.integers(1, 64),
       ff=st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_rules_drop_non_divisible(heads, kv, ff):
    cfg = dataclasses.replace(
        get_arch("internlm2-20b"), num_heads=heads,
        num_kv_heads=kv, d_ff=ff * 128, head_dim=128)
    rules, notes = rules_for(cfg, SINGLE_POD, None)
    t = dict(rules.table)
    assert (t["heads"] is None) == (heads % 16 != 0)
    assert (t["kv_heads"] is None) == (kv % 16 != 0)
    assert (t["ff"] is None) == ((ff * 128) % 16 != 0)


def test_long_context_tiny_batch_moves_sharding_to_kv_seq():
    cfg = get_arch("internlm2-20b")
    rules, notes = rules_for(cfg, SINGLE_POD, get_shape("long_500k"))
    t = dict(rules.table)
    assert t["batch"] is None
    assert t["kv_seq"] is not None


def test_moe_shard_modes_mutually_exclusive():
    for arch in ("grok-1-314b", "qwen3-moe-30b-a3b"):
        cfg = get_arch(arch)
        rules, _ = rules_for(cfg, SINGLE_POD, None)
        t = dict(rules.table)
        assert t["experts"] is None or t["expert_ff"] is None


def test_fsdp_enabled_for_large_models_only():
    for arch, expect in [("grok-1-314b", True), ("gemma2-2b", False),
                         ("qwen1.5-32b", True), ("rwkv6-1.6b", False)]:
        rules, notes = rules_for(get_arch(arch), SINGLE_POD, None)
        assert (dict(rules.table)["embed"] is not None) == expect, arch


def test_multi_pod_batch_spans_pod_axis():
    rules, _ = rules_for(get_arch("internlm2-20b"), MULTI_POD,
                         get_shape("train_4k"))
    spec = logical_to_spec(("batch", "seq"), MULTI_POD.axes, rules)
    assert spec[0] == ("pod", "data")
