"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each assigned architecture (2 layers, d_model<=512, <=4 experts) runs one
forward and one decode step on CPU with correct output shapes and no NaNs;
three representative families additionally run a full optimizer step."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.config import TrainConfig, get_arch, list_archs
from repro.models.registry import get_model
from repro.train.optimizer import adamw_init
from repro.train.step import make_train_step

ARCHS = list_archs()


def _batch(cfg, b, s, key=0):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["media_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (b, cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim)
        ).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (b, cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim)
        ).astype(jnp.bfloat16)
    return batch


def test_all_ten_archs_assigned():
    assert len(ARCHS) == 10
    families = {get_arch(a).family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= 2 or cfg.shared_attn_every
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    logits, aux = model.forward(params, _batch(cfg, b, s), mode="train")
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    cache = model.init_cache(b, 32)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, new_cache = model.decode_step(params, tok, pos, cache)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(new_cache)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch", ["internlm2-20b", "qwen3-moe-30b-a3b",
                                  "rwkv6-1.6b"])
def test_train_step_runs(arch):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, TrainConfig(), dp_size=1))
    batch = _batch(cfg, 2, 16)
    batch["labels"] = batch["tokens"]
    p2, o2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(o2["step"]) == 1
    # parameters actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b_.astype(jnp.float32))))
               for a, b_ in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(p2)))
    assert diff > 0


def test_train_step_with_microbatching_matches_structure():
    cfg = get_arch("internlm2-20b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, TrainConfig(), dp_size=1,
                                   microbatches=2))
    batch = _batch(cfg, 4, 16)
    batch["labels"] = batch["tokens"]
    _, _, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
