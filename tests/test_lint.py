"""Repo lint: baseline cleanliness + seeded-snippet detection per rule."""
from pathlib import Path

import pytest

from repro.analysis.lint import lint_source, lint_tree

REPO = Path(__file__).resolve().parent.parent


def rules_of(findings):
    return {f.rule for f in findings}


def test_repo_baseline_is_clean():
    """src/repro must lint clean — the CI gate enforces this forever."""
    assert lint_tree(REPO / "src" / "repro") == []


# -- R001: pallas_call kwargs ----------------------------------------------

def test_r001_missing_kwargs():
    src = """
out = pl.pallas_call(kern, grid=(n,), out_shape=shape)(x)
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R001"}
    assert "interpret" in findings[0].detail


def test_r001_threaded_kwargs_clean():
    src = """
out = pl.pallas_call(
    kern, grid=(n,), out_shape=shape,
    compiler_params=pltpu.TPUCompilerParams(
        dimension_semantics=("parallel",)),
    interpret=interpret,
)(x)
"""
    assert lint_source(src) == []


# -- R002: knob invalidation ------------------------------------------------

def test_r002_mutator_without_on_change():
    src = """
class _KnobDict(dict):
    def __setitem__(self, k, v):
        super().__setitem__(k, v)   # stale-plan bug: no invalidation
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R002"}
    assert "__setitem__" in findings[0].detail


def test_r002_mutator_delegation_clean():
    src = """
class _KnobDict(dict):
    def __setitem__(self, k, v):
        super().__setitem__(k, v)
        self._on_change()

    def __ior__(self, other):
        self.update(other)   # delegation to a checked mutator is fine
        return self
"""
    assert lint_source(src) == []


def test_r002_knob_name_mismatch():
    src = """
class Engine:
    method = _knob("oh_block")   # wraps the WRONG attribute
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R002"}


def test_r002_clear_caches_missing_cache():
    src = """
class Engine:
    def __init__(self):
        self._plans = {}
        self._jit_cache = {}

    def clear_caches(self):
        self._plans.clear()   # forgets _jit_cache
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R002"}
    assert "_jit_cache" in findings[0].detail


def test_r002_clear_caches_complete_clean():
    src = """
class Engine:
    def __init__(self):
        self._plans = {}
        self._jit_cache = {}

    def clear_caches(self):
        self._plans.clear()
        self._jit_cache.clear()
"""
    assert lint_source(src) == []


# -- R003: Unblocked index maps --------------------------------------------

def test_r003_inline_arithmetic():
    src = """
spec = pl.BlockSpec((1, band, wp, c),
                    lambda i, t: (i, t * 8, 0, 0),
                    indexing_mode=pl.Unblocked())
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R003"}


def test_r003_resolver_named_offset_clean():
    src = """
spec = pl.BlockSpec((1, band, wp, c),
                    lambda i, t: (i, t * row_step, 0, 0),
                    indexing_mode=pl.Unblocked())
"""
    assert lint_source(src) == []


def test_r003_blocked_spec_literals_allowed():
    # block-index (non-Unblocked) specs index in block units; literals fine
    src = """
spec = pl.BlockSpec((None, 4, oh, ow), lambda i, t: (i, t * 2, 0, 0))
"""
    assert lint_source(src) == []


# -- R004: silent excepts ---------------------------------------------------

def test_r004_silent_broad_except():
    src = """
try:
    risky()
except Exception:
    pass
"""
    findings = lint_source(src)
    assert rules_of(findings) == {"R004"}


def test_r004_bare_except_pass():
    src = """
try:
    risky()
except:
    pass
"""
    assert rules_of(lint_source(src)) == {"R004"}


def test_r004_narrow_or_handled_clean():
    src = """
try:
    risky()
except OSError:
    pass

try:
    risky()
except Exception:
    log.warning("risky failed")
"""
    assert lint_source(src) == []


# -- R005: magic byte budgets ----------------------------------------------

@pytest.mark.parametrize("expr", ["8388608", "8 * 1024 * 1024", "14 << 20"])
def test_r005_magic_budget_comparison(expr):
    findings = lint_source(f"ok = cell_bytes <= {expr}\n")
    assert rules_of(findings) == {"R005"}


def test_r005_named_budget_clean():
    src = """
VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # definitions are fine
ok = cell_bytes <= VMEM_BUDGET_BYTES
small = n <= 128
"""
    assert lint_source(src) == []


# -- R006: serving/ supervisor error handling ------------------------------

SERVING_PATH = "src/repro/serving/x.py"


def test_r006_swallowed_serving_except():
    src = """
try:
    run_batch()
except Exception:
    count += 1
"""
    assert rules_of(lint_source(src, path=SERVING_PATH)) == {"R006"}


def test_r006_only_fires_under_serving():
    src = """
try:
    run_batch()
except Exception:
    count += 1
"""
    # outside serving/ the broad-except rule (R004) may speak, R006 not
    assert "R006" not in rules_of(lint_source(src, path="src/repro/core/x.py"))


def test_r006_reraise_clean():
    src = """
try:
    run_batch()
except Exception:
    raise
"""
    assert lint_source(src, path=SERVING_PATH) == []


def test_r006_bound_exception_referenced_clean():
    src = """
try:
    run_batch()
except TransientEngineFault as e:
    last_err = e
"""
    assert lint_source(src, path=SERVING_PATH) == []


def test_r006_typed_failure_result_clean():
    src = """
try:
    run_batch()
except Exception:
    out.append(FailedResult(rid=rid, error="engine_fault", detail="boom",
                            latency_s=0.0, batch_size=1, bucket=1))
"""
    assert lint_source(src, path=SERVING_PATH) == []


# -- R007: kernel-body astype discipline ------------------------------------

KERNEL_PATH = "src/repro/kernels/conv2d/kernels.py"

_R007_KERNEL = """
def _my_kernel(x_ref, o_ref, *, relu):
    acc = x_ref[0].astype({cast})
    o_ref[...] = acc.astype(o_ref.dtype)
"""


def test_r007_inline_dtype_literal():
    src = _R007_KERNEL.format(cast="jnp.float32")
    findings = lint_source(src, path=KERNEL_PATH)
    assert rules_of(findings) == {"R007"}
    assert "ACC_DTYPE" in findings[0].detail


def test_r007_string_dtype_literal():
    src = _R007_KERNEL.format(cast='"bfloat16"')
    assert rules_of(lint_source(src, path=KERNEL_PATH)) == {"R007"}


def test_r007_named_constant_clean():
    src = _R007_KERNEL.format(cast="ACC_DTYPE")
    assert lint_source(src, path=KERNEL_PATH) == []


def test_r007_ref_dtype_clean():
    src = _R007_KERNEL.format(cast="o_ref.dtype")
    assert lint_source(src, path=KERNEL_PATH) == []


def test_r007_only_fires_in_kernel_bodies():
    # a host-side helper (no *_ref parameter) may cast freely
    src = """
def host_pad(x):
    return x.astype(jnp.float32)
"""
    assert lint_source(src, path=KERNEL_PATH) == []


def test_r007_only_fires_under_kernels_tree():
    src = _R007_KERNEL.format(cast="jnp.float32")
    assert lint_source(src, path="src/repro/core/plan.py") == []


def test_tools_and_benchmarks_baseline_clean():
    """The lint default paths grew to tools/ and benchmarks/ — they must
    stay clean too."""
    assert lint_tree(REPO / "tools") == []
    assert lint_tree(REPO / "benchmarks") == []
