"""CNNServer end-to-end tests: queueing/FIFO dynamic batching, deadline
flush (injectable clock), ragged-batch padding correctness through the
bucketed jit cache, per-request output parity with the unbatched path,
and the serving compile bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import CNNEngine
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.serving.cnn import CNNServer, ImageRequest


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def lenet():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)
    params = eng.init(jax.random.PRNGKey(0))
    imgs = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (16, *net.input_shape), jnp.float32))
    return net, eng, params, imgs


def _fresh_engine(net):
    return CNNEngine(net, method=Method.ADVANCED_SIMD_8)


def _submit(server, imgs, rids, top_k=5):
    for r in rids:
        server.submit(ImageRequest(rid=r, image=imgs[r], top_k=top_k))


# ---------------------------------------------------------------------------
# queueing + dynamic batch formation
# ---------------------------------------------------------------------------


def test_fifo_queueing_and_full_batch_flush(lenet):
    net, eng, params, imgs = lenet
    clock = FakeClock()
    srv = CNNServer(eng, params, max_batch=4, max_delay_s=10.0, clock=clock)
    _submit(srv, imgs, range(5))
    served = srv.step()
    # a full max_batch is waiting -> flush the 4 OLDEST, FIFO
    assert [r.rid for r in served] == [0, 1, 2, 3]
    assert all(r.batch_size == 4 and r.bucket == 4 for r in served)
    assert srv.pending() == 1
    # the straggler is under deadline: no flush yet
    assert srv.step() == []
    clock.t += 11.0
    (last,) = srv.step()
    assert last.rid == 4 and last.batch_size == 1 and last.bucket == 1
    assert set(srv.done) == set(range(5))


def test_deadline_flush_with_injectable_clock(lenet):
    net, eng, params, imgs = lenet
    clock = FakeClock()
    srv = CNNServer(eng, params, max_batch=8, max_delay_s=1.0, clock=clock)
    _submit(srv, imgs, range(2))
    assert srv.step() == []          # 2 < max_batch, deadline not reached
    clock.t = 0.5
    assert srv.step() == []          # still under the deadline
    clock.t = 1.01
    served = srv.step()              # oldest aged past max_delay_s
    assert [r.rid for r in served] == [0, 1]
    assert served[0].batch_size == 2 and served[0].bucket == 2


def test_run_until_drained_forces_ragged_tail(lenet):
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params, max_batch=4, max_delay_s=100.0,
                    clock=FakeClock())
    _submit(srv, imgs, range(7))
    done = srv.run_until_drained()
    assert set(done) == set(range(7))
    s = srv.stats()
    assert s["served"] == 7 and s["batches"] == 2
    assert s["mean_batch"] == pytest.approx(3.5)
    assert s["p50_latency_us"] >= 0 and s["p95_latency_us"] >= \
        s["p50_latency_us"]


# ---------------------------------------------------------------------------
# output parity with the unbatched per-request path
# ---------------------------------------------------------------------------


def test_unbatched_server_matches_per_request_exactly(lenet):
    """With max_batch=1 every request is served unbatched through the
    same bucket-1 jit the direct path uses — results are byte-exact."""
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params, max_batch=1, max_delay_s=0.0)
    _submit(srv, imgs, range(4), top_k=3)
    srv.run_until_drained()
    for r in range(4):
        probs = np.asarray(eng.forward_batched(params, imgs[r:r + 1])[0])
        top = np.argsort(-probs, kind="stable")[:3]
        res = srv.done[r]
        assert res.top_indices == [int(j) for j in top]
        assert res.top_probs == [float(probs[j]) for j in top]


def test_ragged_batches_match_per_request(lenet):
    """Ragged dynamic batches (padded to their bucket) reproduce each
    request's unbatched output: byte-exact within a bucket (pad rows are
    inert batchmates), ≤1e-6 across buckets (independently compiled XLA
    executables of the same math)."""
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params, max_batch=8, max_delay_s=0.0,
                    clock=FakeClock())
    # three ragged flushes: 3 (bucket 4), 5 (bucket 8), 1 (bucket 1)
    for rids in (range(0, 3), range(3, 8), range(8, 9)):
        _submit(srv, imgs, rids, top_k=4)
        srv.step(force=True)
    assert sorted(r.batch_size for r in srv.done.values()) == \
        [1] + [3] * 3 + [5] * 5
    for r in range(9):
        probs = np.asarray(eng.forward_batched(params, imgs[r:r + 1])[0])
        res = srv.done[r]
        assert np.allclose(res.top_probs,
                           np.sort(probs)[::-1][:4], atol=1e-6)
        assert res.top_indices == [
            int(j) for j in np.argsort(-probs, kind="stable")[:4]]
    # in-bucket exactness: a request's row is identical whatever its
    # batchmates — zero-pad rows included
    a = eng.forward_batched(params, jnp.asarray(imgs[:3]))   # bucket 4
    b = eng.forward_batched(params, jnp.asarray(imgs[:4]))   # bucket 4
    assert jnp.array_equal(a, b[:3])


def test_serving_compile_bound(lenet):
    """Arbitrary ragged traffic through CNNServer compiles at most
    log2(max_batch)+1 jitted variants (the bucket set)."""
    net, _, params, imgs = lenet
    eng = _fresh_engine(net)
    srv = CNNServer(eng, params, max_batch=8, max_delay_s=0.0,
                    clock=FakeClock())
    rid = 0
    for size in (1, 2, 3, 4, 5, 6, 7, 8, 3, 5, 1, 8):
        for _ in range(size):
            srv.submit(ImageRequest(rid=rid, image=imgs[rid % 16]))
            rid += 1
        srv.step(force=True)
    stats = eng.bucket_stats()
    assert stats["compiles"] <= 4  # log2(8)+1
    assert srv.stats()["served"] == rid


# ---------------------------------------------------------------------------
# validation + top-k edge cases
# ---------------------------------------------------------------------------


def test_submit_rejects_wrong_shape(lenet):
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params)
    with pytest.raises(ValueError, match="shape"):
        srv.submit(ImageRequest(rid=0, image=np.zeros((1, 28, 29))))
    with pytest.raises(ValueError, match="max_batch"):
        CNNServer(eng, params, max_batch=0)


def test_top_k_clamped_and_sorted(lenet):
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params, max_batch=2, max_delay_s=0.0)
    srv.submit(ImageRequest(rid=0, image=imgs[0], top_k=99))
    srv.run_until_drained()
    res = srv.done[0]
    assert len(res.top_indices) == net.num_classes
    assert res.top_probs == sorted(res.top_probs, reverse=True)
    assert abs(sum(res.top_probs) - 1.0) < 1e-5  # softmax distribution


def test_reset_stats_keeps_results(lenet):
    net, eng, params, imgs = lenet
    srv = CNNServer(eng, params, max_batch=4, max_delay_s=0.0)
    _submit(srv, imgs, range(3))
    srv.run_until_drained()
    srv.reset_stats()
    assert srv.stats()["served"] == 0 and set(srv.done) == {0, 1, 2}
    # retrieve-and-remove keeps a long-lived server's result map bounded
    assert srv.pop_result(1).rid == 1
    assert srv.pop_result(1) is None and set(srv.done) == {0, 2}
