"""The strong end-to-end invariant: prefill + per-token decode reproduces
the full-forward logits for EVERY assigned architecture (fp32, reduced)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.config import get_arch, list_archs
from repro.models.registry import get_model


def _fp32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, eval_capacity_factor=float(cfg.moe.num_experts)))
    return cfg


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_full_forward(arch):
    cfg = _fp32(get_arch(arch).reduced())
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s_pre, s_tot = 2, 8, 12
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s_tot), 0,
                              cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras["media_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(8),
            (b, cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim))
    if cfg.family == "audio":
        extras["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(8),
            (b, cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim))
    full, _ = model.forward(params, {"tokens": toks, **extras},
                            mode="prefill")
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        model.init_cache(b, s_tot))
    pre, cache, _ = model.forward(params, {"tokens": toks[:, :s_pre],
                                           **extras},
                                  mode="prefill", cache=cache)
    errs = [float(jnp.max(jnp.abs(
        pre[:, -1, : cfg.vocab_size] - full[:, s_pre - 1, : cfg.vocab_size])))]
    for t in range(s_pre, s_tot):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, t:t+1], pos, cache)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0, : cfg.vocab_size] - full[:, t, : cfg.vocab_size]))))
    assert max(errs) < 2e-2, errs


def test_sliding_window_decode_consistency():
    """Ring-buffer SWA cache: decode with window w matches full forward with
    the same window (starcoder2 family)."""
    cfg = _fp32(get_arch("starcoder2-15b").reduced())  # window 64, s<64 path
    cfg = dataclasses.replace(cfg, sliding_window=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s_pre, s_tot = 1, 10, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s_tot), 0,
                              cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks}, mode="prefill")
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), model.init_cache(b, s_tot))
    pre, cache, _ = model.forward(params, {"tokens": toks[:, :s_pre]},
                                  mode="prefill", cache=cache)
    for t in range(s_pre, s_tot):
        pos = jnp.full((b,), t, jnp.int32)
        lg, cache = model.decode_step(params, toks[:, t:t+1], pos, cache)
        err = float(jnp.max(jnp.abs(
            lg[:, 0, : cfg.vocab_size] - full[:, t, : cfg.vocab_size])))
        assert err < 2e-2, (t, err)
