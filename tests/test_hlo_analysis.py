"""HLO analyzer: trip-count-corrected FLOPs, collective detection."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (
    analyze_hlo_text,
    parse_module,
    shape_bytes,
)


def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert shape_bytes("pred[7]") == 7


def test_scan_flops_trip_corrected():
    """A scan of T matmuls must report ~T x the single-matmul FLOPs (XLA's
    own cost_analysis counts the body once — the reason this module exists)."""
    def scanned(x, ws):
        def step(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, ws)
        return jnp.sum(y)

    T, n = 10, 128
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, n, n), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    costs = analyze_hlo_text(c.as_text())
    expect = 2 * n * n * n * T
    assert 0.9 * expect < costs.flops < 1.2 * expect
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per device
        ca = ca[0]
    xla = ca["flops"]
    assert xla < 0.2 * costs.flops  # body-once undercount, documented


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return jnp.sum(y)

    T, n = 4, 64
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, n, n), jnp.float32)
    c = jax.jit(nested).lower(x, ws).compile()
    costs = analyze_hlo_text(c.as_text())
    expect = 2 * n ** 3 * T * 3
    assert 0.9 * expect < costs.flops < 1.3 * expect


def test_collective_parsing_fixture():
    """Parser handles a hand-written module with collectives inside a loop."""
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %ar = f32[64,64]{1,0} all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[64,64]{1,0}) tuple(%zero, %x)
  %w = (s32[], f32[64,64]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[128,64]{1,0} all-gather(%x), dimensions={0}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    costs = analyze_hlo_text(hlo)
    # all-reduce of 16 KiB runs 5 times; all-gather result is 32 KiB once
    assert costs.coll_count["all-reduce"] == 5
    assert costs.coll_bytes["all-reduce"] == 5 * 64 * 64 * 4
    assert costs.coll_count["all-gather"] == 1
    assert costs.coll_bytes["all-gather"] == 128 * 64 * 4


def test_parse_module_structure():
    hlo = """
ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  ROOT %y = f32[8]{0} tanh(%x)
}
"""
    comps = parse_module(hlo)
    assert "main" in comps
    assert any(i.op == "tanh" for i in comps["main"].instrs)
