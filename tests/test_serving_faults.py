"""Fault-tolerant serving tests: every recovery path in ``CNNServer``
driven deterministically through the ``serving.faults`` harness — no
real sleeps, no wall-clock dependence.

Covers: admission control (queue-full rejection, non-finite frames,
unmeetable deadlines), deadline expiry ordering under an injectable
clock, retry/backoff schedules, poison-batch bisection isolating
exactly one request (batchmates byte-identical to a fault-free run),
non-finite output detection, circuit-breaker trip/shed/half-open/reset,
degradation-ladder hysteresis with ``CNNEngine.switch_verified``
pre-validation, and the drained-vs-wedged contract of
``run_until_drained``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import Finding
from repro.core.engine import CNNEngine
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.serving.cnn import (CNNServer, FailedResult, ImageRequest,
                               ImageResult, NonFiniteInputError,
                               ServerWedgedError, ShedResult,
                               SupervisorConfig)
from repro.serving.degrade import DegradeController, Rung, default_ladder
from repro.serving.faults import (FaultInjector, FaultScript,
                                  PersistentEngineFault,
                                  TransientEngineFault)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


class FakeSleep:
    def __init__(self):
        self.delays = []

    def __call__(self, s):
        self.delays.append(s)


@pytest.fixture(scope="module")
def lenet():
    net = NETWORKS["lenet5"]()
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)
    params = eng.init(jax.random.PRNGKey(0))
    imgs = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (16, *net.input_shape), jnp.float32))
    return net, eng, params, imgs


def _server(eng, params, **kw):
    kw.setdefault("clock", FakeClock())
    kw.setdefault("sleep", FakeSleep())
    return CNNServer(eng, params, **kw)


def _submit(server, imgs, rids, **req_kw):
    out = []
    for r in rids:
        out.append(server.submit(
            ImageRequest(rid=r, image=imgs[r % len(imgs)], **req_kw)))
    return out


# ---------------------------------------------------------------------------
# fault harness basics
# ---------------------------------------------------------------------------


def test_empty_script_injector_is_transparent(lenet):
    """A wired-but-empty FaultScript must not change a single bit of
    the serving output."""
    net, eng, params, imgs = lenet
    plain = _server(eng, params, max_batch=4, max_delay_s=0.0)
    _submit(plain, imgs, range(4))
    plain.run_until_drained()
    inj = FaultInjector(FaultScript())
    faulted = _server(eng, params, max_batch=4, max_delay_s=0.0,
                      fault_injector=inj)
    _submit(faulted, imgs, range(4))
    faulted.run_until_drained()
    for r in range(4):
        assert faulted.done[r].top_probs == plain.done[r].top_probs
        assert faulted.done[r].top_indices == plain.done[r].top_indices
    assert inj.calls == 1 and inj.events == []


def test_injected_faults_raise_typed(lenet):
    net, eng, params, imgs = lenet
    inj = FaultInjector(FaultScript(transient_calls={0},
                                    persistent_calls={1}))
    x = np.zeros((1, *net.input_shape), np.float32)
    with pytest.raises(TransientEngineFault):
        inj(lambda a: a, x, [0])
    with pytest.raises(PersistentEngineFault):
        inj(lambda a: a, x, [0])
    assert [e["kind"] for e in inj.events] == ["transient", "persistent"]


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_queue_full_rejection(lenet):
    net, eng, params, imgs = lenet
    srv = _server(eng, params, max_batch=4, max_delay_s=10.0, max_queue=2)
    assert srv.submit(ImageRequest(rid=0, image=imgs[0])) is None
    assert srv.submit(ImageRequest(rid=1, image=imgs[1])) is None
    shed = srv.submit(ImageRequest(rid=2, image=imgs[2]))
    assert isinstance(shed, ShedResult) and shed.reason == "queue_full"
    assert not shed.ok
    assert srv.done[2] is shed          # recorded, never silently dropped
    assert srv.pending() == 2
    s = srv.stats()
    assert s["rejected"] == 1 and s["shed"] == 1


def test_non_finite_frame_rejected_at_admission(lenet):
    net, eng, params, imgs = lenet
    srv = _server(eng, params)
    bad = imgs[0].copy()
    bad[0, 3, 3] = np.nan
    with pytest.raises(NonFiniteInputError, match="non-finite"):
        srv.submit(ImageRequest(rid=0, image=bad))
    bad[0, 3, 3] = np.inf
    with pytest.raises(ValueError):     # NonFiniteInputError is a ValueError
        srv.submit(ImageRequest(rid=0, image=bad))
    assert srv.pending() == 0


def test_unmeetable_deadline_shed_at_admission(lenet):
    """A deadline below the measured service-time estimate (EWMA over
    executed batches) is shed up front — the request could not be
    served in time even if a batch flushed immediately."""
    net, eng, params, imgs = lenet
    clock = FakeClock()
    inj = FaultInjector(FaultScript(latency_spikes={0: 1.0}),
                        advance=clock.advance)
    srv = _server(eng, params, max_batch=1, max_delay_s=0.0, clock=clock,
                  fault_injector=inj)
    _submit(srv, imgs, [0])
    srv.run_until_drained()             # service estimate is now ~1.0s
    assert srv.health()["service_estimate_s"] == pytest.approx(1.0)
    shed = srv.submit(ImageRequest(rid=1, image=imgs[1], deadline_s=0.5))
    assert isinstance(shed, ShedResult)
    assert shed.reason == "admission_deadline"
    # a zero/negative deadline is unmeetable regardless of any estimate
    shed0 = srv.submit(ImageRequest(rid=2, image=imgs[2], deadline_s=0.0))
    assert shed0.reason == "admission_deadline"
    # a comfortable deadline is admitted
    assert srv.submit(
        ImageRequest(rid=3, image=imgs[3], deadline_s=5.0)) is None


def test_deadline_expiry_ordering_under_injectable_clock(lenet):
    """Queued requests expire exactly when the clock passes each one's
    absolute deadline, in deadline order, as typed sheds — survivors
    keep FIFO order and are served."""
    net, eng, params, imgs = lenet
    clock = FakeClock()
    srv = _server(eng, params, max_batch=8, max_delay_s=100.0, clock=clock)
    srv.submit(ImageRequest(rid=0, image=imgs[0], deadline_s=1.0))
    srv.submit(ImageRequest(rid=1, image=imgs[1], deadline_s=3.0))
    srv.submit(ImageRequest(rid=2, image=imgs[2], deadline_s=0.5))
    clock.t = 0.6
    out = srv.step()                    # no flush: only the expiry runs
    assert [r.rid for r in out] == [2]
    assert isinstance(out[0], ShedResult)
    assert out[0].reason == "deadline_expired"
    assert out[0].waited_s == pytest.approx(0.6)
    clock.t = 1.2
    out = srv.step()
    assert [r.rid for r in out] == [0]
    assert srv.pending() == 1
    (served,) = srv.step(force=True)    # the survivor is served
    assert isinstance(served, ImageResult) and served.rid == 1
    s = srv.stats()
    assert s["expired"] == 2 and s["shed"] == 2 and s["served"] == 1


def test_default_deadline_applies_to_requests_without_one(lenet):
    net, eng, params, imgs = lenet
    clock = FakeClock()
    srv = _server(eng, params, max_batch=8, max_delay_s=100.0, clock=clock,
                  default_deadline_s=1.0)
    srv.submit(ImageRequest(rid=0, image=imgs[0]))                 # default
    srv.submit(ImageRequest(rid=1, image=imgs[1], deadline_s=9.0))  # override
    clock.t = 2.0
    out = srv.step()
    assert [r.rid for r in out] == [0]
    assert out[0].reason == "deadline_expired"


# ---------------------------------------------------------------------------
# supervised execution: retry, bisection, output validation
# ---------------------------------------------------------------------------


def test_retry_backoff_schedule(lenet):
    """Two scripted transient faults retry with capped exponential
    backoff through the injectable sleep, then succeed — the batch is
    served, nothing fails."""
    net, eng, params, imgs = lenet
    sleep = FakeSleep()
    inj = FaultInjector(FaultScript(transient_calls={0, 1}))
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, sleep=sleep,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(max_retries=2,
                                              backoff_base_s=0.01,
                                              backoff_cap_s=0.25))
    _submit(srv, imgs, range(2))
    srv.run_until_drained()
    assert all(isinstance(srv.done[r], ImageResult) for r in range(2))
    assert sleep.delays == [0.01, 0.02]       # base * 2**attempt
    s = srv.stats()
    assert s["retried"] == 2 and s["failed"] == 0
    assert inj.calls == 3                     # 2 faulted attempts + success


def test_backoff_is_capped(lenet):
    net, eng, params, imgs = lenet
    sleep = FakeSleep()
    inj = FaultInjector(FaultScript(transient_calls={0, 1, 2, 3}))
    srv = _server(eng, params, max_batch=1, max_delay_s=0.0, sleep=sleep,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(max_retries=4,
                                              backoff_base_s=0.1,
                                              backoff_cap_s=0.25))
    _submit(srv, imgs, [0])
    srv.run_until_drained()
    assert sleep.delays == [0.1, 0.2, 0.25, 0.25]   # capped, not 0.4/0.8
    assert isinstance(srv.done[0], ImageResult)


def test_transient_exhaustion_falls_back_to_bisection(lenet):
    """When retries are exhausted the batch bisects; sub-batches get a
    fresh retry budget, so a fault that clears mid-bisection still
    serves every request."""
    net, eng, params, imgs = lenet
    sleep = FakeSleep()
    inj = FaultInjector(FaultScript(transient_calls={0, 1, 2}))
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, sleep=sleep,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(max_retries=1,
                                              backoff_base_s=0.01))
    _submit(srv, imgs, range(2))
    srv.run_until_drained()
    # calls: 0 fail, 1 fail (budget out) -> bisect: 2 fail, 3 ok; 4 ok
    assert all(isinstance(srv.done[r], ImageResult) for r in range(2))
    s = srv.stats()
    assert s["retried"] == 2 and s["bisections"] == 1 and s["failed"] == 0


def test_poison_batch_bisection_isolates_exactly_one(lenet):
    """The acceptance scenario: one poison request in a batch of 4
    yields ONE typed FailedResult; every batchmate's result is
    byte-identical to a fault-free run (bisection sub-batches keep the
    parent's pow2 bucket, so the same compiled executable serves them)."""
    net, eng, params, imgs = lenet
    clean = _server(eng, params, max_batch=4, max_delay_s=0.0)
    _submit(clean, imgs, range(4), top_k=4)
    clean.run_until_drained()

    inj = FaultInjector(FaultScript(poison_rids={2}))
    srv = _server(eng, params, max_batch=4, max_delay_s=0.0,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(max_retries=2))
    _submit(srv, imgs, range(4), top_k=4)
    srv.run_until_drained()

    failed = [r for r in srv.done.values() if isinstance(r, FailedResult)]
    assert [f.rid for f in failed] == [2]
    assert failed[0].error == "engine_fault"
    assert "PersistentEngineFault" in failed[0].detail
    for r in (0, 1, 3):
        res = srv.done[r]
        assert isinstance(res, ImageResult)
        assert res.top_probs == clean.done[r].top_probs      # byte-identical
        assert res.top_indices == clean.done[r].top_indices
        assert res.bucket == 4          # bisection kept the parent bucket
    s = srv.stats()
    assert s["failed"] == 1 and s["served"] == 3 and s["bisections"] >= 1
    # persistent faults never consumed the retry budget
    assert s["retried"] == 0


def test_non_finite_output_row_becomes_typed_failure(lenet):
    """A corrupted output row (NaN) is detected and converted into a
    per-request failure — batchmates still get finite, correct top-k."""
    net, eng, params, imgs = lenet
    clean = _server(eng, params, max_batch=4, max_delay_s=0.0)
    _submit(clean, imgs, range(4))
    clean.run_until_drained()

    inj = FaultInjector(FaultScript(corrupt_rids={1}))
    srv = _server(eng, params, max_batch=4, max_delay_s=0.0,
                  fault_injector=inj)
    _submit(srv, imgs, range(4))
    srv.run_until_drained()
    res = srv.done[1]
    assert isinstance(res, FailedResult)
    assert res.error == "non_finite_output"
    for r in (0, 2, 3):
        assert srv.done[r].top_probs == clean.done[r].top_probs
        assert all(np.isfinite(srv.done[r].top_probs))
    assert srv.stats()["failed"] == 1


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def test_breaker_trips_sheds_and_resets(lenet):
    net, eng, params, imgs = lenet
    clock = FakeClock()
    # calls 0..5: two fully-failing steps (batch + 2 bisected singles
    # each); call 6+ clean so the half-open probe succeeds
    inj = FaultInjector(FaultScript(persistent_calls=frozenset(range(6))))
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, clock=clock,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(breaker_threshold=2,
                                              breaker_reset_s=10.0))
    _submit(srv, imgs, [0, 1])
    srv.step(force=True)
    assert srv.health()["breaker"] == "closed"
    assert srv.health()["consecutive_failures"] == 1
    _submit(srv, imgs, [2, 3])
    srv.step(force=True)                     # second failing step: trip
    h = srv.health()
    assert h["breaker"] == "open" and h["state"] == "unhealthy"
    assert srv.stats()["breaker_trips"] == 1
    # open breaker sheds at admission and serves nothing
    shed = srv.submit(ImageRequest(rid=4, image=imgs[4]))
    assert isinstance(shed, ShedResult) and shed.reason == "breaker_open"
    assert srv.step(force=True) == []
    # after the reset window: half-open probe, success closes
    clock.t = 11.0
    assert srv.submit(ImageRequest(rid=5, image=imgs[5])) is None
    (res,) = srv.step(force=True)
    assert isinstance(res, ImageResult) and res.rid == 5
    h = srv.health()
    assert h["breaker"] == "closed" and h["state"] == "healthy"
    assert h["consecutive_failures"] == 0


def test_breaker_reopens_on_failed_probe(lenet):
    net, eng, params, imgs = lenet
    clock = FakeClock()
    inj = FaultInjector(FaultScript(persistent_calls=frozenset(range(9))))
    srv = _server(eng, params, max_batch=1, max_delay_s=0.0, clock=clock,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(breaker_threshold=1,
                                              breaker_reset_s=5.0))
    _submit(srv, imgs, [0])
    srv.step(force=True)                     # trips immediately
    assert srv.health()["breaker"] == "open"
    clock.t = 6.0
    assert srv.submit(ImageRequest(rid=1, image=imgs[1])) is None
    srv.step(force=True)                     # half-open probe fails
    assert srv.health()["breaker"] == "open"
    assert srv.stats()["breaker_trips"] == 2


def test_run_until_drained_raises_when_wedged(lenet):
    """A wedged queue (breaker open, huge reset) must raise — not
    silently return with requests still pending."""
    net, eng, params, imgs = lenet
    inj = FaultInjector(FaultScript(persistent_calls=frozenset(range(3))))
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0,
                  fault_injector=inj,
                  supervisor=SupervisorConfig(breaker_threshold=1,
                                              breaker_reset_s=1e9))
    _submit(srv, imgs, range(4))
    with pytest.raises(ServerWedgedError, match="not drained") as ei:
        srv.run_until_drained(max_steps=5)
    assert ei.value.report["pending"] == 2
    assert ei.value.report["pending_rids"] == [2, 3]
    assert ei.value.report["health"]["breaker"] == "open"
    assert srv.pending() == 2


def test_stats_throughput_zero_not_inf(lenet):
    """Under a frozen clock busy_s is 0 — throughput must report 0.0,
    never inf."""
    net, eng, params, imgs = lenet
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0)
    _submit(srv, imgs, range(2))
    srv.run_until_drained()
    s = srv.stats()
    assert s["served"] == 2 and s["busy_s"] == 0.0
    assert s["throughput_rps"] == 0.0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_default_ladder_walks_down_to_unfused_floor():
    ladder = default_ladder(Method.ADVANCED_SIMD_8, fuse=True)
    assert ladder == (Rung(Method.ADVANCED_SIMD_8, True),
                      Rung(Method.ADVANCED_SIMD_4, True),
                      Rung(Method.BASIC_SIMD, True),
                      Rung(Method.BASIC_SIMD, False))
    # starting unfused, the basic_simd floor is not duplicated
    assert default_ladder(Method.BASIC_SIMD, fuse=False) == (
        Rung(Method.BASIC_SIMD, False),)


def test_controller_hysteresis_and_cooldown():
    ctl = DegradeController(default_ladder(), queue_high=4, degrade_after=3,
                            recover_after=2, cooldown=2)
    # pressure must be SUSTAINED: 2 hot observations + 1 calm -> nothing
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=0) is None
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=9) == "down"
    ctl.commit(1)
    # cooldown dead-band: pressure keeps accumulating but cannot move
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=9) is None
    assert ctl.observe(queue_depth=9) == "down"   # cooldown elapsed
    ctl.commit(2)
    # recovery needs its own sustained calm streak
    assert ctl.observe(queue_depth=0) is None     # cooldown
    assert ctl.observe(queue_depth=0) is None     # cooldown
    assert ctl.observe(queue_depth=0) == "up"     # calm streak >= 2
    ctl.commit(1)
    assert ctl.rung == 1 and ctl.moves == [1, 2, 1]


def test_controller_p95_slo_drift_is_pressure():
    ctl = DegradeController(default_ladder(), queue_high=100,
                            p95_slo_s=0.010, degrade_after=2, cooldown=0)
    assert ctl.observe(queue_depth=0, p95_s=0.030) is None
    assert ctl.observe(queue_depth=0, p95_s=0.030) == "down"
    # no p95 sample and an empty queue is calm
    assert ctl.pressured(queue_depth=0, p95_s=None) is False


def test_degradation_and_recovery_integration(lenet):
    """Sustained queue pressure walks the server down one verified rung
    (the engine's method really switches); sustained calm walks it back
    up — counters and health track both."""
    net, _, params, imgs = lenet
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)
    ladder = (Rung(Method.ADVANCED_SIMD_8, True),
              Rung(Method.ADVANCED_SIMD_4, True))
    ctl = DegradeController(ladder, queue_high=2, degrade_after=2,
                            recover_after=3, cooldown=0)
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, degrade=ctl)
    _submit(srv, imgs, range(8))
    srv.step(force=True)                     # pending 6 > 2: hot 1
    assert eng.method == Method.ADVANCED_SIMD_8
    srv.step(force=True)                     # pending 4 > 2: hot 2 -> down
    assert eng.method == Method.ADVANCED_SIMD_4      # verified switch stuck
    assert ctl.rung == 1
    assert srv.health()["state"] == "degraded"
    assert srv.stats()["degraded"] == 1
    # the committed rung was pre-validated: the live plan verifies clean
    assert not any(f.severity == "error" for f in eng.verify())
    srv.run_until_drained()
    # three calm observations (queue empty) walk it back up
    srv.step()
    srv.step()
    assert eng.method == Method.ADVANCED_SIMD_8
    assert ctl.rung == 0 and srv.stats()["recovered"] == 1
    assert srv.health()["state"] == "healthy"
    # every request was served despite the mid-stream replan
    assert all(isinstance(srv.done[r], ImageResult) for r in range(8))


def test_unverifiable_rung_is_skipped(lenet, monkeypatch):
    """A ladder rung whose plan fails static verification is never
    served: switch_verified rolls the knobs back and the server walks
    to the next rung."""
    net, _, params, imgs = lenet
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)

    def fake_verify(self, fuse=None):
        if self.method == Method.ADVANCED_SIMD_4:
            return [Finding("error", "plan", "V301", "injected bust")]
        return []

    monkeypatch.setattr(CNNEngine, "verify", fake_verify)
    ladder = (Rung(Method.ADVANCED_SIMD_8, True),
              Rung(Method.ADVANCED_SIMD_4, True),
              Rung(Method.BASIC_SIMD, True))
    ctl = DegradeController(ladder, queue_high=1, degrade_after=1,
                            cooldown=0)
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, degrade=ctl)
    _submit(srv, imgs, range(6))
    srv.step(force=True)                     # pressure -> down
    assert eng.method == Method.BASIC_SIMD   # skipped the rejected rung
    assert ctl.rung == 2
    rejected = [e for e in srv.events if e["kind"] == "rung_rejected"]
    assert len(rejected) == 1
    assert rejected[0]["rung"] == "advanced_simd_4/fused"


def test_switch_verified_rolls_back_on_error(lenet, monkeypatch):
    net, _, params, imgs = lenet
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)

    def fake_verify(self, fuse=None):
        if self.method == Method.ADVANCED_SIMD_4:
            return [Finding("error", "plan", "V301", "injected bust")]
        return []

    monkeypatch.setattr(CNNEngine, "verify", fake_verify)
    ok, findings = eng.switch_verified(method=Method.ADVANCED_SIMD_4)
    assert not ok and findings[0].rule == "V301"
    assert eng.method == Method.ADVANCED_SIMD_8      # rolled back
    ok, findings = eng.switch_verified(method=Method.BASIC_SIMD,
                                       fuse_pool=False)
    assert ok and eng.method == Method.BASIC_SIMD
    assert eng.fuse_pool is False
    with pytest.raises(ValueError, match="unknown knob"):
        eng.switch_verified(methd=Method.BASIC_SIMD)


def test_overload_burst_sheds_and_degrades(lenet):
    """The acceptance scenario: a scripted overload burst against a
    bounded queue triggers typed shedding AND at least one verified
    method-downgrade, all visible in stats()."""
    net, _, params, imgs = lenet
    eng = CNNEngine(net, method=Method.ADVANCED_SIMD_8)
    ladder = (Rung(Method.ADVANCED_SIMD_8, True),
              Rung(Method.ADVANCED_SIMD_4, True))
    ctl = DegradeController(ladder, queue_high=1, degrade_after=1,
                            recover_after=10 ** 9, cooldown=0)
    srv = _server(eng, params, max_batch=2, max_delay_s=0.0, max_queue=4,
                  degrade=ctl)
    sheds = [r for r in _submit(srv, imgs, range(10)) if r is not None]
    assert len(sheds) == 6                   # queue bound admits 4 of 10
    assert all(s.reason == "queue_full" for s in sheds)
    srv.run_until_drained()
    s = srv.stats()
    assert s["rejected"] == 6 and s["shed"] == 6
    assert s["degraded"] >= 1                # at least one verified downgrade
    assert s["served"] == 4
    assert eng.method == Method.ADVANCED_SIMD_4
    assert not any(f.severity == "error" for f in eng.verify())
    # shed requests resolved as typed results, served ones as ImageResults
    assert all(isinstance(srv.done[r], ShedResult) for r in range(4, 10))
    assert all(isinstance(srv.done[r], ImageResult) for r in range(4))
