"""Integration: training convergence, checkpoint roundtrip, serving engine
vs manual decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import TrainConfig, get_arch
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine
from repro.train.checkpoint import save_checkpoint, load_checkpoint
from repro.train.data import MarkovLM, batches
from repro.train.optimizer import adamw_init, lr_schedule, global_norm
from repro.train.step import make_train_step


def test_training_reduces_loss():
    cfg = dataclasses.replace(
        get_arch("internlm2-20b").reduced(), vocab_size=128)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40)
    step = jax.jit(make_train_step(model, tcfg, dp_size=1))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    it = batches(lm, 8, 64, seed=1)
    first = last = None
    for i in range(40):
        tokens, labels = next(it)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        params, opt, metrics = step(params, opt, batch)
        if i == 0:
            first = float(metrics["ce"])
        last = float(metrics["ce"])
    assert last < first - 0.1, (first, last)
    assert last > lm.entropy() - 0.05  # cannot beat the entropy floor


def test_markov_entropy_is_floor():
    lm = MarkovLM(32, seed=3)
    h = lm.entropy()
    assert 0 < h < np.log(32) + 1e-6


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("gemma2-2b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    save_checkpoint(tmp_path / "ck", params, opt, 7, {"arch": cfg.name})
    p2, o2, step, extra = load_checkpoint(tmp_path / "ck")
    assert step == 7 and extra["arch"] == cfg.name
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert jnp.array_equal(jnp.asarray(a, jnp.float32),
                               jnp.asarray(b, jnp.float32))


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), tcfg)) for s in
           (1, 10, 50, 100)]
    assert lrs[0] < lrs[1]  # warmup
    assert lrs[1] >= lrs[2] >= lrs[3]  # decay
    assert lrs[3] >= 0.09 * 1e-3  # 10% floor


def test_global_norm_clipping():
    tcfg = TrainConfig(grad_clip=1.0)
    big = {"w": jnp.full((10,), 100.0)}
    gn = float(global_norm(big))
    assert gn > 1.0


def test_serving_matches_manual_greedy_decode():
    """The engine's continuous-batching output must equal a hand-rolled
    prefill + greedy decode for the same prompt."""
    cfg = dataclasses.replace(
        get_arch("internlm2-20b").reduced(),
        dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [3, 1, 4, 1, 5]
    n_new = 6

    # manual loop
    cache = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32),
                                   model.init_cache(1, 64))
    logits, cache, _ = model.forward(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)},
        mode="prefill", cache=cache)
    manual = [int(jnp.argmax(logits[0, -1]))]
    for i in range(n_new - 1):
        pos = jnp.asarray([len(prompt) + i], jnp.int32)
        lg, cache = model.decode_step(
            params, jnp.asarray([[manual[-1]]], jnp.int32), pos, cache)
        manual.append(int(jnp.argmax(lg[0, 0])))

    eng = ServingEngine(model, params, max_batch=2, max_len=64)
    eng.submit(Request(0, prompt, max_new_tokens=n_new))
    done = eng.run_until_drained()
    assert done[0] == manual, (done[0], manual)


def test_serving_interleaves_requests():
    cfg = get_arch("gemma2-2b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_batch=2, max_len=64)
    for rid in range(4):  # more requests than slots
        eng.submit(Request(rid, [1 + rid, 2, 3], max_new_tokens=4))
    done = eng.run_until_drained()
    assert sorted(done) == [0, 1, 2, 3]
    assert all(len(v) == 4 for v in done.values())
