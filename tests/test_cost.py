"""Tests for the analytic cost model (``repro.core.cost``).

Pin the contracts the autotuner and the CI accuracy gate stand on:
per-step resource accounting (FLOPs, HBM bytes, VMEM working set),
fusion's byte savings being visible to the model, VMEM agreeing with the
verifier's resolved geometry, exact coefficient recovery on synthetic
data, the rank-correlation metric, the cost-model fusion gate wired
through ``compile_plan``, and the tuned-knobs deploy round-trip.
"""
import json

import pytest

from repro.analysis.verifier import step_band_params, verify_plan
from repro.core import deploy
from repro.core.cost import (
    FLOP_KEYS,
    CostModel,
    StepCost,
    fit_coefficients,
    fused_flop_key,
    fusion_cost_gate,
    plan_cost,
    spearman,
)
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.core.plan import (
    OH_BLOCK_CANDIDATES,
    compile_plan,
    knob_space,
)

SIMD = Method.ADVANCED_SIMD_8


# ------------------------------------------------- per-step accounting

def test_plan_cost_totals_are_step_sums():
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=True)
    pc = plan_cost(plan, batch=4)
    assert len(pc.steps) == len(plan.steps)
    assert pc.flops == sum(s.flops for s in pc.steps) > 0
    assert pc.hbm_bytes == sum(s.hbm_bytes for s in pc.steps) > 0
    assert pc.dispatches == sum(s.dispatches for s in pc.steps)


def test_flops_scale_linearly_with_batch():
    plan = compile_plan(NETWORKS["cifar10"](), method=SIMD, fuse=True)
    one, eight = plan_cost(plan, batch=1), plan_cost(plan, batch=8)
    assert eight.flops == pytest.approx(8 * one.flops)
    # weights stream once per dispatch regardless of batch, so bytes
    # grow sub-linearly
    assert one.hbm_bytes < eight.hbm_bytes < 8 * one.hbm_bytes


def test_fc_step_flops_are_two_matmul():
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=True)
    fc = next(s for st, s in zip(plan.steps, plan_cost(plan).steps)
              if st.kind == "fc")
    # lenet5 fc1: 50*4*4 -> 500
    assert fc.key == "fc"
    assert fc.flops == 2.0 * 800 * 500


def test_flatten_is_free():
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=False)
    flat = next(s for st, s in zip(plan.steps, plan_cost(plan).steps)
                if st.kind == "flatten")
    assert flat.flops == 0 and flat.hbm_bytes == 0 and flat.dispatches == 0


def test_fused_streams_fewer_bytes_and_dispatches_than_unfused():
    """The fusion win the model must see: no intermediate activations,
    one dispatch for the whole group."""
    net = NETWORKS["lenet5"]()
    fused = plan_cost(compile_plan(net, method=SIMD, fuse=True), batch=8)
    unfused = plan_cost(compile_plan(net, method=SIMD, fuse=False), batch=8)
    assert fused.hbm_bytes < unfused.hbm_bytes
    assert fused.dispatches < unfused.dispatches
    # arithmetic is conserved — fusion moves bytes, not FLOPs
    assert fused.flops == pytest.approx(unfused.flops)


def test_fused_steps_use_fused_coefficient_bucket():
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=True)
    for st, sc in zip(plan.steps, plan_cost(plan).steps):
        if st.kind in ("fused", "chain"):
            assert sc.key == fused_flop_key(SIMD)
            assert sc.key in FLOP_KEYS
        elif st.kind == "conv":
            assert sc.key == SIMD.value


def test_vmem_matches_verifier_resolved_geometry():
    """The model's feasibility resource must be the SAME cell bytes the
    static verifier audits — one geometry, two consumers."""
    plan = compile_plan(NETWORKS["alexnet"](), method=SIMD, fuse=True,
                        use_pallas=True)
    banded = 0
    for st, sc in zip(plan.steps, plan_cost(plan).steps):
        geo, _ = step_band_params(plan, st)
        if geo is not None and st.kind in ("conv", "fused", "chain"):
            banded += 1
            assert sc.vmem_bytes == int(geo["cell_bytes"]) > 0
    assert banded > 0


def test_overfetch_sees_carry_geometry():
    """A carry-enabled fused step runs one extra (prologue) band step but
    fetches ``carry`` fewer rows per step — the input charge must follow
    the carry geometry (steps x reduced band), not the classic
    n_tiles x band product."""
    from repro.core.cost import _overfetch

    kw = dict(method=SIMD, fuse=True, use_pallas=True,
              per_layer_fuse={"norm1": False, "norm2": False})
    net = NETWORKS["alexnet"]
    carry = compile_plan(net(), per_layer_pool_carry={"conv1": True}, **kw)
    classic = compile_plan(net(), per_layer_pool_carry={"conv1": False},
                           **kw)
    geo_c, _ = step_band_params(carry, carry.steps[0])
    geo_0, _ = step_band_params(classic, classic.steps[0])
    assert geo_c["carry"] > 0 and geo_c["steps"] == geo_c["n_tiles"] + 1
    assert geo_0["carry"] == 0 and geo_0["steps"] == geo_0["n_tiles"]
    assert geo_c["band"] == geo_0["band"] - geo_c["carry"]
    assert _overfetch(geo_c) == pytest.approx(
        geo_c["steps"] * geo_c["band"] / geo_c["padded_h"])
    assert _overfetch(geo_c) != _overfetch(geo_0)


def test_xla_path_charges_no_overfetch_and_no_vmem():
    plan = compile_plan(NETWORKS["alexnet"](), method=SIMD, fuse=True,
                        use_pallas=False)
    for sc in plan_cost(plan).steps:
        assert sc.vmem_bytes == 0


# ------------------------------------------------------------ CostModel

def test_unit_model_prices_all_buckets():
    m = CostModel.unit()
    assert set(m.us_per_gflop) == set(FLOP_KEYS)
    # 1 GFLOP + 1 GB + 1 dispatch = 3 us under unit coefficients
    assert m.predict({"fc": 1e9}, 1e9, 1) == pytest.approx(3.0)


def test_unknown_bucket_falls_back_to_other():
    m = CostModel(backend="t", us_per_gflop={"other": 7.0},
                  us_per_gb=0.0, dispatch_us=0.0)
    assert m.predict({"mystery": 1e9}, 0.0, 0) == pytest.approx(7.0)


def test_model_load_roundtrip_and_backend_fallback(tmp_path):
    m = CostModel(backend="cpu",
                  us_per_gflop={k: 2.0 for k in FLOP_KEYS},
                  us_per_gb=3.0, dispatch_us=4.0)
    p = tmp_path / "COST_MODEL.json"
    p.write_text(json.dumps({"format_version": 1,
                             "backends": {"cpu": m.to_dict()}}))
    back = CostModel.load(str(p), backend="cpu")
    assert back.to_dict() == m.to_dict()
    # an exact match records no substitution
    assert back.fallback_from is None
    # a backend with no fitted entry falls back to the sole fitted one,
    # and the substitution is RECORDED — never silent (the requested
    # backend is kept so reports can flag the borrowed coefficients)
    tpu = CostModel.load(str(p), backend="tpu")
    assert tpu.backend == "cpu"
    assert tpu.us_per_gb == 3.0
    assert tpu.fallback_from == "tpu"


def test_fallback_surfaces_in_plan_cost(tmp_path):
    """plan_cost built from a fallback model must carry the provenance
    through to the rendered table."""
    m = CostModel(backend="cpu",
                  us_per_gflop={k: 2.0 for k in FLOP_KEYS},
                  us_per_gb=3.0, dispatch_us=4.0)
    p = tmp_path / "COST_MODEL.json"
    p.write_text(json.dumps({"format_version": 1,
                             "backends": {"cpu": m.to_dict()}}))
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=True)
    borrowed = CostModel.load(str(p), backend="tpu")
    pc = plan_cost(plan, borrowed, batch=2)
    assert pc.model_backend == "cpu"
    assert pc.model_fallback_from == "tpu"
    assert "cross-backend fallback" in pc.table_markdown()
    # an exact-match model renders no fallback note
    exact = plan_cost(plan, CostModel.load(str(p), backend="cpu"), batch=2)
    assert exact.model_fallback_from is None
    assert "fallback" not in exact.table_markdown()


def test_committed_model_loads_and_prices():
    """The repo-root COST_MODEL.json must stay loadable and produce
    finite positive predictions for every bundled net."""
    m = CostModel.load()
    for name in NETWORKS:
        plan = compile_plan(NETWORKS[name](), method=SIMD, fuse=True)
        us = plan_cost(plan, m, batch=8).us
        assert us > 0


# ------------------------------------------------------------- spearman

def test_spearman_perfect_and_inverted():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)


def test_spearman_is_rank_only():
    # wildly nonlinear but monotone -> still 1.0
    assert spearman([1, 2, 3, 4], [1, 100, 1e4, 1e8]) == pytest.approx(1.0)


def test_spearman_degenerate_and_mismatch():
    assert spearman([1.0], [2.0]) == 0.0
    assert spearman([1, 2, 3], [5, 5, 5]) == 0.0
    with pytest.raises(ValueError):
        spearman([1, 2], [1, 2, 3])


def test_spearman_ties_average():
    assert spearman([1, 2, 2, 3], [1, 2, 2, 3]) == pytest.approx(1.0)


# ------------------------------------------------------- fitting (NNLS)

def test_fit_recovers_known_coefficients():
    """A consistent synthetic system — us generated from known positive
    coefficients — must be recovered (near-)exactly by the relative
    least-squares fit."""
    a, b, gb, disp = 120.0, 40.0, 10.0, 2.0
    rows = []
    feats = [(1e9, 0.0, 1e9, 3), (0.0, 2e9, 2e9, 5), (3e9, 1e9, 0.5e9, 2),
             (2e9, 2e9, 4e9, 8), (5e9, 0.5e9, 1e9, 1), (0.5e9, 4e9, 3e9, 6)]
    for fa, fb, hbm, d in feats:
        us = a * fa * 1e-9 + b * fb * 1e-9 + gb * hbm * 1e-9 + disp * d
        rows.append({"flops_by_key": {"basic_simd": fa,
                                      "advanced_simd_8": fb},
                     "hbm_bytes": hbm, "dispatches": d, "us": us})
    m = fit_coefficients(rows, backend="synthetic")
    assert m.us_per_gflop["basic_simd"] == pytest.approx(a, rel=1e-6)
    assert m.us_per_gflop["advanced_simd_8"] == pytest.approx(b, rel=1e-6)
    assert m.us_per_gb == pytest.approx(gb, rel=1e-6)
    assert m.dispatch_us == pytest.approx(disp, rel=1e-6)
    for r in rows:
        assert m.predict(r["flops_by_key"], r["hbm_bytes"],
                         r["dispatches"]) == pytest.approx(r["us"], rel=1e-6)


def test_fit_unobserved_buckets_get_conservative_fallback():
    rows = [{"flops_by_key": {"basic_simd": f}, "hbm_bytes": 0.0,
             "dispatches": 0, "us": 50.0 * f * 1e-9}
            for f in (1e9, 2e9, 4e9)]
    m = fit_coefficients(rows, backend="t")
    assert m.us_per_gflop["basic_simd"] == pytest.approx(50.0, rel=1e-6)
    # never-measured methods price at the LARGEST fitted coefficient —
    # expensive until proven otherwise, so the tuner never chases them
    assert m.us_per_gflop["seq_ref"] == pytest.approx(
        m.us_per_gflop["basic_simd"])
    assert set(m.us_per_gflop) == set(FLOP_KEYS)


def test_fit_never_emits_negative_coefficients():
    # an inconsistent system that plain lstsq resolves with a negative
    # coefficient — the pruning loop must drop it instead
    rows = [
        {"flops_by_key": {"basic_simd": 1e9, "advanced_simd_8": 1e9},
         "hbm_bytes": 1e9, "dispatches": 1, "us": 100.0},
        {"flops_by_key": {"basic_simd": 2e9, "advanced_simd_8": 2e9},
         "hbm_bytes": 2e9, "dispatches": 2, "us": 180.0},
        {"flops_by_key": {"basic_simd": 1e9, "advanced_simd_8": 3e9},
         "hbm_bytes": 1e9, "dispatches": 4, "us": 90.0},
    ]
    m = fit_coefficients(rows, backend="t")
    assert all(v >= 0 for v in m.us_per_gflop.values())
    assert m.us_per_gb >= 0 and m.dispatch_us >= 0


# --------------------------------------------------- cost gate in plans

def test_cost_gate_unit_model_matches_default_grouping():
    """Under unit coefficients fusion always saves bytes + dispatches at
    equal FLOPs, so the gated plan reproduces the heuristic grouping."""
    net = NETWORKS["alexnet"]()
    default = compile_plan(net, method=SIMD, fuse=True)
    gated = compile_plan(net, method=SIMD, fuse=True,
                         cost_gate=fusion_cost_gate(batch=8))
    assert ([s.kind for s in gated.steps]
            == [s.kind for s in default.steps])
    assert not verify_plan(gated)


def test_cost_gate_can_decline_all_fusion():
    """A model that prices fused dispatches punitively must push the
    planner down its fallback ladder to a fully unfused plan — the
    decision the raw VMEM check structurally cannot make."""
    coeffs = {k: 1.0 for k in FLOP_KEYS}
    for meth in (Method.BASIC_SIMD, Method.ADVANCED_SIMD_4,
                 Method.ADVANCED_SIMD_8):
        coeffs[fused_flop_key(meth)] = 1e6
    punitive = CostModel(backend="t", us_per_gflop=coeffs,
                         us_per_gb=1.0, dispatch_us=1.0)
    plan = compile_plan(NETWORKS["lenet5"](), method=SIMD, fuse=True,
                        cost_gate=fusion_cost_gate(punitive, batch=8))
    kinds = {s.kind for s in plan.steps}
    assert "fused" not in kinds and "chain" not in kinds
    assert "conv" in kinds
    assert not verify_plan(plan)


def test_cost_gate_pallas_still_enforces_vmem():
    """The cost gate composes WITH the VMEM feasibility check on the
    Pallas path — a fast-but-infeasible group must not be admitted."""
    net = NETWORKS["alexnet"]()
    plan = compile_plan(net, method=SIMD, fuse=True, use_pallas=True,
                        cost_gate=fusion_cost_gate(use_pallas=True))
    assert not [f for f in verify_plan(plan) if f.severity == "error"]


# ----------------------------------------------------------- knob space

def test_knob_space_axes():
    net = NETWORKS["lenet5"]()
    space = knob_space(net)
    assert set(space) == {"conv1", "pool1", "conv2", "pool2"}
    c1 = space["conv1"]
    assert all(m in c1["methods"] for m in (Method.BASIC_SIMD,
                                            Method.ADVANCED_SIMD_4,
                                            Method.ADVANCED_SIMD_8))
    # oh_block candidates stay below the layer's output height (24)
    assert None in c1["oh_blocks"]
    assert all(b < 24 for b in c1["oh_blocks"] if b is not None)
    assert set(b for b in c1["oh_blocks"] if b is not None) <= \
        set(OH_BLOCK_CANDIDATES)
    assert c1["fuse"] == [True, False]
    assert space["pool1"] == {"fuse": [True, False]}


# -------------------------------------------- tuned-knobs deploy round-trip

TUNED = {
    "method": Method.ADVANCED_SIMD_8,
    "per_layer_methods": {"conv1": Method.ADVANCED_SIMD_4},
    "oh_block": None,
    "per_layer_oh_blocks": {"conv2": 8},
    "fuse": True,
    "fuse_relu": True,
    "per_layer_fuse": {"pool2": False},
    "use_pallas": False,
}


def test_knobs_manifest_roundtrip():
    d = deploy.knobs_to_manifest(TUNED)
    json.dumps(d)  # must be json-serializable as-is
    back = deploy.knobs_from_manifest(d)
    assert back == TUNED
    assert isinstance(back["method"], Method)
    assert isinstance(back["per_layer_methods"]["conv1"], Method)


def test_knobs_to_manifest_rejects_unknown_keys():
    with pytest.raises(ValueError):
        deploy.knobs_to_manifest({**TUNED, "warp_speed": 9})


def test_deploy_tuned_plan_roundtrip(tmp_path, lenet_params):
    net = NETWORKS["lenet5"]()
    out = tmp_path / "tuned"
    deploy.save_model(out, net, lenet_params, tuned=TUNED)
    assert deploy.load_tuned_knobs(out) == TUNED
    # load_model recompiles + verifies the tuned plan on load
    net2, params2, _extra = deploy.load_model(out)
    assert net2.name == net.name
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["tuned_plan"] == deploy.knobs_to_manifest(TUNED)
    engine, _, knobs = deploy.load_engine(out)
    assert knobs == TUNED
    plan = engine.plan()
    assert any(s.method == Method.ADVANCED_SIMD_4 for s in plan.steps
               if "conv1" in s.names)


def test_deploy_without_tuned_plan_stays_compatible(tmp_path, lenet_params):
    net = NETWORKS["lenet5"]()
    out = tmp_path / "plain"
    deploy.save_model(out, net, lenet_params)
    assert deploy.load_tuned_knobs(out) is None
    engine, _, knobs = deploy.load_engine(out)
    assert knobs is None


@pytest.fixture(scope="module")
def lenet_params():
    import jax

    from repro.core.engine import CNNEngine

    return CNNEngine(NETWORKS["lenet5"]()).init(jax.random.PRNGKey(0))
