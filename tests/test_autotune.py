"""Tests for the offline autotuner (``tools/autotune.py``).

The acceptance triple the ISSUE gates on — for the tuned plan of at
least alexnet: (a) zero error findings from the static verifier, (b) a
byte-exact knob round-trip through the deploy manifest, (c) modelled
cost no worse than the default heuristic plan's — plus the search
invariants (monotone improvement, verified candidates only) and the CLI
exit codes CI relies on.
"""
import importlib.util
import json
import pathlib

import pytest

from repro.analysis.verifier import verify_plan
from repro.core import deploy
from repro.core.cost import CostModel, plan_cost
from repro.core.netdefs import NETWORKS
from repro.core.plan import compile_plan

_TOOL = pathlib.Path(__file__).resolve().parent.parent / "tools" / \
    "autotune.py"
_spec = importlib.util.spec_from_file_location("autotune", _TOOL)
autotune = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(autotune)


@pytest.fixture(scope="module")
def model():
    return CostModel.load()  # the committed repo-root COST_MODEL.json


@pytest.fixture(scope="module")
def lenet_result(model):
    return autotune.tune(NETWORKS["lenet5"](), model, batch=8, passes=1)


@pytest.fixture(scope="module")
def alexnet_result(model):
    return autotune.tune(NETWORKS["alexnet"](), model, batch=8, passes=1)


# ------------------------------------------------------ search invariants

def test_tuned_cost_never_exceeds_default(lenet_result, alexnet_result):
    for r in (lenet_result, alexnet_result):
        assert r["cost"].us <= r["default_cost"].us


def test_decisions_are_monotone_improvements(alexnet_result):
    for mv in alexnet_result["decisions"]:
        assert mv["us_after"] < mv["us_before"]


def test_default_knobs_compile_to_default_cost(model):
    """The search baseline IS the heuristic plan — knob identity, not
    just cost equality."""
    net = NETWORKS["lenet5"]()
    knobs = autotune.default_knobs()
    plan = compile_plan(net, verify=True, **knobs)
    ref = compile_plan(net)
    assert [s.kind for s in plan.steps] == [s.kind for s in ref.steps]


# --------------------------------------------- acceptance triple (alexnet)

def test_alexnet_tuned_plan_verifies_clean(alexnet_result):
    errors = [f for f in verify_plan(alexnet_result["plan"])
              if f.severity == "error"]
    assert errors == []


def test_alexnet_knobs_roundtrip_byte_exact(alexnet_result):
    knobs = alexnet_result["knobs"]
    d = deploy.knobs_to_manifest(knobs)
    assert (json.dumps(d, sort_keys=True)
            == json.dumps(deploy.knobs_to_manifest(
                deploy.knobs_from_manifest(d)), sort_keys=True))


def test_alexnet_reconstructed_cost_not_worse(alexnet_result, model):
    """Recompile from the serialized knobs alone — the reconstructed
    plan must price at (not above) the searched plan's cost."""
    knobs = deploy.knobs_from_manifest(
        deploy.knobs_to_manifest(alexnet_result["knobs"]))
    plan = compile_plan(NETWORKS["alexnet"](), verify=True, **knobs)
    us = plan_cost(plan, model, batch=8).us
    assert us <= alexnet_result["default_cost"].us * (1 + 1e-6)
    assert us == pytest.approx(alexnet_result["cost"].us)


def test_write_and_check_full_artifact_gate(lenet_result, model, tmp_path):
    """The tool's own self-check (save → reload → verify → re-price)
    must pass end to end on a real artifact."""
    out = tmp_path / "tuned-lenet5"
    assert autotune.write_and_check(lenet_result, model, str(out)) == 0
    assert deploy.load_tuned_knobs(out) == lenet_result["knobs"]
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["extra"]["autotune"]["modelled_us"] == \
        round(lenet_result["cost"].us, 1)


# -------------------------------------------------------------- rendering

def test_decision_table_renders(lenet_result, model):
    table = autotune.decision_table(lenet_result, model)
    assert table.startswith("### Autotune — lenet5")
    assert "| step | kind | method | oh_block | fused into | pred us |" \
        in table
    assert "default heuristic plan" in table
    assert "tuned plan" in table


# ------------------------------------------------------------- CLI gates

def test_main_unknown_net_exits_two(capsys):
    assert autotune.main(["--net", "resnet152"]) == 2
    assert "unknown network" in capsys.readouterr().err


def test_main_unloadable_model_exits_two(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text("{")
    assert autotune.main(["--net", "lenet5", "--model", str(bad)]) == 2
    assert "cannot load cost model" in capsys.readouterr().err


def test_main_smoke_writes_json_record(tmp_path):
    rec_path = tmp_path / "tune.json"
    assert autotune.main(["--net", "lenet5", "--smoke",
                          "--json", str(rec_path)]) == 0
    rec = json.loads(rec_path.read_text())
    assert rec["net"] == "lenet5"
    assert rec["modelled_us"] <= rec["default_modelled_us"]
    assert "tuned_plan" in rec
