"""Spatial (oh-band) tiling correctness for the Pallas conv ladder.

All three conv methods vs ``conv2d_ref`` across stride/padding combos,
non-multiple-of-8 channel counts, and frames large enough to force
multiple oh-tiles (interpret mode) — including a 512×512×64 frame whose
padded activations exceed the VMEM budget the seed kernel assumed.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.conv2d.kernels import VMEM_BUDGET_BYTES, auto_oh_block
from repro.kernels.conv2d.ops import conv2d as conv2d_pallas
from repro.kernels.conv2d.ref import conv2d_ref

METHODS = ("basic_parallel", "basic_simd", "advanced_simd_128")


def _case(n, c, h, w_, oc, k, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, c, h, w_),
                          jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (oc, c, k, k)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(seed + 2), (oc,))
    return x, w, b


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1, 2])
def test_conv2d_stride_padding_sweep(method, stride, pad):
    x, w, b = _case(2, 5, 14, 14, 7, 3)  # 5 in / 7 out: not multiples of 8
    ref = conv2d_ref(x, w, b, (stride, stride), (pad, pad), relu=True)
    out = conv2d_pallas(x, w, b, (stride, stride), (pad, pad), relu=True,
                        method=method, interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ("basic_simd", "advanced_simd_128"))
@pytest.mark.parametrize("oh_block", [1, 3, 8, 64])
def test_conv2d_explicit_oh_blocks(method, oh_block):
    """Every band size — including ragged last tiles (17 % 3 != 0) and
    bands larger than the frame — matches the untiled reference."""
    x, w, b = _case(1, 6, 17, 13, 10, 3)
    ref = conv2d_ref(x, w, b, (1, 1), (1, 1), relu=True)
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=True, method=method,
                        oh_block=oh_block, interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


@pytest.mark.parametrize("method", ("basic_simd", "advanced_simd_128"))
@pytest.mark.parametrize("stride", [1, 2])
def test_conv2d_multi_tile_strided(method, stride):
    """Multiple oh-tiles with stride: each band's input offset is
    stride-aware (band t starts at t*oh_block*stride input rows)."""
    x, w, b = _case(1, 4, 40, 20, 6, 5)
    ref = conv2d_ref(x, w, b, (stride, stride), (2, 2), relu=False)
    out = conv2d_pallas(x, w, b, (stride, stride), (2, 2), relu=False,
                        method=method, oh_block=7, interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_conv2d_large_frame_multi_tile():
    """The acceptance shape: a 512×512×64 NHWC frame.  The whole padded
    frame (514×514×64 fp32 ≈ 67 MB) cannot be staged in VMEM; the auto
    heuristic must split it into several oh-bands, and the result must
    still match the reference."""
    x, w, b = _case(1, 64, 512, 512, 16, 3, seed=7)
    w = w * 0.5  # keep values O(1) so 1e-4 abs tolerance is meaningful
    ref = conv2d_ref(x, w, b, (1, 1), (1, 1), relu=True)
    # the frame the seed kernel would have staged whole:
    frame_bytes = 514 * 514 * 64 * 4
    assert frame_bytes > VMEM_BUDGET_BYTES
    # the geometry the kernel executes: oc_block clamps to min(128, oc)=16
    ohb = auto_oh_block(512, 512, 514, 64, 3, 3, 1, 16)
    assert ohb < 512  # the heuristic actually tiles this frame
    out = conv2d_pallas(x, w, b, (1, 1), (1, 1), relu=True,
                        method="advanced_simd_128", interpret=True)
    assert jnp.max(jnp.abs(out - ref)) < 1e-4


def test_auto_oh_block_monotone_and_bounded():
    """Auto bands stay within the frame, and shrinking the budget never
    grows the band."""
    prev = None
    for budget in (64 * 2**20, 8 * 2**20, 1 * 2**20, 64 * 1024):
        ohb = auto_oh_block(256, 256, 258, 64, 3, 3, 1, 128, budget=budget)
        assert 1 <= ohb <= 256
        if prev is not None:
            assert ohb <= prev
        prev = ohb
    # small frames fall back to a single whole-frame tile under a big budget
    assert auto_oh_block(13, 13, 15, 8, 3, 3, 1, 128) == 13
