"""Training launcher.

Runs real optimization steps of any registered arch (full or ``--reduced``)
on the available mesh.  On this CPU container the practical configuration
is a reduced arch on the 1×1 test mesh — the same sharded code paths as the
production mesh, which is exercised shape-only by ``dryrun.py``.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import TrainConfig, get_arch
from repro.launch.mesh import make_test_mesh
from repro.models.registry import get_model
from repro.sharding.auto import rules_for
from repro.sharding.ctx import activation_sharding
from repro.core.config import TINY_MESH
from repro.train.checkpoint import save_checkpoint
from repro.train.data import MarkovLM, batches
from repro.train.optimizer import adamw_init
from repro.train.step import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                      total_steps=args.steps)

    mesh = make_test_mesh()
    rules, _ = rules_for(cfg, TINY_MESH, None)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(
        model, tcfg, dp_size=1, microbatches=args.microbatches))

    lm = MarkovLM(cfg.vocab_size, seed=args.seed)
    floor = lm.entropy()
    print(f"[train] {cfg.name}: {sum(x.size for x in jax.tree_util.tree_leaves(params))/1e6:.1f}M params, "
          f"CE floor (markov entropy) = {floor:.3f} nats")

    it = batches(lm, args.batch, args.seq, seed=args.seed + 1)
    history = []
    t0 = time.time()
    with mesh, activation_sharding(("data", "model"), rules):
        for step in range(1, args.steps + 1):
            tokens, labels = next(it)
            extra = {}
            if cfg.family == "vlm":
                extra["media_embeds"] = jnp.zeros(
                    (args.batch, cfg.cross_attn.num_media_tokens,
                     cfg.cross_attn.media_dim), jnp.bfloat16)
            if cfg.family == "audio":
                extra["frames"] = jnp.zeros(
                    (args.batch, cfg.cross_attn.num_media_tokens,
                     cfg.cross_attn.media_dim), jnp.bfloat16)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels), **extra}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % args.log_every == 0 or step == 1:
                ce = float(metrics["ce"])
                history.append((step, ce))
                print(f"  step {step:5d}  ce={ce:.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.2f}  "
                      f"({(time.time()-t0)/step:.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt, args.steps,
                        {"arch": cfg.name, "reduced": args.reduced})
        print(f"[train] checkpoint -> {args.ckpt}")
    return {"history": history, "floor": floor}


if __name__ == "__main__":
    main()
