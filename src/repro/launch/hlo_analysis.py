"""Post-optimization HLO analysis: FLOPs, HBM bytes, collective bytes.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a
``while`` body ONCE, so a 64-layer ``lax.scan`` model is undercounted 64×
(verified empirically — see EXPERIMENTS.md §Dry-run).  This module parses
``compiled.as_text()`` and multiplies every computation's costs by its
loop trip count (read from the ``known_trip_count`` backend config, falling
back to the loop-condition constant).

Costs:
* flops — 2·B·M·N·K per dot (parsed from operand shapes + contracting/batch
  dims); 1 flop/element for top-level elementwise arithmetic.
* bytes — operand + result bytes of instructions at "real" computation
  level (entry / while bodies / called computations).  Fusion internals are
  not counted (a fusion's operands/results approximate its HBM traffic),
  matching the semantics of XLA's bytes-accessed.
* collectives — result bytes per op kind (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), trip-multiplied.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*(?P<type>.+?)"
    r"\s(?P<op>[a-z][a-z0-9\-]*)\((?P<rest>.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\((?P<params>.*)\)\s+->")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _split_top(s: str) -> List[str]:
    """Split on top-level commas (balanced parens/braces/brackets)."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _balanced_args(rest: str) -> Tuple[str, str]:
    """rest starts after the opening '(' of op(...).  Returns (args, attrs)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


@dataclass
class Instr:
    name: str
    op: str
    type_str: str
    args: str
    attrs: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # name -> type str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # instr -> type str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("//", "HloModule")):
            continue
        if stripped.endswith("{") and ("=" not in stripped.split("(")[0]):
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group("name"))
                comps[cur.name] = cur
                for p in _split_top(m.group("params")):
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        cur.params[pname.strip().lstrip("%")] = ptype.strip()
                        cur.shapes[pname.strip().lstrip("%")] = ptype.strip()
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        args, attrs = _balanced_args(m.group("rest"))
        ins = Instr(m.group("name"), m.group("op"), m.group("type"), args, attrs)
        cur.instrs.append(ins)
        cur.shapes[ins.name] = ins.type_str
    return comps


def _operand_names(args: str) -> List[str]:
    return [a.lstrip("%") for a in re.findall(r"%([\w.\-]+)", args)]


def _dims_attr(attrs: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', ins.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest integer constant in the condition computation
    m = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
    if m and m.group(1) in comps:
        best = 1
        for i in comps[m.group(1)].instrs:
            c = re.match(r"constant\((\d+)\)", i.op + "(" + i.args + ")")
            cm = re.search(r"constant\((\d+)\)", "constant(" + i.args + ")") \
                if i.op == "constant" else None
            if cm:
                best = max(best, int(cm.group(1)))
        return best
    return 1


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "select",
    "compare", "and", "or", "not", "floor", "ceil", "sign", "cosine", "sine",
    "clamp", "convert", "reduce", "reduce-window",
}


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(ins.args)
    if len(ops) < 2:
        return 0.0
    lhs = _shape_dims(shapes.get(ops[0], ""))
    rhs = _shape_dims(shapes.get(ops[1], ""))
    out = _shape_dims(ins.type_str)
    if lhs is None or rhs is None or out is None:
        return 0.0
    lc = _dims_attr(ins.attrs, "lhs_contracting_dims")
    k = 1
    for d in lc:
        if d < len(lhs[1]):
            k *= lhs[1][d]
    out_n = 1
    for d in out[1]:
        out_n *= d
    return 2.0 * out_n * max(k, 1)


def _conv_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = _operand_names(ins.args)
    if len(ops) < 2:
        return 0.0
    rhs = _shape_dims(shapes.get(ops[1], ""))
    out = _shape_dims(ins.type_str)
    if rhs is None or out is None:
        return 0.0
    out_n = 1
    for d in out[1]:
        out_n *= d
    kernel_n = 1
    for d in rhs[1]:
        kernel_n *= d
    # per output element: kernel spatial*in_ch MACs ~= kernel_n / out_channels
    # (approximation: assumes standard dim ordering)
    oc = out[1][-1] if out[1] else 1
    return 2.0 * out_n * max(kernel_n // max(oc, 1), 1)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    def scaled(self, k: float) -> "HloCosts":
        out = HloCosts(self.flops * k, self.bytes * k)
        for t, v in self.coll_bytes.items():
            out.coll_bytes[t] = v * k
        for t, v in self.coll_count.items():
            out.coll_count[t] = int(v * k)
        return out

    def add(self, other: "HloCosts") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for t, v in other.coll_bytes.items():
            self.coll_bytes[t] += v
        for t, v in other.coll_count.items():
            self.coll_count[t] += v

    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.coll_bytes),
            "collective_count": dict(self.coll_count),
            "collective_bytes_total": self.total_coll_bytes(),
        }


def _flops_only(comp: Computation, comps, memo) -> float:
    """FLOPs inside fusion computations (dots are rare there but possible)."""
    key = ("f", comp.name)
    if key in memo:
        return memo[key]
    total = 0.0
    for ins in comp.instrs:
        if ins.op == "dot":
            total += _dot_flops(ins, comp.shapes)
        elif ins.op == "convolution":
            total += _conv_flops(ins, comp.shapes)
        elif ins.op in _ELEMENTWISE:
            total += shape_bytes(ins.type_str) / max(
                DTYPE_BYTES.get((_shape_dims(ins.type_str) or ("f32",))[0], 4), 1
            )
        elif ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
            if m and m.group(1) in comps:
                total += _flops_only(comps[m.group(1)], comps, memo)
    memo[key] = total
    return total


def analyze_computation(
    comp: Computation, comps: Dict[str, Computation], memo=None
) -> HloCosts:
    memo = {} if memo is None else memo
    key = ("c", comp.name)
    if key in memo:
        return memo[key]
    costs = HloCosts()
    for ins in comp.instrs:
        if ins.op == "while":
            m = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            trip = _trip_count(ins, comps)
            if m and m.group(1) in comps:
                body = analyze_computation(comps[m.group(1)], comps, memo)
                costs.add(body.scaled(trip))
        elif ins.op in ("call", "conditional", "async-start"):
            for m in re.finditer(
                r"(?:to_apply|calls|branch_computations=\{[^}]*|called_computations=\{[^}]*)"
                r"=?%?([\w.\-]+)", ins.attrs
            ):
                if m.group(1) in comps:
                    costs.add(analyze_computation(comps[m.group(1)], comps, memo))
        elif ins.op in COLLECTIVES or any(
            ins.op.startswith(c) for c in COLLECTIVES
        ):
            kind = next(c for c in COLLECTIVES if ins.op.startswith(c))
            b = shape_bytes(ins.type_str)
            costs.coll_bytes[kind] += b
            costs.coll_count[kind] += 1
            costs.bytes += 2 * b
        elif ins.op == "fusion":
            costs.bytes += shape_bytes(ins.type_str)
            for o in _operand_names(ins.args):
                costs.bytes += shape_bytes(comp.shapes.get(o, ""))
            m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
            if m and m.group(1) in comps:
                costs.flops += _flops_only(comps[m.group(1)], comps, memo)
        elif ins.op == "dot":
            costs.flops += _dot_flops(ins, comp.shapes)
            costs.bytes += shape_bytes(ins.type_str)
            for o in _operand_names(ins.args):
                costs.bytes += shape_bytes(comp.shapes.get(o, ""))
        elif ins.op == "convolution":
            costs.flops += _conv_flops(ins, comp.shapes)
            costs.bytes += shape_bytes(ins.type_str)
        elif ins.op in ("copy", "transpose", "reshape", "broadcast", "concatenate",
                        "slice", "dynamic-slice", "dynamic-update-slice", "gather",
                        "scatter", "sort", "iota", "pad", "reverse", "custom-call",
                        "bitcast", "tuple", "get-tuple-element", "parameter",
                        "constant", "rng", "partition-id", "replica-id"):
            if ins.op in ("bitcast", "tuple", "get-tuple-element", "parameter",
                          "constant", "iota", "partition-id", "replica-id"):
                continue  # no HBM traffic
            costs.bytes += shape_bytes(ins.type_str)
            for o in _operand_names(ins.args):
                costs.bytes += shape_bytes(comp.shapes.get(o, ""))
        elif ins.op in _ELEMENTWISE:
            n = shape_bytes(ins.type_str)
            costs.flops += n / max(
                DTYPE_BYTES.get((_shape_dims(ins.type_str) or ("f32",))[0], 4), 1
            )
            costs.bytes += 2 * n
    memo[key] = costs
    return costs


def analyze_hlo_text(text: str) -> HloCosts:
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group("name")
            break
    if entry is None or entry not in comps:
        # fall back: computation with most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    return analyze_computation(comps[entry], comps)
