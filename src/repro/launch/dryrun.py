import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA:CPU float-normalization + while-loop LICM hoists bf16->f32
    # converts of whole scan-saved stacks (params included) out of loops,
    # inflating per-device memory ~3x with fp32 copies that do not exist on
    # TPU (native bf16).  Disabling LICM keeps the CPU lowering's memory
    # profile representative of the TPU target (EXPERIMENTS.md §Dry-run).
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, compiles, fits, and report its cost terms.

For each combination this lowers the right step function —
``train_step`` (train_4k), ``prefill`` (prefill_32k) or ``serve_step``
(decode_32k / long_500k) — against ShapeDtypeStruct stand-ins with the
production shardings, compiles it, and records:

* ``compiled.memory_analysis()``  — proves the working set fits 16 GB/chip;
* ``compiled.cost_analysis()``    — XLA's own numbers (while-body counted
  once — kept for reference);
* trip-count-corrected FLOPs / bytes / collective bytes from the
  post-optimization HLO (repro.launch.hlo_analysis) — the numbers the
  roofline in EXPERIMENTS.md §Roofline uses.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
      [--multi-pod] [--out results/dryrun] [--all] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

#: per-device bf16 KV-cache footprint above which decode shapes switch to
#: int8 KV quantization (documented beyond-paper serving optimization)
KV_QUANT_THRESHOLD_BYTES = 8 * 2**30


def _build_step(cfg, shape, mesh_cfg, rules, mb_override=None):
    """Returns (fn, arg_specs) ready for jit(fn).lower(*arg_specs)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core.config import TrainConfig
    from repro.launch.inputs import input_specs, cache_specs
    from repro.models.registry import get_model
    from repro.nn.param import axes_tree, is_param
    from repro.sharding.rules import logical_to_spec
    from repro.train.optimizer import adamw_init_spec
    from repro.train.step import make_train_step

    from repro.sharding.ctx import activation_sharding

    model = get_model(cfg)
    dp = mesh_cfg.dp_size
    mesh_axes = mesh_cfg.axes
    window = model.effective_window(shape)

    def with_act_ctx(f):
        """Trace `f` under the activation-sharding context so every
        shard_act() in model code becomes a with_sharding_constraint."""
        def g(*a, **kw):
            with activation_sharding(mesh_axes, rules):
                return f(*a, **kw)
        return g

    def shard(axes):
        return logical_to_spec(axes, mesh_axes, rules)

    def tree_sds(spec_tree, mesh):
        def leaf(p):
            return jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(p.dtype or cfg.param_dtype),
                sharding=NamedSharding(mesh, shard(p.axes)),
            )
        return jax.tree_util.tree_map(leaf, spec_tree, is_leaf=is_param)

    def batch_sds(specs, axes, mesh):
        return {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(mesh, shard(axes[k])),
            )
            for k, v in specs.items()
        }

    def build(mesh):
        param_spec = model.param_spec()
        params = tree_sds(param_spec, mesh)
        b_specs, b_axes = input_specs(cfg, shape)
        batch = batch_sds(b_specs, b_axes, mesh)

        if shape.kind == "train":
            from repro.train.step import default_microbatches

            tcfg = TrainConfig()
            fsdp = dict(rules.table).get("embed") is not None
            # >100B params: bf16 optimizer state + grad accumulation, the
            # documented large-model configuration (EXPERIMENTS.md §Dry-run)
            big = cfg.num_params() > 100e9
            opt_spec = adamw_init_spec(
                param_spec, zero1=True, dp_size=dp, fsdp=fsdp,
                moment_dtype="bfloat16" if big else "float32")
            opt = tree_sds(opt_spec, mesh)
            # media-token activations make VLM/audio steps heavier per token
            mlt = 4096 if cfg.family in ("vlm", "audio") else 8192
            mb = mb_override or default_microbatches(
                shape.global_batch * shape.seq_len, dp, max_local_tokens=mlt)
            step = make_train_step(
                model, tcfg, dp_size=dp, window_override=window,
                microbatches=mb,
                grad_acc_dtype="bfloat16" if big else "float32")
            return with_act_ctx(step), (params, opt, batch), (0, 1)

        if shape.kind == "prefill":
            c_sds_spec = model.cache_spec(shape.global_batch, shape.seq_len,
                                          window)
            cache = tree_sds(c_sds_spec, mesh)

            def prefill(params, batch, cache):
                return model.forward(params, batch, mode="prefill",
                                     dp_size=dp, window_override=window,
                                     cache=cache)

            return with_act_ctx(prefill), (params, batch, cache), (2,)

        # decode
        c_sds_spec = model.cache_spec(shape.global_batch, shape.seq_len,
                                      window)
        cache = tree_sds(c_sds_spec, mesh)

        def serve_step(params, tokens, positions, cache):
            return model.decode_step(params, tokens, positions, cache,
                                     window=window, dp_size=dp)

        return with_act_ctx(serve_step), (params, batch["tokens"],
                                          batch["positions"], cache), (3,)

    return build


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            save_hlo: bool = False, variants=()) -> dict:
    import jax

    from repro.core.config import get_arch, get_shape
    from repro.launch.hlo_analysis import analyze_hlo_text
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.sharding.auto import rules_for

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_cfg = mesh_config(multi_pod)
    if shape.is_decode and not cfg.is_attention_free:
        # bf16 KV cache footprint per device; >8 GB -> int8 KV (documented
        # beyond-paper serving optimization, EXPERIMENTS.md SPerf)
        win = cfg.sliding_window or (cfg.long_context_window
                                     if shape.seq_len > 131_072 else 0)
        s_eff = min(shape.seq_len, win) if win else shape.seq_len
        n_attn = (cfg.num_layers if cfg.shared_attn_every == 0
                  else cfg.num_layers // cfg.shared_attn_every)
        cache_bytes = (2 * n_attn * shape.global_batch * s_eff
                       * cfg.num_kv_heads * cfg.head_dim * 2)
        if cache_bytes / mesh_cfg.num_devices > KV_QUANT_THRESHOLD_BYTES:
            cfg = dataclasses.replace(cfg, kv_quant=True)
    mb_override = None
    vnotes = []
    if variants:
        # phase 1: config transforms BEFORE rules_for so the divisibility
        # policies see the transformed architecture (e.g. padded heads)
        from repro.launch.variants import apply_variants
        from repro.sharding.rules import DEFAULT_RULES

        cfg, _, vnotes, mb_override = apply_variants(
            variants, cfg, DEFAULT_RULES, mesh_cfg.model_size)
    rules, notes = rules_for(cfg, mesh_cfg, shape)
    if variants:
        # phase 2: rule-only overrides on the derived rules (e.g. seq_sp)
        from repro.launch.variants import apply_variants as _av

        _, rules, _, _ = _av(variants, cfg, rules, mesh_cfg.model_size)
    notes = notes + vnotes
    if cfg.kv_quant:
        notes = notes + ["int8 KV cache"]
    mesh = make_production_mesh(multi_pod=multi_pod)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh_cfg.shape)),
        "num_devices": mesh_cfg.num_devices,
        "sharding_notes": notes,
        "variants": list(variants),
        "status": "error",
    }
    t0 = time.time()
    try:
        build = _build_step(cfg, shape, mesh_cfg, rules,
                            mb_override=mb_override)
        fn, args, donate = build(mesh)
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
        args_b = rec["memory"].get("argument_size_in_bytes", 0)
        alias_b = rec["memory"].get("alias_size_in_bytes", 0)
        temp_b = rec["memory"].get("temp_size_in_bytes", 0)
        out_b = rec["memory"].get("output_size_in_bytes", 0)
        rec["memory"]["per_device_total_bytes"] = (
            args_b + temp_b + out_b - alias_b
        )
        ca = compiled.cost_analysis() or {}
        rec["xla_cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
        }
        hlo_text = compiled.as_text()
        costs = analyze_hlo_text(hlo_text)
        rec["hlo"] = costs.to_dict()
        rec["hlo"]["note"] = "per-device; trip-count-corrected"
        if save_hlo:
            hlo_path = out_dir / f"{arch}__{shape_name}__{rec['mesh']}.hlo"
            hlo_path.write_text(hlo_text)
            rec["hlo_path"] = str(hlo_path)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all (arch, shape) pairs for the chosen mesh")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="",
                    help="'+'-separated variant chain, e.g. head_pad+int8kv")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.core.config import SHAPES, list_archs

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    combos = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    variants = tuple(v for v in args.variant.split("+") if v)
    vtag = ("__v-" + "-".join(variants)) if variants else ""
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    for arch, shape in combos:
        path = out_dir / f"{arch}__{shape}__{mesh_tag}{vtag}.json"
        if args.skip_existing and path.exists():
            try:
                status = json.loads(path.read_text()).get("status")
            except (OSError, json.JSONDecodeError):
                status = None  # unreadable/corrupt record: re-run it
            if status == "ok":
                print(f"[skip] {arch} {shape} {mesh_tag}")
                continue
        print(f"[run ] {arch} {shape} {mesh_tag}", flush=True)
        rec = run_one(arch, shape, args.multi_pod, out_dir,
                      save_hlo=args.save_hlo, variants=variants)
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            per_dev = rec["memory"].get("per_device_total_bytes", 0)
            extra = (f" mem/dev={per_dev/2**30:.2f}GiB"
                     f" flops/dev={rec['hlo']['flops']:.3e}"
                     f" coll/dev={rec['hlo']['collective_bytes_total']:.3e}")
        else:
            extra = " " + rec.get("error", "")[:200]
        print(f"[done] {arch} {shape} {mesh_tag}: {status}"
              f" ({rec['total_s']}s){extra}", flush=True)


if __name__ == "__main__":
    main()
