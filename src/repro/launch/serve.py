"""Serving launcher: run the batched serving engine on a registered arch.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.config import get_arch
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve launcher supports text-only archs; "
                         "use examples/deploy_and_serve.py for media stubs")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(model, params, max_batch=args.max_batch,
                        max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 12)).tolist()
        eng.submit(Request(rid, prompt, max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(f"[serve] {cfg.name}: {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    for rid in sorted(done):
        print(f"  req {rid}: {done[rid][:12]}{'...' if len(done[rid])>12 else ''}")
    return {"tokens": total_tokens, "seconds": dt, "done": done}


if __name__ == "__main__":
    main()
