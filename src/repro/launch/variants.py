"""Named optimization variants for the §Perf hillclimbs.

Each variant is (config transform, rules transform, note).  Variants are
beyond-paper optimizations recorded SEPARATELY from the paper-faithful
baselines (EXPERIMENTS.md §Perf) — baselines stay untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.config import ModelConfig
from repro.sharding.rules import AxisRules


def _pad_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def head_pad(cfg: ModelConfig, rules: AxisRules, model_size: int = 16):
    """Pad q heads (and kv heads when beneficial) to the model-axis multiple
    so attention shards instead of replicating.  Semantics-preserving: the
    padded head slices are zero-initialized and their outputs are annihilated
    by the zero rows of wo (tests/test_variants.py)."""
    nh = _pad_up(cfg.num_heads, model_size)
    kvh = cfg.num_kv_heads
    padded_kv = _pad_up(kvh, model_size)
    if nh % kvh != 0:
        # kv must divide the padded head count — forced to pad kv too
        kvh = padded_kv
    elif kvh % model_size and padded_kv <= 2 * kvh:
        # optional kv pad when it costs <=2x KV-cache memory
        kvh = padded_kv
    assert nh % kvh == 0, (nh, kvh)
    cfg2 = dataclasses.replace(cfg, num_heads=nh, num_kv_heads=kvh)
    rules2 = rules.replace(heads="model" if nh % model_size == 0 else None,
                           kv_heads="model" if kvh % model_size == 0 else None)
    return cfg2, rules2, (f"head_pad: q {cfg.num_heads}->{nh}, "
                          f"kv {cfg.num_kv_heads}->{kvh}")


def seq_sp(cfg: ModelConfig, rules: AxisRules, model_size: int = 16):
    """Megatron-style sequence parallelism: the residual stream (and the
    saved scan carries) shard their sequence axis over the model axis."""
    rules2 = rules.replace(seq_res="model")
    return cfg, rules2, "seq_sp: residual-stream sequence sharded over model"


def int8kv(cfg: ModelConfig, rules: AxisRules, model_size: int = 16):
    return (dataclasses.replace(cfg, kv_quant=True), rules,
            "int8kv: quantized KV cache")


def microbatches(k: int):
    def f(cfg, rules, model_size: int = 16):
        return cfg, rules, f"mb{k}: microbatch override"
    f.mb_override = k
    return f


def chunk(size: int):
    """Larger flash chunks: K/V re-read bytes scale ~ (s/chunk)."""
    def f(cfg: ModelConfig, rules: AxisRules, model_size: int = 16):
        return dataclasses.replace(cfg, attn_chunk=size), rules, f"chunk{size}"
    return f


VARIANTS: Dict[str, Callable] = {
    "chunk2k": chunk(2048),
    "chunk4k": chunk(4096),
    "head_pad": head_pad,
    "seq_sp": seq_sp,
    "int8kv": int8kv,
    "mb2": microbatches(2),
    "mb4": microbatches(4),
    "mb16": microbatches(16),
}


def apply_variants(names, cfg, rules, model_size: int = 16):
    """Apply a +-separated chain of variants; returns (cfg, rules, notes,
    mb_override)."""
    notes, mb = [], None
    for name in names:
        fn = VARIANTS[name]
        cfg, rules, note = fn(cfg, rules, model_size)
        notes.append(note)
        mb = getattr(fn, "mb_override", mb)
    return cfg, rules, notes, mb
