"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The single-pod mesh is
16×16 = 256 chips (data × model); the multi-pod mesh is 2×16×16 = 512 chips
(pod × data × model) where the leading axis crosses the slower inter-pod
links — the batch shards over ("pod","data") so only data-parallel gradient
all-reduces cross pods.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.config import MeshConfig, SINGLE_POD, MULTI_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_test_mesh():
    """1×1 mesh over the single CPU device — used by smoke/integration tests
    so the same sharded code paths run unmodified."""
    import numpy as np

    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))
