"""ShapeDtypeStruct stand-ins for every model input.

``input_specs(cfg, shape)`` returns (tree of jax.ShapeDtypeStruct, tree of
logical axes) for the batch of the given input shape — weak-type-correct,
shardable, and allocation-free.  The dry-run attaches NamedShardings from
the per-(arch, mesh, shape) rules; smoke tests materialize them with zeros.

Decode shapes describe ``serve_step`` inputs: ONE new token per request
plus the KV cache of ``seq_len``; train/prefill describe the full batch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, ShapeConfig
from repro.models.registry import get_model
from repro.nn.param import axes_tree, is_param, Param


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _media_specs(cfg: ModelConfig, b: int):
    """Stub modality frontend outputs (DESIGN.md §7): patch/frame embeddings
    of the right shape, as if produced by the ViT / conv feature extractor."""
    specs, axes = {}, {}
    if cfg.family == "vlm":
        t, dm = cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim
        specs["media_embeds"] = _sds((b, t, dm), cfg.dtype)
        axes["media_embeds"] = ("batch", "media", None)
    if cfg.family == "audio":
        t, dm = cfg.cross_attn.num_media_tokens, cfg.cross_attn.media_dim
        specs["frames"] = _sds((b, t, dm), cfg.dtype)
        axes["frames"] = ("batch", "media", None)
    return specs, axes


def cache_specs(model, batch: int, cache_len: int, window: int):
    spec = model.cache_spec(batch, cache_len, window)
    sds = jax.tree_util.tree_map(
        lambda p: _sds(p.shape, p.dtype or "bfloat16"), spec,
        is_leaf=is_param,
    )
    return sds, axes_tree(spec)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[dict, dict]:
    """Batch-side inputs only (params/opt/cache handled by the dry-run)."""
    b, s = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    if shape.kind == "train":
        specs = {
            "tokens": _sds((b, s), "int32"),
            "labels": _sds((b, s), "int32"),
        }
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    elif shape.kind == "prefill":
        specs = {"tokens": _sds((b, s), "int32")}
        axes = {"tokens": ("batch", "seq")}
    else:  # decode: ONE new token per request
        specs = {
            "tokens": _sds((b, 1), "int32"),
            "positions": _sds((b,), "int32"),
        }
        axes = {"tokens": ("batch", None), "positions": ("batch",)}
    m_specs, m_axes = _media_specs(cfg, b)
    specs.update(m_specs)
    axes.update(m_axes)
    return specs, axes
