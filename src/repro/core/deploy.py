"""Model deployment pipeline (paper §2.2 / Fig. 2).

Train-side: serialize a trained model (net definition + weights) into a
device-ready directory — ``manifest.json`` (architecture, layer table,
dtype, version) + ``weights.npz``.  Device-side: load and verify, yielding
the exact structures the engine executes.  This is the Caffe→convert→
upload→execute path with JAX in both roles.

Also used by the transformer stack's checkpointing (``repro.train.checkpoint``
wraps the same format with sharding metadata).

A manifest may additionally carry a ``tuned_plan`` — the winning knob
set ``tools/autotune.py`` found for this network (per-layer methods,
``oh_block`` bands, fusion opt-outs), serialized canonically so the
round-trip is byte-exact.  ``load_model`` verifies the TUNED plan (not
just the default one) and ``load_engine`` reconstructs a pre-tuned
``CNNEngine`` — deployment serves the autotuned configuration without
re-searching.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods import Method
from repro.core.netdefs import LayerSpec, NetworkDef, NETWORKS
from repro.core.plan import compile_plan, infer_param_shapes

FORMAT_VERSION = 1

#: knob names a tuned plan may pin — exactly ``compile_plan``'s
#: configuration surface (engine-side, ``fuse`` maps onto ``fuse_pool``)
TUNED_KNOBS = ("method", "per_layer_methods", "oh_block",
               "per_layer_oh_blocks", "fuse", "fuse_relu", "per_layer_fuse",
               "per_layer_pool_carry", "per_layer_lrn_oc_block",
               "per_layer_oc_block_final", "use_pallas")


def knobs_to_manifest(knobs: dict) -> dict:
    """Serialize a ``compile_plan`` knob set for the manifest: ``Method``
    enums become their value strings, dict knobs sort canonically.
    Unknown knob names raise — a typo must not ship as a silently
    ignored tuning decision."""
    unknown = set(knobs) - set(TUNED_KNOBS)
    if unknown:
        raise ValueError(f"unknown tuned-plan knob(s): {sorted(unknown)}")
    out = {}
    for k in TUNED_KNOBS:
        if k not in knobs:
            continue
        v = knobs[k]
        if isinstance(v, Method):
            v = v.value
        elif isinstance(v, dict):
            v = {n: (m.value if isinstance(m, Method) else m)
                 for n, m in sorted(v.items())}
        out[k] = v
    return out


def knobs_from_manifest(d: dict) -> dict:
    """Inverse of ``knobs_to_manifest``: value strings back to ``Method``
    enums, ready to splat into ``compile_plan``."""
    out = dict(d)
    if "method" in out:
        out["method"] = Method(out["method"])
    if "per_layer_methods" in out:
        out["per_layer_methods"] = {
            n: Method(m) for n, m in out["per_layer_methods"].items()}
    return out


def _flatten(params: dict, prefix: str = "") -> Dict[str, np.ndarray]:
    flat = {}
    for k, v in params.items():
        key = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            flat.update(_flatten(v, key))
        else:
            flat[key] = np.asarray(v)
    return flat


def _unflatten(flat: Dict[str, np.ndarray]) -> dict:
    out: dict = {}
    for k, v in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(v)
    return out


def save_model(path, net: NetworkDef, params: dict, extra: dict = None,
               tuned: dict = None) -> None:
    """Train-side conversion: write the deployable artifact.  ``tuned``
    (optional) is a ``compile_plan`` knob set (``Method`` enums welcome)
    persisted under ``manifest["tuned_plan"]`` — the autotuner's winning
    configuration, reconstructed verbatim by ``load_engine``."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(params)
    np.savez(path / "weights.npz", **flat)
    digest = hashlib.sha256()
    for k in sorted(flat):
        digest.update(k.encode())
        digest.update(flat[k].tobytes())
    manifest = {
        "format_version": FORMAT_VERSION,
        "network": dataclasses.asdict(net),
        "tensors": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in flat.items()},
        "weights_sha256": digest.hexdigest(),
        "extra": extra or {},
    }
    if tuned is not None:
        manifest["tuned_plan"] = knobs_to_manifest(tuned)
    (path / "manifest.json").write_text(
        json.dumps(manifest, indent=2, sort_keys=True))


def load_model(path) -> Tuple[NetworkDef, dict, dict]:
    """Device-side load: verify integrity, rebuild net + params."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    if manifest["format_version"] != FORMAT_VERSION:
        raise ValueError(f"format version {manifest['format_version']}")
    data = np.load(path / "weights.npz")
    flat = {k: data[k] for k in data.files}
    digest = hashlib.sha256()
    for k in sorted(flat):
        digest.update(k.encode())
        digest.update(flat[k].tobytes())
    if digest.hexdigest() != manifest["weights_sha256"]:
        raise ValueError("weight checksum mismatch — corrupted artifact")
    for k, meta in manifest["tensors"].items():
        if list(flat[k].shape) != meta["shape"]:
            raise ValueError(f"tensor {k} shape mismatch")
        if str(flat[k].dtype) != meta["dtype"]:
            # a dtype-corrupted artifact (e.g. re-saved at lower precision
            # with a refreshed checksum) must not load silently
            raise ValueError(
                f"tensor {k} dtype mismatch: manifest records "
                f"{meta['dtype']}, weights.npz holds {flat[k].dtype}")
    nd = manifest["network"]
    net = NetworkDef(
        name=nd["name"],
        input_shape=tuple(nd["input_shape"]),
        num_classes=nd["num_classes"],
        layers=tuple(
            LayerSpec(**{**l, "kernel": tuple(l["kernel"]),
                         "stride": tuple(l["stride"]),
                         "padding": tuple(l["padding"])})
            for l in nd["layers"]
        ),
    )
    # the declared architecture must size the shipped tensors: a tampered
    # layer table (wrong kernel, channel count, fc fan-in) fails HERE,
    # not at first inference with a cryptic dot-shape error
    for name, shp in infer_param_shapes(net).items():
        spec = next(l for l in net.layers if l.name == name)
        b_shape = (shp[0],) if spec.kind == "conv" else (shp[1],)
        for key, want in ((f"{name}/w", tuple(shp)), (f"{name}/b", b_shape)):
            meta = manifest["tensors"].get(key)
            got = None if meta is None else tuple(meta["shape"])
            if got != want:
                raise ValueError(
                    f"manifest geometry mismatch: tensor {key} must be "
                    f"{want} for the declared architecture, manifest "
                    f"records {got}")
    # static plan verification: shape flow, band coverage, VMEM audit
    # (PlanVerificationError is a ValueError — corrupt geometry fails
    # the load exactly like a checksum or dtype mismatch).  A tuned
    # manifest is verified under ITS knobs — a tampered tuning that
    # compiles to broken geometry fails the load, not the first batch.
    tuned = manifest.get("tuned_plan")
    if tuned is not None:
        kn = knobs_from_manifest(tuned)
        kn.setdefault("verify", True)
        compile_plan(net, **kn)
    else:
        compile_plan(net, verify=True)
    return net, _unflatten(flat), manifest["extra"]


def load_tuned_knobs(path) -> Optional[dict]:
    """The deserialized ``tuned_plan`` knob set of an artifact, or None
    for an untuned manifest.  Reads only the manifest — no weight I/O."""
    manifest = json.loads((Path(path) / "manifest.json").read_text())
    tuned = manifest.get("tuned_plan")
    return None if tuned is None else knobs_from_manifest(tuned)


def load_engine(path) -> Tuple["object", dict, Optional[dict]]:
    """Device-side one-call bring-up: ``(engine, params, tuned_knobs)``
    with the ``CNNEngine`` already configured to the manifest's tuned
    plan (default heuristics when the artifact carries none) — serving
    starts on the autotuned configuration without re-searching."""
    from repro.core.engine import CNNEngine

    net, params, _extra = load_model(path)
    knobs = load_tuned_knobs(path)
    kwargs = dict(knobs or {})
    if "fuse" in kwargs:  # compile_plan's name; the engine calls it fuse_pool
        kwargs["fuse_pool"] = kwargs.pop("fuse")
    engine = CNNEngine(net, **kwargs)
    return engine, params, knobs
