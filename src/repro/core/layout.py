"""Dimension swapping — the paper's §4.3 layout transformation.

CNNdroid's "basic SIMD" method moves channels to the lowest (fastest-
varying) dimension so the innermost reduction vectorizes: NCHW → NHWC.
On TPU the lane width is 128 (not 4), so the same transformation also pads
channels up to the lane multiple; the padding is stripped on the way out.

These helpers are used by the engine (host-side, overlapped with device
compute — the Fig. 5 scheduling analogue) and by the kernels' ops wrappers.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

LANES = 128  # TPU vector lane width (the paper's "4" on 128-bit mobile SIMD)


def nchw_to_nhwc(x):
    return jnp.transpose(x, (0, 2, 3, 1))


def nhwc_to_nchw(x):
    return jnp.transpose(x, (0, 3, 1, 2))


def oihw_to_hwio(k):
    """Kernel layout swap: [out_c, in_c, kh, kw] -> [kh, kw, in_c, out_c]."""
    return jnp.transpose(k, (2, 3, 1, 0))


def hwio_to_oihw(k):
    return jnp.transpose(k, (3, 2, 0, 1))


def pad_axis(x, axis: int, multiple: int):
    """Zero-pad `axis` up to the next multiple; returns (padded, orig_size)."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def pad_channels_nhwc(x, multiple: int = LANES):
    return pad_axis(x, 3, multiple)


def unpad_axis(x, axis: int, size: int):
    if x.shape[axis] == size:
        return x
    return jnp.take(x, jnp.arange(size), axis=axis)
