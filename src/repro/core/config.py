"""Typed configuration system.

Every selectable architecture is described by a :class:`ModelConfig`; input
shapes by :class:`ShapeConfig`; distribution by :class:`MeshConfig`.  Configs
are plain frozen dataclasses so they hash, compare, and serialize cleanly and
can be used as static args to ``jax.jit``.

A registry maps ``--arch <id>`` / ``--shape <id>`` strings to configs; the
per-architecture modules in ``repro.configs`` register themselves on import.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    num_experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25  # training (drops are a gradient tradeoff)
    eval_capacity_factor: float = 2.0  # prefill (rare drops tolerated)
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # "expert": shard the expert dimension over the model axis (many small
    # experts, e.g. qwen3's 128).  "tensor": shard each expert's ff dimension
    # over the model axis (few large experts, e.g. grok's 8).
    shard_mode: str = "expert"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD state-space block configuration."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time/channel mixing configuration."""

    head_dim: int = 64
    decay_lora: int = 64
    tokenshift_lora: int = 32
    chunk_size: int = 64  # [b,L,L,h,e] pairwise-decay transient stays <1GB


@dataclass(frozen=True)
class CrossAttnConfig:
    """Cross-attention (VLM / encoder-decoder) configuration."""

    # every `interval`-th layer is a cross-attention layer (VLM style);
    # 0 means "every decoder layer has cross-attention" (enc-dec style).
    interval: int = 0
    num_media_tokens: int = 0  # stub frontend: number of patch/frame embeds
    media_dim: int = 0  # embedding dim delivered by the (stubbed) frontend


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` is one of dense | moe | ssm | hybrid | vlm | audio.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    source: str = ""  # citation for the config

    # --- attention variants -------------------------------------------------
    rope_theta: float = 10000.0
    use_qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0  # gemma2 final-logit softcap (0 = off)
    attn_softcap: float = 0.0  # gemma2 attention-logit softcap (0 = off)
    sliding_window: int = 0  # 0 = full attention
    # gemma2-style alternation: 0 = uniform; k>0 = every k-th layer is
    # global, the rest use `sliding_window`.
    local_global_interval: int = 0
    # post-attn / post-mlp extra norms (gemma2)
    post_block_norms: bool = False
    tie_embeddings: bool = False
    attn_logit_scale: float = 0.0  # 0 -> 1/sqrt(head_dim)
    attn_chunk: int = 512  # flash chunk size (K/V re-read factor ~ s/chunk)

    # --- non-attention mixers ----------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None

    # --- hybrid (zamba2): shared attention block applied every k ssm blocks
    shared_attn_every: int = 0

    # --- encoder/decoder (audio) --------------------------------------------
    num_encoder_layers: int = 0  # >0 => encoder-decoder model

    # --- long-context fallback ----------------------------------------------
    # Window used when a full-attention arch is run on the long_500k shape
    # ("sliding-window variant", documented in DESIGN.md §Arch-applicability).
    long_context_window: int = 8192

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # int8 KV cache with per-(slot, head) scales.  The scales factor exactly
    # into the score/prob vectors (s = (q·k_i8)·scale; pv = (p·v_scale)·v_i8)
    # so the int8 tensors are only ever operands of MXU dots.  Auto-enabled
    # by the dry-run when the bf16 cache would exceed ~8 GB/device.
    kv_quant: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"  # mlp activation: silu | gelu | relu
    mlp_gated: bool = True  # SwiGLU/GeGLU vs plain 2-matrix MLP
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    rms_plus_one: bool = False  # gemma (1+w) convention

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 (TPU lane width; also makes
        every assigned vocab divisible by the 16-way model axis)."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def num_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.registry import analytic_param_count

        return analytic_param_count(self)

    def active_params(self) -> int:
        from repro.models.registry import analytic_param_count

        return analytic_param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (<=2 layers,
        d_model<=512, <=4 experts)."""
        changes: Dict[str, Any] = dict(
            num_layers=2,
            d_model=256,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=64,
            d_ff=512,
            vocab_size=512,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=4,
                num_experts_per_token=min(2, self.moe.num_experts_per_token),
                d_ff_expert=128,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(self.ssm, d_state=16, chunk_size=32)
        if self.rwkv is not None:
            changes["rwkv"] = dataclasses.replace(
                self.rwkv, head_dim=64, decay_lora=16, tokenshift_lora=8, chunk_size=32
            )
        if self.cross_attn is not None:
            changes["cross_attn"] = dataclasses.replace(
                self.cross_attn,
                interval=min(self.cross_attn.interval, 2),
                num_media_tokens=16,
                media_dim=256,
            )
        if self.num_encoder_layers:
            changes["num_encoder_layers"] = 2
        if self.shared_attn_every:
            changes["shared_attn_every"] = 1
            changes["num_layers"] = 2
        if self.sliding_window:
            changes["sliding_window"] = 64
        if self.local_global_interval:
            changes["local_global_interval"] = 2
        changes["long_context_window"] = 64
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / distribution configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        """Axes over which the batch is sharded."""
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def dp_size(self) -> int:
        return int(
            _prod(s for s, a in zip(self.shape, self.axes) if a in ("pod", "data"))
        )

    @property
    def model_size(self) -> int:
        return int(_prod(s for s, a in zip(self.shape, self.axes) if a == "model"))


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
# CPU-sized meshes for tests.
TINY_MESH = MeshConfig((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# Training configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # remat policy for the layer scan: "none" | "full" | "dots"
    remat: str = "full"
    zero1: bool = True  # shard optimizer state over the dp axes
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _ARCH_REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ModelConfig:
    _ensure_configs_imported()
    if name not in _ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_ARCH_REGISTRY)}"
        )
    return _ARCH_REGISTRY[name]()


def list_archs():
    _ensure_configs_imported()
    return sorted(_ARCH_REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def _ensure_configs_imported() -> None:
    import repro.configs  # noqa: F401  (registers all archs)


def config_to_json(cfg: Any) -> str:
    def default(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        raise TypeError(o)

    return json.dumps(cfg, default=default, indent=2)
