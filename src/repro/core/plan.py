"""Compile-once ExecutionPlan IR — the engine's executor spine.

``compile_plan(net, ...)`` lowers a ``NetworkDef`` into a typed sequence
of resolved ``PlanStep``s, making every decision the old interpreting
``forward`` loop used to re-make per trace:

* **shape resolution** — each step carries its pre-resolved input and
  output activation shape (``(C, H, W)`` while spatial, ``(D,)`` once
  flattened); an fc straight after a conv/pool resolves its ``d_in`` to
  the whole ``c*h*w`` activation and is flagged ``pre_flatten`` so the
  executor reshapes without inspecting ``x.ndim`` semantics,
* **standalone-ReLU folding** — a standalone ``relu`` layer following a
  conv/fc/pool is folded into that step's epilogue at compile time (the
  folded layer's name joins the step's ``names`` so instrumentation
  still sees it); with ``fuse_relu=False`` it stays its own step,
* **super-layer fusion** — ``repro.core.fusion.plan_fusion`` runs once
  at compile time; each ``FusedLayerSpec`` becomes one ``fused`` (single
  conv + pool epilogue) or ``chain`` (multi-conv, VMEM-resident halo)
  step carrying its resolved method, ``oh_block``, and LRN constants,
* **method / oh_block resolution** — per-layer overrides are read off
  the knob maps once; steps store the resolved values.

``ExecutionPlan.execute`` is a thin loop over step executors — no
fusion, folding, or shape decision happens at trace time, so a plan is
compiled once and re-traced cheaply per batch bucket.  The plan also
answers ``fusion_report()`` (executed Pallas geometry) straight off its
steps, and iterating an ``ExecutionPlan`` yields the underlying
``LayerSpec``/``FusedLayerSpec`` items so planner-level helpers
(``fusion_summary``) keep working on it.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fusion import (
    FUSABLE_METHODS,
    CostGate,
    FusedLayerSpec,
    PlanItem,
    _conv_out_hw,
    _pool_out_hw,
    group_geometry,
    plan_fusion,
)
from repro.core.methods import (
    Method,
    conv2d,
    conv2d_chain_fused,
    conv2d_pool_fused,
    fc_fused,
    fc_seq_ref,
)
from repro.core.netdefs import LayerSpec, NetworkDef

Shape = Tuple[int, ...]


def infer_param_shapes(net: NetworkDef) -> Dict[str, Tuple]:
    """Propagate shapes through the net to size conv/fc parameters
    (conv: OIHW weight shape; fc: ``(d_in, d_out)``).  An fc straight
    after a conv/pool (no flatten layer) consumes the WHOLE ``c*h*w``
    activation, not just the channel count."""
    c, h, w = net.input_shape
    shapes: Dict[str, Tuple] = {}
    flat: Optional[int] = None
    for spec in net.layers:
        if spec.kind == "conv":
            kh, kw = spec.kernel
            shapes[spec.name] = (spec.out_channels, c, kh, kw)
            h, w = _conv_out_hw(h, w, spec)
            c = spec.out_channels
        elif spec.kind == "pool":
            h, w = _pool_out_hw(h, w, spec)
        elif spec.kind == "flatten":
            flat = c * h * w
        elif spec.kind == "fc":
            d_in = flat if flat is not None else c * h * w
            shapes[spec.name] = (d_in, spec.out_channels)
            flat = spec.out_channels
    return shapes


#: the conv methods worth sweeping per layer: the three fusable SIMD
#: rungs (seq_ref / basic_parallel are reference semantics, never faster)
SIMD_METHODS: Tuple[Method, ...] = tuple(
    m for m in Method if m in FUSABLE_METHODS)

#: default per-layer band-override candidates the autotuner tries on top
#: of the resolver's auto sizing (clipped per layer to its output height)
OH_BLOCK_CANDIDATES: Tuple[int, ...] = (4, 8, 16, 32, 64)


def knob_space(net: NetworkDef, *,
               methods: Tuple[Method, ...] = SIMD_METHODS,
               oh_blocks: Tuple[int, ...] = OH_BLOCK_CANDIDATES,
               ) -> Dict[str, Dict[str, list]]:
    """The per-layer candidate knob grid an offline autotuner sweeps:
    ``{layer_name: {"methods": [...], "oh_blocks": [None, ...],
    "fuse": [True, False]}}``.

    Shapes are propagated through the net so each conv's ``oh_blocks``
    list is clipped to bands strictly smaller than its output height
    (``None`` — the resolver's VMEM-model auto sizing — always leads).
    Conv layers also expose the second-generation fused-cell axes:
    ``pool_carry`` (sliding-window pool accumulator; None = auto) and
    ``lrn_oc_block`` (two-pass channel-halo LRN blocking; None = auto)
    bind when the conv leads a fused conv+pool group, ``oc_block_final``
    binds when the conv ENDS a fused chain (final-stage oc-grid
    blocking).  Pool and LRN layers expose only the ``fuse`` axis (their
    method/band geometry is owned by the group they fuse into); fc and
    the other pointwise tail layers expose no tunable axis today.
    """
    space: Dict[str, Dict[str, list]] = {}
    c, h, w = net.input_shape
    for spec in net.layers:
        if spec.kind == "conv":
            oh, ow = _conv_out_hw(h, w, spec)
            space[spec.name] = {
                "methods": list(methods),
                "oh_blocks": [None] + [b for b in oh_blocks if b < oh],
                "fuse": [True, False],
                "pool_carry": [None, False],
                "lrn_oc_block": [None, True, False],
                "oc_block_final": [None, 4, 8],
            }
            c, h, w = spec.out_channels, oh, ow
        elif spec.kind == "pool":
            space[spec.name] = {"fuse": [True, False]}
            h, w = _pool_out_hw(h, w, spec)
        elif spec.kind == "lrn":
            space[spec.name] = {"fuse": [True, False]}
    return space


@dataclass(frozen=True)
class PlanStep:
    """One resolved executor step.  ``kind`` selects the executor:
    conv | fused (single conv + pool epilogue) | chain (multi-conv) |
    pool | lrn | flatten | fc | relu | softmax.  ``names`` are the
    original layer names the step covers (folded standalone ReLUs
    included) — ``execute(collect=...)`` records the step's output under
    every one of them, matching the per-layer interpreter."""
    kind: str
    names: Tuple[str, ...]
    in_shape: Shape
    out_shape: Shape
    spec: Optional[LayerSpec] = None          # per-layer steps
    group: Optional[FusedLayerSpec] = None    # fused / chain steps
    method: Optional[Method] = None           # conv / fc / fused / chain
    oh_block: Optional[int] = None            # conv / fused / chain
    relu: bool = False                        # folded epilogue ReLU
    pre_flatten: bool = False                 # fc fed a spatial activation
    d_in: Optional[int] = None                # fc input features
    kwargs: Optional[Mapping] = None          # fused/chain tail constants


def _lrn_kwargs(lrn: Optional[LayerSpec]) -> Dict:
    return dict(
        lrn_n=lrn.lrn_n if lrn is not None else None,
        lrn_alpha=lrn.lrn_alpha if lrn is not None else 1e-4,
        lrn_beta=lrn.lrn_beta if lrn is not None else 0.75,
        lrn_k=lrn.lrn_k if lrn is not None else 1.0)


# -- step executors (dispatch on PlanStep.kind; every decision is already
# resolved in the step, the executors only route tensors) -------------------


def _pool(x, spec: LayerSpec, use_pallas: bool = False, relu: bool = False):
    """VALID pooling; ``relu`` is the folded standalone activation (applied
    on top of the spec's own)."""
    do_relu = spec.relu or relu
    if use_pallas:
        from repro.kernels.pool2d import ops as pool_ops

        return pool_ops.pool2d(x, spec.kernel, spec.stride, spec.pool_kind,
                               relu=do_relu)
    from repro.kernels.pool2d.ref import pool2d_ref

    return pool2d_ref(x, spec.kernel, spec.stride, spec.pool_kind,
                      relu=do_relu)


def _lrn(x, spec: LayerSpec):
    """Local response normalization across channels (AlexNet-style): one
    channel-axis ``reduce_window`` (fp32) instead of ``lrn_n`` slice+adds."""
    sq = x.astype(jnp.float32) ** 2
    n = spec.lrn_n
    # window [c - n//2, c + (n-1)//2]: asymmetric padding keeps the output
    # at C channels for even n too (symmetric pad would yield C+1)
    acc = jax.lax.reduce_window(
        sq, 0.0, jax.lax.add, (1, n, 1, 1), (1, 1, 1, 1),
        ((0, 0), (n // 2, n - 1 - n // 2), (0, 0), (0, 0)),
    )
    denom = (spec.lrn_k + spec.lrn_alpha * acc) ** spec.lrn_beta
    return (x.astype(jnp.float32) / denom).astype(x.dtype)


def _exec_conv(plan: "ExecutionPlan", step: PlanStep, params, x):
    p = params[step.spec.name]
    return conv2d(x, p["w"], p["b"], step.method, step.spec.stride,
                  step.spec.padding, step.relu, plan.use_pallas,
                  step.oh_block)


def _exec_fused(plan: "ExecutionPlan", step: PlanStep, params, x):
    # single conv + pool[+LRN]: the oc-blocked epilogue kernel
    g = step.group
    p = params[g.conv.name]
    return conv2d_pool_fused(
        x, p["w"], p["b"], step.method, g.conv.stride, g.conv.padding,
        g.relu, g.pool.kernel, g.pool.stride, g.pool.pool_kind, g.pool_relu,
        plan.use_pallas, step.oh_block, **step.kwargs)


def _exec_chain(plan: "ExecutionPlan", step: PlanStep, params, x):
    # conv chain (optional pool/LRN tail): the full-width chain cell,
    # VMEM-resident halo between stages
    g = step.group
    pool = g.pool
    return conv2d_chain_fused(
        x, tuple(params[cv.name]["w"] for cv in g.convs),
        tuple(params[cv.name]["b"] for cv in g.convs),
        step.method, tuple(cv.stride for cv in g.convs),
        tuple(cv.padding for cv in g.convs), g.relus,
        pool_kernel=pool.kernel if pool is not None else None,
        pool_stride=pool.stride if pool is not None else None,
        pool_kind=pool.pool_kind if pool is not None else "max",
        pool_relu=g.pool_relu, use_pallas=plan.use_pallas,
        oh_block=step.oh_block, **step.kwargs)


def _exec_pool(plan, step, params, x):
    return _pool(x, step.spec, plan.use_pallas, relu=step.relu)


def _exec_lrn(plan, step, params, x):
    return _lrn(x, step.spec)


def _exec_flatten(plan, step, params, x):
    return x.reshape(x.shape[0], -1)


def _exec_fc(plan, step, params, x):
    if step.pre_flatten:  # fc fed a spatial activation (no flatten layer)
        x = x.reshape(x.shape[0], -1)
    p = params[step.spec.name]
    if step.method == Method.SEQ_REF:
        return fc_seq_ref(x, p["w"], p["b"], step.relu)
    return fc_fused(x, p["w"], p["b"], step.relu, plan.use_pallas)


def _exec_relu(plan, step, params, x):
    return jnp.maximum(x, 0.0)


def _exec_softmax(plan, step, params, x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1)


_EXECUTORS: Dict[str, Callable] = {
    "conv": _exec_conv,
    "fused": _exec_fused,
    "chain": _exec_chain,
    "pool": _exec_pool,
    "lrn": _exec_lrn,
    "flatten": _exec_flatten,
    "fc": _exec_fc,
    "relu": _exec_relu,
    "softmax": _exec_softmax,
}


@dataclass(frozen=True)
class ExecutionPlan:
    """The compiled forward path: a tuple of resolved ``PlanStep``s plus
    the pre-IR ``PlanItem`` sequence (iterating the plan yields the
    items, so ``fusion_summary`` and planner-level introspection work on
    an ``ExecutionPlan`` unchanged)."""
    net: NetworkDef
    fuse: bool
    use_pallas: bool
    steps: Tuple[PlanStep, ...]
    items: Tuple[PlanItem, ...]
    #: the vmem_budget override compile_plan was called with (None = the
    #: kernel-module defaults) — recorded so the static verifier audits
    #: the same ceiling the fusion planner admitted against
    vmem_budget: Optional[int] = None

    def __iter__(self):
        return iter(self.items)

    def __len__(self) -> int:
        return len(self.items)

    def execute(self, params, x, collect: Optional[dict] = None):
        """x: [N, C, H, W].  A thin loop over the step executors — every
        fusion/folding/shape decision was resolved at compile time."""
        for step in self.steps:
            x = _EXECUTORS[step.kind](self, step, params, x)
            if collect is not None:
                for n in step.names:
                    collect[n] = x
        return x

    def fusion_report(self) -> List[dict]:
        """Executed geometry of every fused group, read straight off the
        plan steps (each already carries its resolved input shape, method
        and band override) — see ``fusion.group_geometry``."""
        return [group_geometry(
                    s.group, s.method, s.in_shape, s.oh_block,
                    pool_carry=(s.kwargs or {}).get("pool_carry"),
                    lrn_oc_block=(s.kwargs or {}).get("lrn_oc_block"))
                for s in self.steps if s.kind in ("fused", "chain")]

    def cost(self, model=None, batch: int = 1):
        """Modelled cost of this plan: a ``repro.core.cost.PlanCost``
        with per-step FLOPs / HBM bytes / VMEM working set and, under
        ``model`` (a fitted ``CostModel``; None = unit coefficients),
        predicted microseconds.  Deferred import — the cost model sits
        above the plan IR, not under it."""
        from repro.core.cost import plan_cost

        return plan_cost(self, model=model, batch=batch)


def compile_plan(net: NetworkDef, *,
                 method: Method = Method.ADVANCED_SIMD_8,
                 per_layer_methods: Optional[Mapping[str, Method]] = None,
                 oh_block: Optional[int] = None,
                 per_layer_oh_blocks: Optional[Mapping[str, int]] = None,
                 fuse: bool = True,
                 fuse_relu: bool = True,
                 per_layer_fuse: Optional[Mapping[str, bool]] = None,
                 per_layer_pool_carry: Optional[Mapping[str, bool]] = None,
                 per_layer_lrn_oc_block: Optional[Mapping[str, bool]] = None,
                 per_layer_oc_block_final: Optional[Mapping[str, int]] = None,
                 use_pallas: bool = False,
                 vmem_budget: Optional[int] = None,
                 cost_gate: Optional[CostGate] = None,
                 verify: bool = True) -> ExecutionPlan:
    """Lower ``net`` into an ``ExecutionPlan``.

    Subsumes the legacy interpreter's per-call work: runs the fusion
    planner (``fuse=True``; the VMEM working-set check binds on the
    Pallas path only), folds standalone ReLUs into the preceding
    conv/fc/pool step (``fuse_relu``), resolves every layer's method /
    ``oh_block`` override, and propagates activation shapes so each step
    carries its input/output geometry.

    ``cost_gate`` (see ``fusion.plan_fusion``) swaps the fusion
    planner's raw VMEM budget check for a cost-model admission decision
    (``repro.core.cost.fusion_cost_gate``) — a group fuses only when the
    model scores the single dispatch faster than its per-layer ladder.

    ``per_layer_pool_carry`` / ``per_layer_lrn_oc_block`` (keyed by the
    conv LEADING a fused conv+pool group) pin that group's
    sliding-window carry / channel-halo LRN blocking (None = the kernel
    resolvers' auto rule); ``per_layer_oc_block_final`` (keyed by the
    conv ENDING a fused chain) forces the chain's final-stage oc block
    (ignored when the chain keeps an LRN tail — the kernel rejects the
    combination).

    ``verify=True`` (the default) runs the static plan verifier
    (``repro.analysis.verifier.verify_plan``) over the compiled plan and
    raises ``PlanVerificationError`` on any error-severity finding —
    every engine construction and ``deploy.load_model`` self-checks its
    geometry before the first batch arrives.
    """
    per_layer_methods = per_layer_methods or {}
    per_layer_oh_blocks = per_layer_oh_blocks or {}
    per_layer_pool_carry = per_layer_pool_carry or {}
    per_layer_lrn_oc_block = per_layer_lrn_oc_block or {}
    per_layer_oc_block_final = per_layer_oc_block_final or {}

    def method_for(name: str) -> Method:
        return per_layer_methods.get(name, method)

    def ohb_for(name: str) -> Optional[int]:
        return per_layer_oh_blocks.get(name, oh_block)

    if fuse:
        no = frozenset(n for n, v in (per_layer_fuse or {}).items() if not v)
        items: List[PlanItem] = plan_fusion(
            net, method_for=method_for, no_fuse=no, fuse_relu=fuse_relu,
            vmem_budget=vmem_budget, vmem_check=use_pallas,
            cost_gate=cost_gate)
    else:
        items = list(net.layers)

    steps: List[PlanStep] = []
    final_items: List[PlanItem] = []
    c, h, w = net.input_shape
    cur: Shape = (c, h, w)
    flat: Optional[int] = None
    for it in items:
        if isinstance(it, FusedLayerSpec):
            in_shape = cur
            c, h, w = cur
            for cv in it.convs:
                h, w = _conv_out_hw(h, w, cv)
            c = it.convs[-1].out_channels
            if it.pool is not None:
                h, w = _pool_out_hw(h, w, it.pool)
            cur = (c, h, w)
            kw = _lrn_kwargs(it.lrn)
            if len(it.convs) > 1:
                # explicit final-stage oc block (keyed by the LAST conv —
                # the chain cell's band lives in final-stage rows too)
                # overrides the planner's admission-ladder choice; an LRN
                # tail keeps full width (the kernel rejects the combo)
                obf = per_layer_oc_block_final.get(it.convs[-1].name)
                if obf is not None and it.lrn is None:
                    it = replace(it, oc_block_final=obf)
                kw["oc_block_final"] = it.oc_block_final
            else:
                kw["pool_carry"] = per_layer_pool_carry.get(it.conv.name)
                kw["lrn_oc_block"] = per_layer_lrn_oc_block.get(it.conv.name)
            # a chain cell's band is defined in FINAL-stage rows, so the
            # last conv's oh_block override is the one that maps onto it
            steps.append(PlanStep(
                kind="chain" if len(it.convs) > 1 else "fused",
                names=it.names, in_shape=in_shape, out_shape=cur, group=it,
                method=method_for(it.conv.name),
                oh_block=ohb_for(it.convs[-1].name),
                kwargs=kw))
            final_items.append(it)
            continue
        spec = it
        final_items.append(spec)
        in_shape = cur
        if spec.kind == "conv":
            c, h, w = cur
            h, w = _conv_out_hw(h, w, spec)
            c = spec.out_channels
            cur = (c, h, w)
            steps.append(PlanStep(
                "conv", (spec.name,), in_shape, cur, spec=spec,
                method=method_for(spec.name), oh_block=ohb_for(spec.name),
                relu=spec.relu))
        elif spec.kind == "pool":
            c, h, w = cur
            h, w = _pool_out_hw(h, w, spec)
            cur = (c, h, w)
            steps.append(PlanStep("pool", (spec.name,), in_shape, cur,
                                  spec=spec, relu=spec.relu))
        elif spec.kind == "lrn":
            steps.append(PlanStep("lrn", (spec.name,), in_shape, cur,
                                  spec=spec))
        elif spec.kind == "flatten":
            flat = int(cur[0] * cur[1] * cur[2]) if len(cur) == 3 else cur[0]
            cur = (flat,)
            steps.append(PlanStep("flatten", (spec.name,), in_shape, cur,
                                  spec=spec))
        elif spec.kind == "fc":
            d_in = flat if flat is not None else int(cur[0] * cur[1] * cur[2])
            flat = spec.out_channels
            pre_flatten = len(cur) == 3
            cur = (spec.out_channels,)
            steps.append(PlanStep(
                "fc", (spec.name,), in_shape, cur, spec=spec,
                method=method_for(spec.name), relu=spec.relu,
                pre_flatten=pre_flatten, d_in=d_in))
        elif spec.kind == "relu":
            # standalone-ReLU folding, resolved HERE not at trace time: a
            # relu following a conv/fc/pool step joins that step's
            # epilogue (its name joins the step so collect still sees it)
            if (fuse_relu and steps
                    and steps[-1].kind in ("conv", "fc", "pool")):
                steps[-1] = replace(steps[-1], relu=True,
                                    names=steps[-1].names + (spec.name,))
            else:
                steps.append(PlanStep("relu", (spec.name,), in_shape, cur,
                                      spec=spec))
        elif spec.kind == "softmax":
            steps.append(PlanStep("softmax", (spec.name,), in_shape, cur,
                                  spec=spec))
        else:
            raise ValueError(spec.kind)
    plan = ExecutionPlan(net=net, fuse=fuse, use_pallas=use_pallas,
                         steps=tuple(steps), items=tuple(final_items),
                         vmem_budget=vmem_budget)
    if verify:
        # deferred import: analysis imports this module at its top level
        from repro.analysis.verifier import PlanVerificationError, verify_plan

        errors = [f for f in verify_plan(plan) if f.severity == "error"]
        if errors:
            raise PlanVerificationError(errors)
    return plan
