"""The paper's three benchmark CNNs (Table 2): LeNet-5 (MNIST),
Alex Krizhevsky's CIFAR-10 network, and AlexNet (ImageNet 2012)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    kind: str  # conv | pool | lrn | fc | relu | softmax | flatten
    name: str
    # conv/fc
    out_channels: int = 0
    kernel: Tuple[int, int] = (0, 0)
    stride: Tuple[int, int] = (1, 1)
    padding: Tuple[int, int] = (0, 0)
    relu: bool = False  # fused activation (paper §4.2)
    # pool
    pool_kind: str = "max"  # max | avg
    # lrn
    lrn_n: int = 5
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    lrn_k: float = 1.0


@dataclass(frozen=True)
class NetworkDef:
    name: str
    input_shape: Tuple[int, int, int]  # (C, H, W)
    num_classes: int
    layers: Tuple[LayerSpec, ...]


def lenet5() -> NetworkDef:
    """LeNet-5 for MNIST [13] — Table 2 column 1."""
    return NetworkDef(
        name="lenet5",
        input_shape=(1, 28, 28),
        num_classes=10,
        layers=(
            LayerSpec("conv", "conv1", out_channels=20, kernel=(5, 5)),
            LayerSpec("pool", "pool1", kernel=(2, 2), stride=(2, 2)),
            LayerSpec("conv", "conv2", out_channels=50, kernel=(5, 5)),
            LayerSpec("pool", "pool2", kernel=(2, 2), stride=(2, 2)),
            LayerSpec("flatten", "flatten"),
            LayerSpec("fc", "fc1", out_channels=500, relu=True),
            LayerSpec("fc", "fc2", out_channels=10),
            LayerSpec("softmax", "prob"),
        ),
    )


def cifar10_quick() -> NetworkDef:
    """Krizhevsky's CIFAR-10 network [14] — Table 2 column 2."""
    return NetworkDef(
        name="cifar10",
        input_shape=(3, 32, 32),
        num_classes=10,
        layers=(
            LayerSpec("conv", "conv1", out_channels=32, kernel=(5, 5),
                      padding=(2, 2)),
            LayerSpec("pool", "pool1", kernel=(3, 3), stride=(2, 2),
                      relu=True),
            LayerSpec("conv", "conv2", out_channels=32, kernel=(5, 5),
                      padding=(2, 2), relu=True),
            LayerSpec("pool", "pool2", kernel=(3, 3), stride=(2, 2),
                      pool_kind="avg"),
            LayerSpec("conv", "conv3", out_channels=64, kernel=(5, 5),
                      padding=(2, 2), relu=True),
            LayerSpec("pool", "pool3", kernel=(3, 3), stride=(2, 2),
                      pool_kind="avg"),
            LayerSpec("flatten", "flatten"),
            LayerSpec("fc", "fc1", out_channels=64),
            LayerSpec("fc", "fc2", out_channels=10),
            LayerSpec("softmax", "prob"),
        ),
    )


def alexnet() -> NetworkDef:
    """Alex Krizhevsky's ImageNet 2012 CNN [15] (single-tower shapes,
    Fig. 8) — Table 2 column 3."""
    return NetworkDef(
        name="alexnet",
        input_shape=(3, 227, 227),
        num_classes=1000,
        layers=(
            LayerSpec("conv", "conv1", out_channels=96, kernel=(11, 11),
                      stride=(4, 4), relu=True),
            LayerSpec("pool", "pool1", kernel=(3, 3), stride=(2, 2)),
            LayerSpec("lrn", "norm1"),
            LayerSpec("conv", "conv2", out_channels=256, kernel=(5, 5),
                      padding=(2, 2), relu=True),
            LayerSpec("pool", "pool2", kernel=(3, 3), stride=(2, 2)),
            LayerSpec("lrn", "norm2"),
            LayerSpec("conv", "conv3", out_channels=384, kernel=(3, 3),
                      padding=(1, 1), relu=True),
            LayerSpec("conv", "conv4", out_channels=384, kernel=(3, 3),
                      padding=(1, 1), relu=True),
            LayerSpec("conv", "conv5", out_channels=256, kernel=(3, 3),
                      padding=(1, 1), relu=True),
            LayerSpec("pool", "pool5", kernel=(3, 3), stride=(2, 2)),
            LayerSpec("flatten", "flatten"),
            LayerSpec("fc", "fc6", out_channels=4096, relu=True),
            LayerSpec("fc", "fc7", out_channels=4096, relu=True),
            LayerSpec("fc", "fc8", out_channels=1000),
            LayerSpec("softmax", "prob"),
        ),
    )


NETWORKS = {"lenet5": lenet5, "cifar10": cifar10_quick, "alexnet": alexnet}
