"""The CNNdroid inference engine: forward-path executor with per-layer
method selection (the paper's core deliverable).

The engine owns:
* parameter init / loading (via ``core.deploy`` — the Caffe→device path),
* the forward executor with the execution-method ladder for conv/FC layers,
* fused-activation scheduling (ReLU folded into the producing layer —
  the TPU-native realization of the paper's Fig. 5 CPU/GPU overlap),
* super-layer fusion: ``repro.core.fusion.plan_fusion`` groups runs of
  consecutive convs plus an optional pool/LRN tail into single dispatches
  (``fuse_pool``, on by default, with per-layer opt-outs via
  ``per_layer_fuse``) so no intermediate of the run — conv chain bands,
  the pooled band under an absorbed LRN — ever round-trips through HBM
  (AlexNet's conv3→conv4→conv5+pool5 is one dispatch); a VMEM
  working-set check keeps shapes whose floor cell cannot fit the budget
  on the per-layer ladder, falling back to shorter chains first,
* per-layer instrumentation used by the benchmark harness (``collect``
  forces the un-fused per-layer path so every activation is observable).

Pooling runs through the Pallas ``pool2d`` kernels when ``use_pallas`` is
set, else as an XLA ``reduce_window``; LRN is a single channel-axis
``reduce_window`` (fp32 accumulation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedLayerSpec, layers_as_chain, plan_fusion
from repro.core.methods import (
    Method,
    conv2d,
    conv2d_chain_fused,
    conv2d_pool_fused,
    fc_fused,
    fc_seq_ref,
)
from repro.core.netdefs import LayerSpec, NetworkDef


def _pool(x, spec: LayerSpec, use_pallas: bool = False, relu: bool = False):
    """VALID pooling; ``relu`` is the folded standalone activation (applied
    on top of the spec's own)."""
    do_relu = spec.relu or relu
    if use_pallas:
        from repro.kernels.pool2d import ops as pool_ops

        return pool_ops.pool2d(x, spec.kernel, spec.stride, spec.pool_kind,
                               relu=do_relu)
    from repro.kernels.pool2d.ref import pool2d_ref

    return pool2d_ref(x, spec.kernel, spec.stride, spec.pool_kind,
                      relu=do_relu)


def _lrn(x, spec: LayerSpec):
    """Local response normalization across channels (AlexNet-style): one
    channel-axis ``reduce_window`` (fp32) instead of ``lrn_n`` slice+adds."""
    sq = x.astype(jnp.float32) ** 2
    n = spec.lrn_n
    # window [c - n//2, c + (n-1)//2]: asymmetric padding keeps the output
    # at C channels for even n too (symmetric pad would yield C+1)
    acc = jax.lax.reduce_window(
        sq, 0.0, jax.lax.add, (1, n, 1, 1), (1, 1, 1, 1),
        ((0, 0), (n // 2, n - 1 - n // 2), (0, 0), (0, 0)),
    )
    denom = (spec.lrn_k + spec.lrn_alpha * acc) ** spec.lrn_beta
    return (x.astype(jnp.float32) / denom).astype(x.dtype)


class CNNEngine:
    """Forward-path executor for a trained CNN."""

    def __init__(self, net: NetworkDef, method: Method = Method.ADVANCED_SIMD_8,
                 use_pallas: bool = False, fuse_relu: bool = True,
                 per_layer_methods: Optional[Dict[str, Method]] = None,
                 oh_block: Optional[int] = None,
                 per_layer_oh_blocks: Optional[Dict[str, int]] = None,
                 fuse_pool: bool = True,
                 per_layer_fuse: Optional[Dict[str, bool]] = None):
        self.net = net
        self.method = method
        self.use_pallas = use_pallas
        self.fuse_relu = fuse_relu
        self.per_layer_methods = per_layer_methods or {}
        # spatial tile (output-row band) for the Pallas SIMD conv kernels;
        # None = auto from the VMEM budget, overridable per layer like the
        # execution method itself
        self.oh_block = oh_block
        self.per_layer_oh_blocks = per_layer_oh_blocks or {}
        # super-layer fusion (conv[+relu][+pool] groups); per_layer_fuse
        # maps a conv/pool layer name -> False to opt it out of fusion,
        # mirroring per_layer_methods
        self.fuse_pool = fuse_pool
        self.per_layer_fuse = per_layer_fuse or {}
        self._shapes = self._infer_shapes()
        # plan + jit caches (keyed by fuse setting).  Engine config is
        # treated as fixed once forward has run — call clear_caches()
        # after mutating method/fuse/oh_block attributes in place.
        self._plans: Dict[bool, list] = {}
        self._jit_cache: Dict[bool, "jax.stages.Wrapped"] = {}

    def clear_caches(self) -> None:
        """Drop the memoized fusion plans and jitted forwards (call after
        mutating engine configuration in place)."""
        self._plans.clear()
        self._jit_cache.clear()

    # -- parameters -----------------------------------------------------------
    def _infer_shapes(self) -> Dict[str, Tuple]:
        """Propagate shapes through the net to size conv/fc parameters."""
        c, h, w = self.net.input_shape
        shapes: Dict[str, Tuple] = {}
        flat: Optional[int] = None
        for spec in self.net.layers:
            if spec.kind == "conv":
                kh, kw = spec.kernel
                shapes[spec.name] = (spec.out_channels, c, kh, kw)
                h = (h + 2 * spec.padding[0] - kh) // spec.stride[0] + 1
                w = (w + 2 * spec.padding[1] - kw) // spec.stride[1] + 1
                c = spec.out_channels
            elif spec.kind == "pool":
                kh, kw = spec.kernel
                h = (h - kh) // spec.stride[0] + 1
                w = (w - kw) // spec.stride[1] + 1
            elif spec.kind == "flatten":
                flat = c * h * w
            elif spec.kind == "fc":
                # an fc straight after a conv/pool (no flatten layer)
                # consumes the WHOLE activation — c*h*w, not just the
                # channel count (which silently dropped the spatial
                # extent); forward() flattens implicitly to match
                d_in = flat if flat is not None else c * h * w
                shapes[spec.name] = (d_in, spec.out_channels)
                flat = spec.out_channels
        return shapes

    def init(self, key) -> Dict[str, Dict[str, jnp.ndarray]]:
        params = {}
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / (ic * kh * kw)) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (oc, ic, kh, kw),
                                                 jnp.float32),
                    "b": jnp.zeros((oc,), jnp.float32),
                }
            elif spec.kind == "fc":
                d_in, d_out = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / d_in) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (d_in, d_out),
                                                 jnp.float32),
                    "b": jnp.zeros((d_out,), jnp.float32),
                }
        return params

    # -- forward ----------------------------------------------------------------
    def _method_for(self, name: str) -> Method:
        return self.per_layer_methods.get(name, self.method)

    def _oh_block_for(self, name: str) -> Optional[int]:
        return self.per_layer_oh_blocks.get(name, self.oh_block)

    def plan(self, fuse: Optional[bool] = None) -> list:
        """The execution plan: the layer list with conv[+relu][+pool] runs
        replaced by ``FusedLayerSpec`` groups when fusion is on."""
        use_fuse = self.fuse_pool if fuse is None else bool(fuse)
        if use_fuse not in self._plans:
            if use_fuse:
                no = frozenset(n for n, v in self.per_layer_fuse.items()
                               if not v)
                # the VMEM working-set check only binds on the Pallas
                # path; the XLA analogue fuses regardless of cell size
                self._plans[True] = plan_fusion(
                    self.net, method_for=self._method_for, no_fuse=no,
                    fuse_relu=self.fuse_relu, vmem_check=self.use_pallas)
            else:
                self._plans[False] = list(self.net.layers)
        return self._plans[use_fuse]

    def forward(self, params, x, collect: Optional[dict] = None,
                fuse: Optional[bool] = None):
        """x: [N, C, H, W] (a batch of frames, paper §4).  ``collect``
        (optional dict) receives per-layer outputs for inspection — it
        forces the un-fused per-layer path so every activation exists.
        ``fuse`` overrides the engine-level ``fuse_pool`` for this call."""
        if collect is not None:
            fuse = False  # instrumentation needs every per-layer output
        items = self.plan(fuse)
        i = 0
        while i < len(items):
            spec = items[i]
            if isinstance(spec, FusedLayerSpec):
                # super-layer: one dispatch; no intermediate of the run
                # (conv chain bands, pooled band under an absorbed LRN)
                # ever lands in HBM
                lrn = spec.lrn
                lrn_kw = dict(
                    lrn_n=lrn.lrn_n if lrn is not None else None,
                    lrn_alpha=lrn.lrn_alpha if lrn is not None else 1e-4,
                    lrn_beta=lrn.lrn_beta if lrn is not None else 0.75,
                    lrn_k=lrn.lrn_k if lrn is not None else 1.0)
                method = self._method_for(spec.conv.name)
                # a chain cell's band is defined in FINAL-stage rows, so
                # the last conv's oh_block override is the one that maps
                # onto it (overrides on earlier chain members have no
                # per-stage band to bind to)
                ohb = self._oh_block_for(spec.convs[-1].name)
                if len(spec.convs) == 1:
                    # single conv + pool: the oc-blocked epilogue kernel
                    p = params[spec.conv.name]
                    x = conv2d_pool_fused(
                        x, p["w"], p["b"], method, spec.conv.stride,
                        spec.conv.padding, spec.relu, spec.pool.kernel,
                        spec.pool.stride, spec.pool.pool_kind,
                        spec.pool_relu, self.use_pallas, ohb, **lrn_kw)
                else:
                    # conv chain (optional pool/LRN tail): the full-width
                    # chain cell, VMEM-resident halo between stages
                    pool = spec.pool
                    x = conv2d_chain_fused(
                        x, tuple(params[cv.name]["w"] for cv in spec.convs),
                        tuple(params[cv.name]["b"] for cv in spec.convs),
                        method, tuple(cv.stride for cv in spec.convs),
                        tuple(cv.padding for cv in spec.convs), spec.relus,
                        pool_kernel=pool.kernel if pool is not None else None,
                        pool_stride=pool.stride if pool is not None else None,
                        pool_kind=(pool.pool_kind if pool is not None
                                   else "max"),
                        pool_relu=spec.pool_relu,
                        use_pallas=self.use_pallas, oh_block=ohb, **lrn_kw)
                i += 1
                continue
            # fused-activation scheduling: a standalone relu following a
            # conv/fc/pool is folded into that layer's epilogue
            fused_relu = spec.relu
            if (self.fuse_relu and i + 1 < len(items)
                    and items[i + 1].kind == "relu"
                    and spec.kind in ("conv", "fc", "pool")):
                fused_relu = True
            if spec.kind == "conv":
                p = params[spec.name]
                x = conv2d(x, p["w"], p["b"], self._method_for(spec.name),
                           spec.stride, spec.padding, fused_relu,
                           self.use_pallas, self._oh_block_for(spec.name))
            elif spec.kind == "pool":
                x = _pool(x, spec, self.use_pallas, relu=fused_relu)
            elif spec.kind == "lrn":
                x = _lrn(x, spec)
            elif spec.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif spec.kind == "fc":
                if x.ndim > 2:  # fc after conv/pool without a flatten
                    x = x.reshape(x.shape[0], -1)
                p = params[spec.name]
                if self._method_for(spec.name) == Method.SEQ_REF:
                    x = fc_seq_ref(x, p["w"], p["b"], fused_relu)
                else:
                    x = fc_fused(x, p["w"], p["b"], fused_relu,
                                 self.use_pallas)
            elif spec.kind == "relu":
                if not (self.fuse_relu and i > 0
                        and items[i - 1].kind in ("conv", "fc", "pool")):
                    x = jnp.maximum(x, 0.0)
            elif spec.kind == "softmax":
                x = jax.nn.softmax(x.astype(jnp.float32), axis=-1)
            else:
                raise ValueError(spec.kind)
            if collect is not None:
                collect[spec.name] = x
            i += 1
        return x

    def jit_forward(self, fuse: Optional[bool] = None):
        """The jitted forward, memoized per fuse setting — repeated calls
        (``time_forward``, every bench iteration) reuse one compilation."""
        key = self.fuse_pool if fuse is None else bool(fuse)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                partial(self.forward, fuse=key))
        return self._jit_cache[key]

    # -- instrumentation ----------------------------------------------------------
    def fusion_report(self, fuse: Optional[bool] = None) -> List[dict]:
        """Executed geometry of every fused group in the plan: the layer
        names covered, the chain depth (``convs``), the group's output
        spatial size, and the final-row band the Pallas cell resolves —
        ``rows_per_cell`` pooled/final rows per grid cell × ``n_tiles``
        bands per frame (the XLA analogue runs each group as one
        un-banded pass; the banding reported is the Pallas path's).
        Shares ``kernels.resolve_ph_block``/``resolve_chain_block`` with
        the kernels themselves, so the report IS what a Pallas run would
        execute."""
        from repro.core.fusion import _conv_out_hw, _pool_out_hw
        from repro.kernels.conv2d import kernels as K
        from repro.kernels.conv2d.ops import SUBLANES

        report = []
        c, h, w = self.net.input_shape
        for it in self.plan(fuse):
            if not isinstance(it, FusedLayerSpec):
                if it.kind == "conv":
                    h, w = _conv_out_hw(h, w, it)
                    c = it.out_channels
                elif it.kind == "pool":
                    h, w = _pool_out_hw(h, w, it)
                continue
            method = self._method_for(it.conv.name)
            im2col = method in (Method.ADVANCED_SIMD_4,
                                Method.ADVANCED_SIMD_8)
            cp = -(-c // SUBLANES) * SUBLANES
            ohb = self._oh_block_for(it.convs[-1].name)
            pool_t = (None if it.pool is None else
                      (it.pool.kernel[0], it.pool.kernel[1],
                       it.pool.stride[0], it.pool.stride[1]))
            if len(it.convs) == 1:
                # single conv + pool: the oc-blocked epilogue kernel
                cv = it.convs[0]
                oh, ow = _conv_out_hw(h, w, cv)
                wp = w + 2 * cv.padding[1]
                oc = cv.out_channels
                if not im2col or it.lrn is not None:
                    ocb = oc  # basic_simd / LRN tail: full oc width
                else:
                    ocb = min(4 if method == Method.ADVANCED_SIMD_4 else 8,
                              oc)
                ph = (oh - pool_t[0]) // pool_t[2] + 1
                blk, n_tiles = K.resolve_ph_block(
                    ph, oh, ow, wp, cp, cv.kernel[0], cv.kernel[1],
                    cv.stride[0], ocb, pool_t, ohb, im2col=im2col)
            else:
                chain, ocs = layers_as_chain(it.convs)
                blk, n_tiles = K.resolve_chain_block(
                    h, w, cp, chain, ocs, pool_t, ohb, im2col=im2col)
            for cv in it.convs:
                h, w = _conv_out_hw(h, w, cv)
            c = it.convs[-1].out_channels
            if it.pool is not None:
                h, w = _pool_out_hw(h, w, it.pool)
            report.append({"group": it.name, "convs": len(it.convs),
                           "rows_per_cell": blk, "n_tiles": n_tiles,
                           "out_hw": [h, w]})
        return report

    def time_forward(self, params, x, iters: int = 3,
                     fuse: Optional[bool] = None) -> float:
        fn = self.jit_forward(fuse)
        fn(params, x).block_until_ready()  # compile + warm (cached)
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(params, x).block_until_ready()
        return (time.perf_counter() - t0) / iters

    def heaviest_conv(self, params, x) -> Tuple[str, "jnp.ndarray"]:
        """The conv layer with the most MACs (paper Table 4 target) and its
        input activation."""
        best, best_macs, best_in = None, -1, None
        acts: dict = {}
        self.forward(params, x, collect=acts)
        cur = x
        c, h, w = self.net.input_shape
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                out = acts[spec.name]
                macs = int(np.prod(out.shape)) * ic * kh * kw
                if macs > best_macs:
                    best, best_macs, best_in = spec, macs, cur
            cur = acts[spec.name]
        return best.name, best_in

    def conv_layer_fn(self, name: str, method: Method,
                      oh_block: Optional[int] = None):
        spec = next(s for s in self.net.layers if s.name == name)
        ohb = oh_block if oh_block is not None else self._oh_block_for(name)

        def fn(params, x):
            p = params[name]
            return conv2d(x, p["w"], p["b"], method, spec.stride,
                          spec.padding, True, self.use_pallas, ohb)

        return fn
