"""The CNNdroid inference engine: forward-path executor with per-layer
method selection (the paper's core deliverable).

The engine compiles its network into the **ExecutionPlan IR**
(``repro.core.plan``) once per fuse setting and executes it with a thin
step loop — shape propagation, standalone-ReLU folding, super-layer
fusion grouping (``repro.core.fusion.plan_fusion``), and per-layer
method/``oh_block`` resolution all happen at ``compile_plan`` time, not
per trace.  The engine owns:

* parameter init / loading (via ``core.deploy`` — the Caffe→device path),
* the compiled plans (memoized per fuse flag) and their jitted forwards,
  including a **batch-bucketed jit cache**: ``forward_batched`` rounds a
  request batch up to its power-of-two bucket, pads with zero frames,
  runs the bucket's memoized jitted plan, and slices the real rows back
  out — arbitrary batch sizes in ``1..max_batch`` cost at most
  ``log2(max_batch)+1`` compilations instead of one per distinct size
  (the paper's §6.2 deployment is batched frames; ``serving.cnn`` is
  built on this path),
* knob invalidation: assigning ``method`` / ``oh_block`` / ``fuse_pool``
  / ``fuse_relu`` / ``use_pallas``, or mutating the ``per_layer_*``
  maps, drops every memoized plan and jitted forward so the next call
  re-compiles against the new configuration (the old behaviour —
  silently serving the stale plan — was a bug),
* per-layer instrumentation used by the benchmark harness (``collect``
  forces the un-fused plan so every activation is observable).

Execution semantics live in ``repro.core.plan``'s step executors:
pooling runs through the Pallas ``pool2d`` kernels when ``use_pallas``
is set, else as an XLA ``reduce_window``; LRN is a single channel-axis
``reduce_window`` (fp32); fused groups dispatch to
``methods.conv2d_pool_fused`` / ``conv2d_chain_fused``.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding, PlanVerificationError
from repro.core.methods import Method, conv2d
from repro.core.netdefs import NetworkDef
from repro.core.plan import (  # noqa: F401  (_pool/_lrn re-exported: the
    ExecutionPlan,             # executors moved to the plan IR but their
    _lrn,                      # home here predates it)
    _pool,
    compile_plan,
    infer_param_shapes,
)


class _KnobDict(dict):
    """A per-layer knob map that invalidates the owning engine's caches
    on any mutation — ``eng.per_layer_fuse["conv1"] = False`` after a
    forward must re-plan, not keep serving the memoized stale plan."""

    def __init__(self, on_change, data=None):
        super().__init__(data or {})
        self._on_change = on_change

    def __setitem__(self, k, v):
        # no-op writes don't invalidate: a loop idempotently re-asserting
        # config must keep its warm jit caches
        changed = k not in self or self[k] != v
        super().__setitem__(k, v)
        if changed:
            self._on_change()

    def __delitem__(self, k):
        super().__delitem__(k)
        self._on_change()

    def update(self, *args, **kwargs):
        before = dict(self)
        super().update(*args, **kwargs)
        if dict(self) != before:
            self._on_change()

    def __ior__(self, other):
        # dict.__ior__ bypasses update(): |= must invalidate too
        self.update(other)
        return self

    def setdefault(self, k, default=None):
        if k in self:  # pure read
            return self[k]
        super().__setitem__(k, default)
        self._on_change()
        return default

    def pop(self, *args):
        out = super().pop(*args)
        self._on_change()
        return out

    def popitem(self):
        out = super().popitem()
        self._on_change()
        return out

    def clear(self):
        super().clear()
        self._on_change()


_UNSET = object()


def _knob(name: str):
    """A config property whose assignment drops the memoized plans and
    jitted forwards (mutating engine config used to silently keep
    serving the stale plan).  Re-assigning the current value is a no-op:
    warm caches survive idempotent config re-assertion."""
    attr = "_" + name

    def get(self):
        return getattr(self, attr)

    def set_(self, value):
        cur = getattr(self, attr, _UNSET)
        if cur is not _UNSET and (cur is value or cur == value):
            return
        setattr(self, attr, value)
        self.clear_caches()

    return property(get, set_)


def _dict_knob(name: str):
    """A per-layer map knob: reassignment re-wraps into a ``_KnobDict``
    (invalidating only on a real content change); in-place mutation
    invalidates via the wrapper."""
    attr = "_" + name

    def get(self):
        return getattr(self, attr)

    def set_(self, value):
        changed = dict(getattr(self, attr, {})) != dict(value or {})
        setattr(self, attr, _KnobDict(self.clear_caches, value))
        if changed:
            self.clear_caches()

    return property(get, set_)


class CNNEngine:
    """Forward-path executor for a trained CNN."""

    method = _knob("method")
    use_pallas = _knob("use_pallas")
    fuse_relu = _knob("fuse_relu")
    fuse_pool = _knob("fuse_pool")
    oh_block = _knob("oh_block")
    per_layer_methods = _dict_knob("per_layer_methods")
    per_layer_oh_blocks = _dict_knob("per_layer_oh_blocks")
    per_layer_fuse = _dict_knob("per_layer_fuse")
    per_layer_pool_carry = _dict_knob("per_layer_pool_carry")
    per_layer_lrn_oc_block = _dict_knob("per_layer_lrn_oc_block")
    per_layer_oc_block_final = _dict_knob("per_layer_oc_block_final")

    def __init__(self, net: NetworkDef, method: Method = Method.ADVANCED_SIMD_8,
                 use_pallas: bool = False, fuse_relu: bool = True,
                 per_layer_methods: Optional[Dict[str, Method]] = None,
                 oh_block: Optional[int] = None,
                 per_layer_oh_blocks: Optional[Dict[str, int]] = None,
                 fuse_pool: bool = True,
                 per_layer_fuse: Optional[Dict[str, bool]] = None,
                 per_layer_pool_carry: Optional[Dict[str, bool]] = None,
                 per_layer_lrn_oc_block: Optional[Dict[str, bool]] = None,
                 per_layer_oc_block_final: Optional[Dict[str, int]] = None):
        self.net = net
        # plan + jit caches (created first: the knob setters below clear
        # them on every assignment, including these initial ones)
        self._plans: Dict[bool, ExecutionPlan] = {}
        self._jit_cache: Dict[bool, "jax.stages.Wrapped"] = {}
        # batch-bucketed jits: (fuse, bucket) -> jitted forward.  Each
        # bucket jit only ever sees ONE batch shape (inputs are padded up
        # to the bucket), so len(_bucket_jits) IS the compile count.
        self._bucket_jits: Dict[Tuple[bool, int], "jax.stages.Wrapped"] = {}
        self._bucket_compiles = 0
        self.method = method
        self.use_pallas = use_pallas
        self.fuse_relu = fuse_relu
        self.per_layer_methods = per_layer_methods or {}
        # spatial tile (output-row band) for the Pallas SIMD conv kernels;
        # None = auto from the VMEM budget, overridable per layer like the
        # execution method itself
        self.oh_block = oh_block
        self.per_layer_oh_blocks = per_layer_oh_blocks or {}
        # super-layer fusion (conv[+relu][+pool] groups); per_layer_fuse
        # maps a conv/pool layer name -> False to opt it out of fusion,
        # mirroring per_layer_methods
        self.fuse_pool = fuse_pool
        self.per_layer_fuse = per_layer_fuse or {}
        # second-generation fused-cell knobs (None/absent = the kernel
        # resolvers' auto rule), keyed like per_layer_methods
        self.per_layer_pool_carry = per_layer_pool_carry or {}
        self.per_layer_lrn_oc_block = per_layer_lrn_oc_block or {}
        self.per_layer_oc_block_final = per_layer_oc_block_final or {}
        self._shapes = infer_param_shapes(net)

    def clear_caches(self) -> None:
        """Drop the memoized execution plans and every jitted forward
        (per-fuse and batch-bucketed).  Called automatically by the knob
        setters; only direct mutation of private state needs it by hand."""
        self._plans.clear()
        self._jit_cache.clear()
        self._bucket_jits.clear()
        self._bucket_compiles = 0  # the count tracks the live cache

    # -- parameters -----------------------------------------------------------
    def init(self, key) -> Dict[str, Dict[str, jnp.ndarray]]:
        params = {}
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / (ic * kh * kw)) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (oc, ic, kh, kw),
                                                 jnp.float32),
                    "b": jnp.zeros((oc,), jnp.float32),
                }
            elif spec.kind == "fc":
                d_in, d_out = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / d_in) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (d_in, d_out),
                                                 jnp.float32),
                    "b": jnp.zeros((d_out,), jnp.float32),
                }
        return params

    # -- forward ----------------------------------------------------------------
    def _oh_block_for(self, name: str) -> Optional[int]:
        return self.per_layer_oh_blocks.get(name, self.oh_block)

    def plan(self, fuse: Optional[bool] = None) -> ExecutionPlan:
        """The compiled ``ExecutionPlan`` for this engine configuration,
        memoized per fuse flag (iterating it yields the layer/group
        items, so ``fusion_summary(eng.plan(True))`` keeps working)."""
        use_fuse = self.fuse_pool if fuse is None else bool(fuse)
        if use_fuse not in self._plans:
            # the VMEM working-set check only binds on the Pallas path;
            # the XLA analogue fuses regardless of cell size
            self._plans[use_fuse] = compile_plan(
                self.net, method=self.method,
                per_layer_methods=self.per_layer_methods,
                oh_block=self.oh_block,
                per_layer_oh_blocks=self.per_layer_oh_blocks,
                fuse=use_fuse, fuse_relu=self.fuse_relu,
                per_layer_fuse=self.per_layer_fuse,
                per_layer_pool_carry=self.per_layer_pool_carry,
                per_layer_lrn_oc_block=self.per_layer_lrn_oc_block,
                per_layer_oc_block_final=self.per_layer_oc_block_final,
                use_pallas=self.use_pallas)
        return self._plans[use_fuse]

    def verify(self, fuse: Optional[bool] = None) -> List[Finding]:
        """Run the static plan verifier over this engine's compiled plan
        and return ALL findings (``compile_plan`` already raises on
        error-severity ones; this surfaces the warnings/infos too —
        the knob-sweep oracle the autotuner arc builds on)."""
        from repro.analysis.verifier import verify_plan

        return verify_plan(self.plan(fuse))

    #: knob names switch_verified accepts — exactly the cache-invalidating
    #: configuration surface (the _knob/_dict_knob descriptors above)
    KNOBS = ("method", "use_pallas", "fuse_relu", "fuse_pool", "oh_block",
             "per_layer_methods", "per_layer_oh_blocks", "per_layer_fuse",
             "per_layer_pool_carry", "per_layer_lrn_oc_block",
             "per_layer_oc_block_final")

    def switch_verified(self, **knobs) -> Tuple[bool, List[Finding]]:
        """Atomically apply a candidate knob configuration, but only if
        its compiled plan passes static verification with no
        error-severity findings — otherwise roll every knob back and
        report why.  This is the degradation ladder's gate: a rung is
        never served until ``CNNEngine.verify()`` has blessed it.

        Returns ``(switched, findings)``: ``findings`` is the full list
        (warnings/infos included on success, the error findings on
        rollback).  Unknown knob names raise — a typo must not silently
        verify the unchanged configuration."""
        unknown = set(knobs) - set(self.KNOBS)
        if unknown:
            raise ValueError(f"unknown knob(s): {sorted(unknown)}")
        snapshot = {k: (dict(getattr(self, k)) if k.startswith("per_layer")
                        else getattr(self, k)) for k in knobs}
        for k, v in knobs.items():
            setattr(self, k, v)
        try:
            findings = self.verify()
        except PlanVerificationError as e:
            findings = e.findings
        if any(f.severity == "error" for f in findings):
            for k, v in snapshot.items():
                setattr(self, k, v)
            return False, findings
        return True, findings

    def forward(self, params, x, collect: Optional[dict] = None,
                fuse: Optional[bool] = None):
        """x: [N, C, H, W] (a batch of frames, paper §4).  ``collect``
        (optional dict) receives per-layer outputs for inspection — it
        forces the un-fused plan so every activation exists.  ``fuse``
        overrides the engine-level ``fuse_pool`` for this call.  All
        fusion/folding decisions were made at ``compile_plan`` time;
        this is a thin loop of step executors."""
        if collect is not None:
            fuse = False  # instrumentation needs every per-layer output
        return self.plan(fuse).execute(params, x, collect=collect)

    def jit_forward(self, fuse: Optional[bool] = None):
        """The jitted forward, memoized per fuse setting — repeated calls
        (``time_forward``, every bench iteration) reuse one compilation."""
        key = self.fuse_pool if fuse is None else bool(fuse)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                partial(self.forward, fuse=key))
        return self._jit_cache[key]

    # -- batch-bucketed forward (serving path) --------------------------------
    @staticmethod
    def batch_bucket(n: int) -> int:
        """The power-of-two bucket a batch of ``n`` requests rounds up
        to: every batch size in ``1..max_batch`` lands in one of the
        ``log2(max_batch)+1`` buckets ``{1, 2, 4, ..., max_batch}``."""
        if n < 1:
            raise ValueError(f"batch must be >= 1, got {n}")
        return 1 << (int(n) - 1).bit_length()

    def _bucket_jit(self, fuse: bool, bucket: int):
        key = (fuse, bucket)
        if key not in self._bucket_jits:
            self._bucket_jits[key] = jax.jit(partial(self.forward, fuse=fuse))
            self._bucket_compiles += 1
        return self._bucket_jits[key]

    def forward_batched(self, params, x, fuse: Optional[bool] = None):
        """``forward`` through the batch-bucketed jit cache: pad the
        batch up to its power-of-two bucket with zero frames, run the
        bucket's memoized jitted plan, slice the real rows back out.
        Arbitrary request batch sizes hit at most ``log2(max_batch)+1``
        compiled variants — the steady-state serving path (``CNNServer``)
        never recompiles once its buckets are warm."""
        use_fuse = self.fuse_pool if fuse is None else bool(fuse)
        n = x.shape[0]
        bucket = self.batch_bucket(n)
        fn = self._bucket_jit(use_fuse, bucket)
        if bucket != n:
            pad = jnp.zeros((bucket - n, *x.shape[1:]), x.dtype)
            x = jnp.concatenate([x, pad], axis=0)
        return fn(params, x)[:n]

    def bucket_stats(self) -> dict:
        """Bucketed-jit cache introspection: the live (fuse, bucket)
        keys and the total number of bucket compilations this engine has
        paid (monotone until ``clear_caches`` — the compile-count tests
        assert repeat batch sizes within a bucket add nothing)."""
        return {"buckets": sorted(self._bucket_jits),
                "compiles": self._bucket_compiles}

    # -- instrumentation ----------------------------------------------------------
    def fusion_report(self, fuse: Optional[bool] = None) -> List[dict]:
        """Executed geometry of every fused group — read straight off the
        compiled plan's steps (each carries its resolved input shape,
        method, and band override): the layer names covered, the chain
        depth (``convs``), the group's output spatial size, and the
        final-row band the Pallas cell resolves (``rows_per_cell`` ×
        ``n_tiles``; the XLA analogue runs each group un-banded)."""
        return self.plan(fuse).fusion_report()

    def time_forward(self, params, x, iters: int = 3,
                     fuse: Optional[bool] = None) -> float:
        fn = self.jit_forward(fuse)
        fn(params, x).block_until_ready()  # compile + warm (cached)
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(params, x).block_until_ready()
        return (time.perf_counter() - t0) / iters

    def heaviest_conv(self, params, x) -> Tuple[str, "jnp.ndarray"]:
        """The conv layer with the most MACs (paper Table 4 target) and its
        input activation."""
        best, best_macs, best_in = None, -1, None
        acts: dict = {}
        self.forward(params, x, collect=acts)
        cur = x
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                out = acts[spec.name]
                macs = int(np.prod(out.shape)) * ic * kh * kw
                if macs > best_macs:
                    best, best_macs, best_in = spec, macs, cur
            cur = acts[spec.name]
        return best.name, best_in

    def conv_layer_fn(self, name: str, method: Method,
                      oh_block: Optional[int] = None):
        spec = next(s for s in self.net.layers if s.name == name)
        ohb = oh_block if oh_block is not None else self._oh_block_for(name)

        def fn(params, x):
            p = params[name]
            return conv2d(x, p["w"], p["b"], method, spec.stride,
                          spec.padding, True, self.use_pallas, ohb)

        return fn
