"""The CNNdroid inference engine: forward-path executor with per-layer
method selection (the paper's core deliverable).

The engine owns:
* parameter init / loading (via ``core.deploy`` — the Caffe→device path),
* the forward executor with the execution-method ladder for conv/FC layers,
* fused-activation scheduling (ReLU folded into the producing layer —
  the TPU-native realization of the paper's Fig. 5 CPU/GPU overlap),
* per-layer instrumentation used by the benchmark harness.

Pooling and LRN run as plain XLA ops ("accelerated on mobile CPU via
multi-threading" in the paper; on our stack XLA:CPU/TPU handles them).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods import Method, conv2d, fc_fused, fc_seq_ref
from repro.core.netdefs import LayerSpec, NetworkDef


def _pool(x, spec: LayerSpec):
    kh, kw = spec.kernel
    sy, sx = spec.stride
    if spec.pool_kind == "max":
        out = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 1, kh, kw), (1, 1, sy, sx), "VALID"
        )
    else:
        out = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 1, kh, kw), (1, 1, sy, sx), "VALID"
        ) / float(kh * kw)
    if spec.relu:
        out = jnp.maximum(out, 0.0)
    return out


def _lrn(x, spec: LayerSpec):
    """Local response normalization across channels (AlexNet-style)."""
    sq = x.astype(jnp.float32) ** 2
    n = spec.lrn_n
    pad = n // 2
    sq_p = jnp.pad(sq, ((0, 0), (pad, pad), (0, 0), (0, 0)))
    acc = jnp.zeros_like(sq)
    for i in range(n):
        acc = acc + jax.lax.slice_in_dim(sq_p, i, i + x.shape[1], axis=1)
    denom = (spec.lrn_k + spec.lrn_alpha * acc) ** spec.lrn_beta
    return (x.astype(jnp.float32) / denom).astype(x.dtype)


class CNNEngine:
    """Forward-path executor for a trained CNN."""

    def __init__(self, net: NetworkDef, method: Method = Method.ADVANCED_SIMD_8,
                 use_pallas: bool = False, fuse_relu: bool = True,
                 per_layer_methods: Optional[Dict[str, Method]] = None,
                 oh_block: Optional[int] = None,
                 per_layer_oh_blocks: Optional[Dict[str, int]] = None):
        self.net = net
        self.method = method
        self.use_pallas = use_pallas
        self.fuse_relu = fuse_relu
        self.per_layer_methods = per_layer_methods or {}
        # spatial tile (output-row band) for the Pallas SIMD conv kernels;
        # None = auto from the VMEM budget, overridable per layer like the
        # execution method itself
        self.oh_block = oh_block
        self.per_layer_oh_blocks = per_layer_oh_blocks or {}
        self._shapes = self._infer_shapes()

    # -- parameters -----------------------------------------------------------
    def _infer_shapes(self) -> Dict[str, Tuple]:
        """Propagate shapes through the net to size conv/fc parameters."""
        c, h, w = self.net.input_shape
        shapes: Dict[str, Tuple] = {}
        flat: Optional[int] = None
        for spec in self.net.layers:
            if spec.kind == "conv":
                kh, kw = spec.kernel
                shapes[spec.name] = (spec.out_channels, c, kh, kw)
                h = (h + 2 * spec.padding[0] - kh) // spec.stride[0] + 1
                w = (w + 2 * spec.padding[1] - kw) // spec.stride[1] + 1
                c = spec.out_channels
            elif spec.kind == "pool":
                kh, kw = spec.kernel
                h = (h - kh) // spec.stride[0] + 1
                w = (w - kw) // spec.stride[1] + 1
            elif spec.kind == "flatten":
                flat = c * h * w
            elif spec.kind == "fc":
                d_in = flat if flat is not None else c
                shapes[spec.name] = (d_in, spec.out_channels)
                flat = spec.out_channels
        return shapes

    def init(self, key) -> Dict[str, Dict[str, jnp.ndarray]]:
        params = {}
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / (ic * kh * kw)) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (oc, ic, kh, kw),
                                                 jnp.float32),
                    "b": jnp.zeros((oc,), jnp.float32),
                }
            elif spec.kind == "fc":
                d_in, d_out = self._shapes[spec.name]
                key, k1 = jax.random.split(key)
                std = (2.0 / d_in) ** 0.5
                params[spec.name] = {
                    "w": std * jax.random.normal(k1, (d_in, d_out),
                                                 jnp.float32),
                    "b": jnp.zeros((d_out,), jnp.float32),
                }
        return params

    # -- forward ----------------------------------------------------------------
    def _method_for(self, name: str) -> Method:
        return self.per_layer_methods.get(name, self.method)

    def _oh_block_for(self, name: str) -> Optional[int]:
        return self.per_layer_oh_blocks.get(name, self.oh_block)

    def forward(self, params, x, collect: Optional[dict] = None):
        """x: [N, C, H, W] (a batch of frames, paper §4).  ``collect``
        (optional dict) receives per-layer outputs for inspection."""
        layers = list(self.net.layers)
        i = 0
        while i < len(layers):
            spec = layers[i]
            # fused-activation scheduling: a standalone relu following a
            # conv/fc/pool is folded into that layer's epilogue
            fused_relu = spec.relu
            if (self.fuse_relu and i + 1 < len(layers)
                    and layers[i + 1].kind == "relu"
                    and spec.kind in ("conv", "fc", "pool")):
                fused_relu = True
            if spec.kind == "conv":
                p = params[spec.name]
                x = conv2d(x, p["w"], p["b"], self._method_for(spec.name),
                           spec.stride, spec.padding, fused_relu,
                           self.use_pallas, self._oh_block_for(spec.name))
            elif spec.kind == "pool":
                x = _pool(x, spec)
                if fused_relu and not spec.relu:
                    x = jnp.maximum(x, 0.0)
            elif spec.kind == "lrn":
                x = _lrn(x, spec)
            elif spec.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif spec.kind == "fc":
                p = params[spec.name]
                if self._method_for(spec.name) == Method.SEQ_REF:
                    x = fc_seq_ref(x, p["w"], p["b"], fused_relu)
                else:
                    x = fc_fused(x, p["w"], p["b"], fused_relu,
                                 self.use_pallas)
            elif spec.kind == "relu":
                if not (self.fuse_relu and i > 0
                        and layers[i - 1].kind in ("conv", "fc", "pool")):
                    x = jnp.maximum(x, 0.0)
            elif spec.kind == "softmax":
                x = jax.nn.softmax(x.astype(jnp.float32), axis=-1)
            else:
                raise ValueError(spec.kind)
            if collect is not None:
                collect[spec.name] = x
            i += 1
        return x

    def jit_forward(self):
        return jax.jit(self.forward)

    # -- instrumentation ----------------------------------------------------------
    def time_forward(self, params, x, iters: int = 3) -> float:
        fn = self.jit_forward()
        fn(params, x).block_until_ready()  # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(params, x).block_until_ready()
        return (time.perf_counter() - t0) / iters

    def heaviest_conv(self, params, x) -> Tuple[str, "jnp.ndarray"]:
        """The conv layer with the most MACs (paper Table 4 target) and its
        input activation."""
        best, best_macs, best_in = None, -1, None
        acts: dict = {}
        self.forward(params, x, collect=acts)
        cur = x
        c, h, w = self.net.input_shape
        for spec in self.net.layers:
            if spec.kind == "conv":
                oc, ic, kh, kw = self._shapes[spec.name]
                out = acts[spec.name]
                macs = int(np.prod(out.shape)) * ic * kh * kw
                if macs > best_macs:
                    best, best_macs, best_in = spec, macs, cur
            cur = acts[spec.name]
        return best.name, best_in

    def conv_layer_fn(self, name: str, method: Method,
                      oh_block: Optional[int] = None):
        spec = next(s for s in self.net.layers if s.name == name)
        ohb = oh_block if oh_block is not None else self._oh_block_for(name)

        def fn(params, x):
            p = params[name]
            return conv2d(x, p["w"], p["b"], method, spec.stride,
                          spec.padding, True, self.use_pallas, ohb)

        return fn
