"""The CNNdroid execution-method ladder (§4 of the paper), in JAX.

Each method computes the same convolution (or FC) with a different
data-layout / blocking strategy.  On this CPU container the four methods
are honest algorithmic restagings whose XLA lowerings differ exactly the
way the paper's RenderScript kernels differ (loop order, layout, reuse);
on TPU the corresponding Pallas kernels in ``repro.kernels.conv2d`` are
selected via ``use_pallas``.

Ladder (paper table 3/4 columns):
  SEQ_REF          — §4.1 CPU-only sequential: direct NCHW convolution,
                     kernel-position loops, no vectorized reduction.
  BASIC_PARALLEL   — §4.2 one thread per output element, NCHW, width
                     innermost: parallel over outputs, scalar channel loop.
  BASIC_SIMD       — §4.3 dimension swapping: NHWC, channels innermost,
                     vectorized channel dot product.
  ADVANCED_SIMD_4/8 — §4.4 each thread computes 4/8 output channels: im2col
                     patch reuse across an output-channel block + fused
                     bias/activation epilogue (on TPU: one MXU matmul per
                     patch block).

``conv2d_pool_fused`` is the super-layer entry point used by the fusion
planner (``repro.core.fusion``): one dispatch computes conv→ReLU→pool so
the intermediate conv activation is never materialized between layers.
"""
from __future__ import annotations

import enum
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.layout import nchw_to_nhwc, nhwc_to_nchw, oihw_to_hwio


class Method(enum.Enum):
    SEQ_REF = "seq_ref"
    BASIC_PARALLEL = "basic_parallel"
    BASIC_SIMD = "basic_simd"
    ADVANCED_SIMD_4 = "advanced_simd_4"
    ADVANCED_SIMD_8 = "advanced_simd_8"


LADDER = (
    Method.SEQ_REF,
    Method.BASIC_PARALLEL,
    Method.BASIC_SIMD,
    Method.ADVANCED_SIMD_4,
    Method.ADVANCED_SIMD_8,
)


def pallas_method_name(method: Method, what: str = "fused super-layer") -> str:
    """The ``kernels.conv2d`` method-name string for a fusable SIMD
    ``Method`` — the shared gate of both fused entry points (the planner
    keeps ``seq_ref``/``basic_parallel`` on the per-layer ladder, so a
    non-SIMD method reaching a fused dispatch is a caller bug)."""
    if method == Method.BASIC_SIMD:
        return "basic_simd"
    if method == Method.ADVANCED_SIMD_4:
        return "advanced_simd_4"
    if method == Method.ADVANCED_SIMD_8:
        return "advanced_simd_8"
    raise ValueError(f"{what} requires a SIMD method: {method}")


def _out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


# ---------------------------------------------------------------------------
# §4.1 sequential reference — direct convolution, NCHW, no reuse structure
# ---------------------------------------------------------------------------


def conv2d_seq_ref(x, w, b, stride=(1, 1), padding=(0, 0), relu=False):
    """x: [N, C, H, W]; w: [OC, C, KH, KW]; b: [OC].

    Literal restaging of the sequential loop nest: for every kernel offset
    (kh, kw) accumulate x[...] * w[...] — the reduction is over *kernel
    positions*, never a vectorized channel dot.
    """
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    out = jnp.zeros((n, oc, oh, ow), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, 0, i, j),
                (n, c, i + (oh - 1) * sy + 1, j + (ow - 1) * sx + 1),
                (1, 1, sy, sx),
            )  # [n, c, oh, ow]
            out = out + jnp.einsum(
                "nchw,oc->nohw", patch.astype(jnp.float32),
                w[:, :, i, j].astype(jnp.float32),
            )
    out = out + b[None, :, None, None].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# §4.2 basic parallel — one output element per thread, NCHW
# ---------------------------------------------------------------------------


def conv2d_basic_parallel(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                          use_pallas=False):
    """Parallel over output elements; each computes its own receptive-field
    reduction in NCHW order (channels are the OUTER reduction loop, width
    inner — the paper's §4.2 loop order)."""
    if use_pallas:
        from repro.kernels.conv2d import ops as conv_ops

        return conv_ops.conv2d(x, w, b, stride, padding, relu,
                               method="basic_parallel")
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    # extract_patches: [n, c*kh*kw, oh, ow] then reduce with the kernel.
    patches = jax.lax.conv_general_dilated_patches(
        xp, (kh, kw), (sy, sx), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # [n, c*kh*kw, oh, ow]
    wf = w.reshape(oc, c * kh * kw)
    out = jnp.einsum("nkhw,ok->nohw", patches.astype(jnp.float32),
                     wf.astype(jnp.float32))
    out = out + b[None, :, None, None].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# shared NHWC conv cores (used by the per-layer §4.3/§4.4 wrappers AND the
# fused super-layer — one copy of the conv math)
# ---------------------------------------------------------------------------


def _conv_positions_nhwc(xp, wh, oh, ow, sy, sx):
    """Basic-SIMD core: per-kernel-position vectorized channel dot over a
    padded NHWC input; returns the fp32 [n, oh, ow, oc] pre-bias output."""
    n, _, _, c = xp.shape
    kh, kw, _, oc = wh.shape
    out = jnp.zeros((n, oh, ow, oc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (oh - 1) * sy + 1, j + (ow - 1) * sx + 1, c),
                (1, sy, sx, 1),
            )  # [n, oh, ow, c]
            # vectorized dot over the (innermost) channel axis
            out = out + jnp.einsum(
                "nhwc,co->nhwo", patch.astype(jnp.float32),
                wh[i, j].astype(jnp.float32),
            )
    return out


def _im2col_nhwc(xp, kh, kw, oh, ow, sy, sx):
    """Advanced-SIMD im2col: one patch load reused for all oc blocks;
    returns [n, oh, ow, kh*kw*c]."""
    n, _, _, c = xp.shape
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                xp, (0, i, j, 0),
                (n, i + (oh - 1) * sy + 1, j + (ow - 1) * sx + 1, c),
                (1, sy, sx, 1),
            ))
    return jnp.concatenate(cols, axis=-1)


# ---------------------------------------------------------------------------
# §4.3 basic SIMD — dimension swapping, channels innermost
# ---------------------------------------------------------------------------


def conv2d_basic_simd(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                      use_pallas=False, oh_block=None):
    """NHWC: the channel axis is the fastest-varying dimension and the
    reduction is a vectorized dot over channels per kernel position.
    ``oh_block`` (Pallas path) tiles the output height into row bands so a
    grid cell stages only the band it needs; None = auto from VMEM."""
    if use_pallas:
        from repro.kernels.conv2d import ops as conv_ops

        return conv_ops.conv2d(x, w, b, stride, padding, relu,
                               method="basic_simd", oh_block=oh_block)
    xh = nchw_to_nhwc(x)  # dimension swapping (§4.3)
    wh = oihw_to_hwio(w)  # [kh, kw, c, oc]
    n, h, wd, c = xh.shape
    kh, kw, _, oc = wh.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(xh, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    out = _conv_positions_nhwc(xp, wh, oh, ow, sy, sx)
    out = out + b[None, None, None, :].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return nhwc_to_nchw(out.astype(x.dtype))


# ---------------------------------------------------------------------------
# §4.4 advanced SIMD — output-channel blocking + im2col patch reuse
# ---------------------------------------------------------------------------


def conv2d_advanced_simd(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                         block: int = 4, use_pallas=False, oh_block=None):
    """Each "thread" (here: matmul tile) produces `block` output channels
    from one loaded patch — the paper's 4/8-outputs-per-thread reuse taken
    to the MXU: im2col patches × kernel matrix, bias+ReLU fused in the
    epilogue.  `block` is kept as the paper's parameter; on TPU the Pallas
    kernel raises it to the 128-wide MXU tile.  ``oh_block`` (Pallas path)
    tiles the output height into row bands (None = auto from VMEM)."""
    if use_pallas:
        from repro.kernels.conv2d import ops as conv_ops

        return conv_ops.conv2d(x, w, b, stride, padding, relu,
                               method=f"advanced_simd_{block}",
                               oh_block=oh_block)
    xh = nchw_to_nhwc(x)
    wh = oihw_to_hwio(w)
    n, h, wd, c = xh.shape
    kh, kw, _, oc = wh.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(xh, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    patches = _im2col_nhwc(xp, kh, kw, oh, ow, sy, sx)  # [n, oh, ow, kh*kw*c]
    wmat = wh.reshape(kh * kw * c, oc)
    outs = []
    for o0 in range(0, oc, block):  # output-channel blocking (§4.4)
        blk = jnp.einsum(
            "nhwk,ko->nhwo", patches.astype(jnp.float32),
            wmat[:, o0 : o0 + block].astype(jnp.float32),
        )
        blk = blk + b[None, None, None, o0 : o0 + block].astype(jnp.float32)
        if relu:  # fused epilogue — no extra memory pass (§4.2/Fig. 5)
            blk = jnp.maximum(blk, 0.0)
        outs.append(blk)
    out = jnp.concatenate(outs, axis=-1)
    return nhwc_to_nchw(out.astype(x.dtype))


# ---------------------------------------------------------------------------
# fused conv→ReLU→pool super-layer (engine fusion planner target)
# ---------------------------------------------------------------------------


def conv2d_pool_fused(x, w, b, method: "Method", stride=(1, 1),
                      padding=(0, 0), relu=False, pool_kernel=(2, 2),
                      pool_stride=(2, 2), pool_kind: str = "max",
                      pool_relu: bool = False, use_pallas=False,
                      oh_block=None, lrn_n=None, lrn_alpha: float = 1e-4,
                      lrn_beta: float = 0.75, lrn_k: float = 1.0,
                      pool_carry: bool = None, lrn_oc_block: bool = None):
    """One-dispatch conv→[ReLU]→pool→[ReLU]→[LRN] (a ``FusedLayerSpec``).

    SIMD methods only — the planner falls back to the per-layer ladder for
    ``seq_ref``/``basic_parallel``.  On the Pallas path the conv kernel
    pools (and, with ``lrn_n``, channel-normalizes) its oh-band in VMEM
    and writes only the final activation; the XLA analogue runs the whole
    group in one NHWC pass (im2col matmul at full output-channel width +
    ``reduce_window`` pooling + channel-axis LRN on the NHWC minor axis)
    with a single layout round-trip instead of one per layer.  LRN
    matches ``engine._lrn`` exactly, including the asymmetric window
    padding for even ``lrn_n``.
    """
    pallas_method = pallas_method_name(method)
    if use_pallas:
        from repro.kernels.conv2d import ops as conv_ops

        return conv_ops.conv2d(x, w, b, stride, padding, relu,
                               method=pallas_method, oh_block=oh_block,
                               pool_kernel=pool_kernel,
                               pool_stride=pool_stride, pool_kind=pool_kind,
                               pool_relu=pool_relu, lrn_n=lrn_n,
                               lrn_alpha=lrn_alpha, lrn_beta=lrn_beta,
                               lrn_k=lrn_k, pool_carry=pool_carry,
                               lrn_oc_block=lrn_oc_block)
    xh = nchw_to_nhwc(x)  # one layout round-trip for the whole group
    wh = oihw_to_hwio(w)
    n, h, wd, c = xh.shape
    kh, kw, _, oc = wh.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(xh, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    if method == Method.BASIC_SIMD:
        out = _conv_positions_nhwc(xp, wh, oh, ow, sy, sx)
    else:
        # super-layer im2col: full-width matmul (the Pallas kernel's
        # 128-wide MXU tile, not the per-layer 4/8 sub-blocks)
        patches = _im2col_nhwc(xp, kh, kw, oh, ow, sy, sx)
        out = jnp.einsum("nhwk,ko->nhwo", patches.astype(jnp.float32),
                         wh.reshape(kh * kw * c, oc).astype(jnp.float32))
    out = out + b[None, None, None, :].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    pkh, pkw = pool_kernel
    psy, psx = pool_stride
    if pool_kind == "max":
        out = jax.lax.reduce_window(
            out, -jnp.inf, jax.lax.max, (1, pkh, pkw, 1), (1, psy, psx, 1),
            "VALID")
    elif pool_kind == "avg":
        out = jax.lax.reduce_window(
            out, 0.0, jax.lax.add, (1, pkh, pkw, 1), (1, psy, psx, 1),
            "VALID") / float(pkh * pkw)
    else:
        raise ValueError(pool_kind)
    if pool_relu:
        out = jnp.maximum(out, 0.0)
    if lrn_n is not None:
        # channel-axis LRN while channels are still the NHWC minor axis —
        # the SAME lrn_band the Pallas epilogue runs (engine._lrn
        # semantics: asymmetric padding keeps C channels for even n)
        from repro.kernels.conv2d.kernels import lrn_band

        out = lrn_band(out, lrn_n, lrn_alpha, lrn_beta, lrn_k)
    return nhwc_to_nchw(out.astype(x.dtype))


# ---------------------------------------------------------------------------
# fused conv→conv chain super-layer (VMEM-resident halo between stages)
# ---------------------------------------------------------------------------


def conv2d_chain_fused(x, ws, bs, method: "Method", strides, paddings,
                       relus, pool_kernel=None, pool_stride=None,
                       pool_kind: str = "max", pool_relu: bool = False,
                       use_pallas=False, oh_block=None, lrn_n=None,
                       lrn_alpha: float = 1e-4, lrn_beta: float = 0.75,
                       lrn_k: float = 1.0, oc_block_final: int = None):
    """One-dispatch conv→[ReLU]→conv→…→[pool]→[ReLU]→[LRN] (a chain
    ``FusedLayerSpec``).

    ``ws``/``bs``: per-stage OIHW weights and biases; ``strides``/
    ``paddings``/``relus``: parallel per-stage tuples.  SIMD methods only.
    On the Pallas path each grid cell computes an output-row band of the
    final stage with every intermediate activation (halo included)
    VMEM-resident — AlexNet's conv3→conv4→conv5(+pool5) is one dispatch
    writing only the pooled band.  The XLA analogue runs the whole chain
    in one NHWC pass (full-width matmuls, a single layout round-trip for
    the run instead of one per layer), with the same optional
    pool/``lrn_n`` tail as ``conv2d_pool_fused``.
    """
    pallas_method = pallas_method_name(method, what="fused conv chain")
    if lrn_n is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    if use_pallas:
        from repro.kernels.conv2d import ops as conv_ops

        return conv_ops.conv2d_chain(
            x, tuple(ws), tuple(bs), tuple(strides), tuple(paddings),
            tuple(relus), method=pallas_method, oh_block=oh_block,
            pool_kernel=pool_kernel, pool_stride=pool_stride,
            pool_kind=pool_kind, pool_relu=pool_relu, lrn_n=lrn_n,
            lrn_alpha=lrn_alpha, lrn_beta=lrn_beta, lrn_k=lrn_k,
            oc_block_final=oc_block_final)
    xh = nchw_to_nhwc(x).astype(jnp.float32)  # one swap for the whole chain
    for w, b, stride, padding, relu in zip(ws, bs, strides, paddings, relus):
        wh = oihw_to_hwio(w)
        kh, kw, ci, oc = wh.shape
        sy, sx = stride
        py, px = padding
        xp = jnp.pad(xh, ((0, 0), (py, py), (px, px), (0, 0)))
        oh = _out_size(xh.shape[1], kh, sy, py)
        ow = _out_size(xh.shape[2], kw, sx, px)
        if method == Method.BASIC_SIMD:
            out = _conv_positions_nhwc(xp, wh, oh, ow, sy, sx)
        else:
            # chain stages run at full output-channel width (stage N+1
            # consumes every channel of stage N), like the Pallas cell
            patches = _im2col_nhwc(xp, kh, kw, oh, ow, sy, sx)
            out = jnp.einsum("nhwk,ko->nhwo", patches.astype(jnp.float32),
                             wh.reshape(kh * kw * ci, oc)
                             .astype(jnp.float32))
        out = out + b[None, None, None, :].astype(jnp.float32)
        if relu:
            out = jnp.maximum(out, 0.0)
        xh = out
    if pool_kernel is not None:
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        if pool_kind == "max":
            xh = jax.lax.reduce_window(
                xh, -jnp.inf, jax.lax.max, (1, pkh, pkw, 1),
                (1, psy, psx, 1), "VALID")
        elif pool_kind == "avg":
            xh = jax.lax.reduce_window(
                xh, 0.0, jax.lax.add, (1, pkh, pkw, 1), (1, psy, psx, 1),
                "VALID") / float(pkh * pkw)
        else:
            raise ValueError(pool_kind)
        if pool_relu:
            xh = jnp.maximum(xh, 0.0)
        if lrn_n is not None:
            from repro.kernels.conv2d.kernels import lrn_band

            xh = lrn_band(xh, lrn_n, lrn_alpha, lrn_beta, lrn_k)
    return nhwc_to_nchw(xh.astype(x.dtype))


# ---------------------------------------------------------------------------
# FC ladder (§4 "fully connected layers are also accelerated")
# ---------------------------------------------------------------------------


def fc_seq_ref(x, w, b, relu=False):
    """x: [N, D]; w: [D, F].  Row-by-row dot products, fp32."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def fc_fused(x, w, b, relu=False, use_pallas=False):
    """Fused bias+activation matmul — the paper's FC acceleration; on TPU
    the ``matmul_fused`` Pallas kernel."""
    if use_pallas:
        from repro.kernels.matmul_fused import ops as mm_ops

        return mm_ops.matmul_fused(x, w, b, act="relu" if relu else "none")
    return fc_seq_ref(x, w, b, relu)


def conv2d(x, w, b, method: Method, stride=(1, 1), padding=(0, 0),
           relu=False, use_pallas=False, oh_block=None):
    if method == Method.SEQ_REF:
        return conv2d_seq_ref(x, w, b, stride, padding, relu)
    if method == Method.BASIC_PARALLEL:
        return conv2d_basic_parallel(x, w, b, stride, padding, relu, use_pallas)
    if method == Method.BASIC_SIMD:
        return conv2d_basic_simd(x, w, b, stride, padding, relu, use_pallas,
                                 oh_block)
    if method == Method.ADVANCED_SIMD_4:
        return conv2d_advanced_simd(x, w, b, stride, padding, relu, 4,
                                    use_pallas, oh_block)
    if method == Method.ADVANCED_SIMD_8:
        return conv2d_advanced_simd(x, w, b, stride, padding, relu, 8,
                                    use_pallas, oh_block)
    raise ValueError(method)
