"""Engine-level fusion planner: conv[+relu][+pool][+lrn] → super-layers.

CNNdroid's headline wins come from eliminating redundant memory passes
(fused bias/ReLU epilogues, the Fig. 5 overlap).  This module extends
that idea across layers: it scans a ``NetworkDef`` and greedily groups a
conv layer, an optional standalone ReLU, an immediately-following pool
layer, and an immediately-following LRN layer into one
``FusedLayerSpec``.  The engine executes a group as a single dispatch —
on the Pallas path the conv kernel pools (and channel-normalizes) its
band in VMEM and writes only the final activation (neither the conv nor
the pooled intermediate ever touches HBM); on the XLA path the whole
group runs in one NHWC pass with a single layout round-trip.

Correctness fallbacks — a group is NOT formed (the layers stay on the
per-layer ladder) when:

* the conv layer's execution method is not a SIMD method (``seq_ref`` and
  ``basic_parallel`` keep the paper's un-fused per-layer semantics),
* the pool kind is not max/avg,
* the pool window is larger than the conv output (shape-checked by
  propagating spatial dims through the net),
* the conv, pool, or lrn layer is named in ``no_fuse`` (per-layer
  opt-out, mirroring ``per_layer_methods``; an opted-out LRN only drops
  the LRN from the group — conv+pool still fuse),
* a standalone ReLU sits between conv and pool but ``fuse_relu`` is off
  (we will not reorder an activation we were told not to fold),
* the VMEM working-set check fails (Pallas path — the engine passes
  ``vmem_check=use_pallas``, since the one-pass XLA analogue has no VMEM
  ceiling): the fused kernel shrinks its pooled band (``oh_block``) to
  fit the soft budget, but its floor cell is one pool window of conv
  rows — when even THAT cell's modelled footprint (halo-widened input
  band + patch staging + weights + conv band + pooled band, via
  ``kernels.fused_cell_bytes``) exceeds the budget, the planner keeps
  the run un-fused instead of compiling a cell that cannot fit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Tuple, Union

from repro.core.methods import Method
from repro.core.netdefs import LayerSpec, NetworkDef

#: methods whose kernels support the fused pooling epilogue
FUSABLE_METHODS = frozenset({
    Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8,
})

#: methods whose fused kernel stages a full im2col patch matrix (the
#: advanced oc-blocked kernels; basic_simd holds one [rows, C] slice)
IM2COL_METHODS = frozenset({Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8})

SUPPORTED_POOL_KINDS = frozenset({"max", "avg"})

#: oc tile width each advanced method's fused kernel actually runs with
#: (``conv2d_pool_fused`` maps the method to ``advanced_simd_4``/``_8``
#: and ``conv2d.ops`` parses the block out of that name)
_ADVANCED_OC_BLOCK = {Method.ADVANCED_SIMD_4: 4, Method.ADVANCED_SIMD_8: 8}


@dataclass(frozen=True)
class FusedLayerSpec:
    """A conv→[ReLU]→pool→[ReLU]→[LRN] super-layer (one dispatch)."""
    conv: LayerSpec
    pool: LayerSpec
    relu: bool        # ReLU between conv and pool (conv's own or absorbed)
    pool_relu: bool   # ReLU after the pool (pool's own or absorbed)
    names: Tuple[str, ...]  # original layer names this group covers
    lrn: Optional[LayerSpec] = None  # trailing LRN absorbed into the cell

    kind = "fused"  # sentinel so plan items can be dispatched on .kind

    @property
    def name(self) -> str:
        return "+".join(self.names)


PlanItem = Union[LayerSpec, FusedLayerSpec]


def _conv_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h + 2 * spec.padding[0] - kh) // spec.stride[0] + 1,
            (w + 2 * spec.padding[1] - kw) // spec.stride[1] + 1)


def _pool_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h - kh) // spec.stride[0] + 1,
            (w - kw) // spec.stride[1] + 1)


def fused_working_set(conv: LayerSpec, pool: LayerSpec, method: Method,
                      cin: int, w_in: int, *,
                      lrn: bool = False) -> int:
    """Modelled VMEM bytes of the smallest possible fused grid cell (one
    pooled row — one pool window of conv rows) for this conv+pool pair.

    Mirrors what ``conv2d.ops`` + the kernels will actually stage: the
    input channel count is padded to the sublane multiple, the advanced
    methods charge a full im2col patch matrix and the 4/8-wide oc tile
    their fused kernel runs with — widened to the FULL output-channel
    width when ``lrn`` is set, because the LRN epilogue needs every
    channel of a pooled row in one cell (basic_simd is always full
    width).
    """
    from repro.kernels.conv2d import kernels as K  # deferred: keeps the
    from repro.kernels.conv2d.ops import SUBLANES  # planner importable
    # without pulling Pallas in at module-import time

    c = -(-cin // SUBLANES) * SUBLANES
    oc = conv.out_channels
    im2col = method in IM2COL_METHODS
    ocb = oc if (lrn or not im2col) else min(_ADVANCED_OC_BLOCK[method], oc)
    _, ow = _conv_out_hw(0, w_in, conv)  # h unused for the width
    wp = w_in + 2 * conv.padding[1]
    return K.fused_cell_bytes(
        1, ow, wp, c, conv.kernel[0], conv.kernel[1], conv.stride[0], ocb,
        (pool.kernel[0], pool.kernel[1], pool.stride[0], pool.stride[1]),
        im2col=im2col)


def plan_fusion(net: NetworkDef, *,
                method_for: Optional[Callable[[str], Method]] = None,
                no_fuse: Iterable[str] = (),
                fuse_relu: bool = True,
                vmem_budget: Optional[int] = None,
                vmem_check: bool = True) -> List[PlanItem]:
    """Greedy left-to-right grouping of conv[+relu][+pool][+lrn] runs.

    ``method_for`` maps a conv layer name to its execution ``Method`` (the
    engine passes its per-layer resolution; ``None`` assumes the widest
    fused working set, the advanced im2col kernels).  ``vmem_budget``
    overrides the soft VMEM budget the working-set check runs against
    (None = ``kernels.VMEM_BUDGET_BYTES``); ``vmem_check=False`` skips
    the check entirely — the engine passes its ``use_pallas`` here, since
    the one-NHWC-pass XLA analogue has no VMEM ceiling to respect.
    Returns the layer sequence with each fused run replaced by one
    ``FusedLayerSpec``; ungrouped layers pass through unchanged.
    """
    no_fuse = frozenset(no_fuse)
    layers = list(net.layers)
    plan: List[PlanItem] = []
    c, h, w = net.input_shape
    i = 0
    while i < len(layers):
        spec = layers[i]
        if spec.kind == "conv":
            oh, ow = _conv_out_hw(h, w, spec)
            group = _try_group(layers, i, oh, ow, method_for, no_fuse,
                               fuse_relu, c, w, vmem_budget, vmem_check)
            c = spec.out_channels
            if group is not None:
                plan.append(group)
                h, w = _pool_out_hw(oh, ow, group.pool)
                i += len(group.names)
                continue
            h, w = oh, ow
        elif spec.kind == "pool":
            h, w = _pool_out_hw(h, w, spec)
        plan.append(spec)
        i += 1
    return plan


def _try_group(layers, i, oh, ow, method_for, no_fuse, fuse_relu,
               cin, w_in, vmem_budget,
               vmem_check=True) -> Optional[FusedLayerSpec]:
    """A FusedLayerSpec for the run starting at conv ``layers[i]``, or
    None when any eligibility check fails (the per-layer fallback)."""
    conv = layers[i]
    if conv.name in no_fuse:
        return None
    method = method_for(conv.name) if method_for is not None else None
    if method is not None and method not in FUSABLE_METHODS:
        return None
    names = [conv.name]
    relu = conv.relu
    j = i + 1
    if j < len(layers) and layers[j].kind == "relu":
        if not fuse_relu:
            return None  # a standalone ReLU we may not fold blocks fusion
        relu = True
        names.append(layers[j].name)
        j += 1
    if j >= len(layers) or layers[j].kind != "pool":
        return None
    pool = layers[j]
    if pool.name in no_fuse:
        return None
    if pool.pool_kind not in SUPPORTED_POOL_KINDS:
        return None
    pkh, pkw = pool.kernel
    if pkh < 1 or pkw < 1 or pool.stride[0] < 1 or pool.stride[1] < 1:
        return None
    if pkh > oh or pkw > ow:
        return None  # pool window larger than the conv output
    names.append(pool.name)
    pool_relu = pool.relu
    k = j + 1
    if fuse_relu and k < len(layers) and layers[k].kind == "relu":
        pool_relu = True
        names.append(layers[k].name)
        k += 1
    lrn = None
    if (k < len(layers) and layers[k].kind == "lrn"
            and layers[k].name not in no_fuse):
        lrn = layers[k]
        names.append(lrn.name)
    # VMEM working-set check (Pallas path only): the fused kernel shrinks
    # its pooled band to fit, but never below one pool window of conv
    # rows — when even that floor cell busts the budget, decline (first
    # retrying without the LRN tail, whose full-width oc tile is the
    # widest working set)
    if vmem_check and not _fits_vmem(conv, pool, method, cin, w_in,
                                     lrn is not None, vmem_budget):
        if lrn is not None and _fits_vmem(conv, pool, method, cin, w_in,
                                          False, vmem_budget):
            names.pop()
            lrn = None
        else:
            return None
    return FusedLayerSpec(conv=conv, pool=pool, relu=relu,
                          pool_relu=pool_relu, names=tuple(names), lrn=lrn)


def _fits_vmem(conv, pool, method, cin, w_in, with_lrn, vmem_budget) -> bool:
    from repro.kernels.conv2d import kernels as K

    budget = K.VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    # unknown method (method_for=None): charge the widest cell any
    # fusable method would stage — basic_simd's full-width oc terms and
    # the advanced kernels' im2col staging dominate different regimes
    methods = ((method,) if method is not None
               else (Method.BASIC_SIMD, Method.ADVANCED_SIMD_8))
    return max(fused_working_set(conv, pool, m, cin, w_in, lrn=with_lrn)
               for m in methods) <= budget


def fusion_summary(plan: Iterable[PlanItem]) -> List[Tuple[str, ...]]:
    """The fused groups in a plan, as tuples of original layer names."""
    return [it.names for it in plan if isinstance(it, FusedLayerSpec)]
