"""Engine-level fusion planner: conv[+relu][+pool] → super-layers.

CNNdroid's headline wins come from eliminating redundant memory passes
(fused bias/ReLU epilogues, the Fig. 5 overlap).  This module extends
that idea across layers: it scans a ``NetworkDef`` and greedily groups a
conv layer, an optional standalone ReLU, and an immediately-following
pool layer into one ``FusedLayerSpec``.  The engine executes a group as a
single dispatch — on the Pallas path the conv kernel pools its band in
VMEM and writes only the pooled activation (the intermediate conv output
never touches HBM); on the XLA path the whole group runs in one NHWC pass
with a single layout round-trip.

Correctness fallbacks — a group is NOT formed (the layers stay on the
per-layer ladder) when:

* the conv layer's execution method is not a SIMD method (``seq_ref`` and
  ``basic_parallel`` keep the paper's un-fused per-layer semantics),
* the pool kind is not max/avg,
* the pool window is larger than the conv output (shape-checked by
  propagating spatial dims through the net),
* the conv or pool layer is named in ``no_fuse`` (per-layer opt-out,
  mirroring ``per_layer_methods``),
* a standalone ReLU sits between conv and pool but ``fuse_relu`` is off
  (we will not reorder an activation we were told not to fold).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Tuple, Union

from repro.core.methods import Method
from repro.core.netdefs import LayerSpec, NetworkDef

#: methods whose kernels support the fused pooling epilogue
FUSABLE_METHODS = frozenset({
    Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8,
})

SUPPORTED_POOL_KINDS = frozenset({"max", "avg"})


@dataclass(frozen=True)
class FusedLayerSpec:
    """A conv→[ReLU]→pool→[ReLU] super-layer (one dispatch)."""
    conv: LayerSpec
    pool: LayerSpec
    relu: bool        # ReLU between conv and pool (conv's own or absorbed)
    pool_relu: bool   # ReLU after the pool (pool's own or absorbed)
    names: Tuple[str, ...]  # original layer names this group covers

    kind = "fused"  # sentinel so plan items can be dispatched on .kind

    @property
    def name(self) -> str:
        return "+".join(self.names)


PlanItem = Union[LayerSpec, FusedLayerSpec]


def _conv_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h + 2 * spec.padding[0] - kh) // spec.stride[0] + 1,
            (w + 2 * spec.padding[1] - kw) // spec.stride[1] + 1)


def _pool_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h - kh) // spec.stride[0] + 1,
            (w - kw) // spec.stride[1] + 1)


def plan_fusion(net: NetworkDef, *,
                method_for: Optional[Callable[[str], Method]] = None,
                no_fuse: Iterable[str] = (),
                fuse_relu: bool = True) -> List[PlanItem]:
    """Greedy left-to-right grouping of conv[+relu][+pool] runs.

    ``method_for`` maps a conv layer name to its execution ``Method`` (the
    engine passes its per-layer resolution; ``None`` assumes fusable).
    Returns the layer sequence with each fused run replaced by one
    ``FusedLayerSpec``; ungrouped layers pass through unchanged.
    """
    no_fuse = frozenset(no_fuse)
    layers = list(net.layers)
    plan: List[PlanItem] = []
    h, w = net.input_shape[1], net.input_shape[2]
    i = 0
    while i < len(layers):
        spec = layers[i]
        if spec.kind == "conv":
            oh, ow = _conv_out_hw(h, w, spec)
            group = _try_group(layers, i, oh, ow, method_for, no_fuse,
                               fuse_relu)
            if group is not None:
                plan.append(group)
                h, w = _pool_out_hw(oh, ow, group.pool)
                i += len(group.names)
                continue
            h, w = oh, ow
        elif spec.kind == "pool":
            h, w = _pool_out_hw(h, w, spec)
        plan.append(spec)
        i += 1
    return plan


def _try_group(layers, i, oh, ow, method_for, no_fuse,
               fuse_relu) -> Optional[FusedLayerSpec]:
    """A FusedLayerSpec for the run starting at conv ``layers[i]``, or
    None when any eligibility check fails (the per-layer fallback)."""
    conv = layers[i]
    if conv.name in no_fuse:
        return None
    if method_for is not None and method_for(conv.name) not in FUSABLE_METHODS:
        return None
    names = [conv.name]
    relu = conv.relu
    j = i + 1
    if j < len(layers) and layers[j].kind == "relu":
        if not fuse_relu:
            return None  # a standalone ReLU we may not fold blocks fusion
        relu = True
        names.append(layers[j].name)
        j += 1
    if j >= len(layers) or layers[j].kind != "pool":
        return None
    pool = layers[j]
    if pool.name in no_fuse:
        return None
    if pool.pool_kind not in SUPPORTED_POOL_KINDS:
        return None
    pkh, pkw = pool.kernel
    if pkh < 1 or pkw < 1 or pool.stride[0] < 1 or pool.stride[1] < 1:
        return None
    if pkh > oh or pkw > ow:
        return None  # pool window larger than the conv output
    names.append(pool.name)
    pool_relu = pool.relu
    k = j + 1
    if fuse_relu and k < len(layers) and layers[k].kind == "relu":
        pool_relu = True
        names.append(layers[k].name)
    return FusedLayerSpec(conv=conv, pool=pool, relu=relu,
                          pool_relu=pool_relu, names=tuple(names))


def fusion_summary(plan: Iterable[PlanItem]) -> List[Tuple[str, ...]]:
    """The fused groups in a plan, as tuples of original layer names."""
    return [it.names for it in plan if isinstance(it, FusedLayerSpec)]
