"""Engine-level fusion planner: conv-chain[+pool][+lrn] → super-layers.

CNNdroid's headline wins come from eliminating redundant memory passes
(fused bias/ReLU epilogues, the Fig. 5 overlap).  This module extends
that idea across layers: it scans a ``NetworkDef`` and greedily groups a
run of CONSECUTIVE conv layers (interleaved standalone ReLUs absorbed),
an optional immediately-following pool layer, and an optional trailing
LRN layer into one ``FusedLayerSpec``.  The engine executes a group as a
single dispatch — on the Pallas path the chain cell keeps every
intermediate conv band (halo included) in VMEM and writes only the final
activation (no intermediate of the run ever touches HBM); on the XLA
path the whole group runs in one NHWC pass with a single layout
round-trip.  AlexNet's conv3→conv4→conv5+pool5 — the MAC-heaviest
stretch of the paper's Table 2 networks — becomes one dispatch writing
only the pooled band.

A group needs at least two layers: a lone conv (no following conv or
pool) stays on the per-layer ladder; a conv chain of length ≥ 2 fuses
with or without a pool tail.

Correctness fallbacks — layers stay on the per-layer ladder when:

* a conv's execution method is not a SIMD method (``seq_ref`` and
  ``basic_parallel`` keep the paper's un-fused per-layer semantics), or
  two consecutive convs resolve to *different* methods (a chain cell
  runs one method; the chain breaks between them),
* the pool kind is not max/avg,
* the pool window is larger than the conv output (shape-checked by
  propagating spatial dims through the net),
* a conv, pool, or lrn layer is named in ``no_fuse`` (per-layer opt-out,
  mirroring ``per_layer_methods``; an opted-out LRN only drops the LRN
  from the group, an opted-out conv breaks the chain at that conv),
* a standalone ReLU follows a conv but ``fuse_relu`` is off (we will not
  reorder an activation we were told not to fold: the chain ends before
  it and no pool is absorbed across it),
* the VMEM working-set check fails (Pallas path — the engine passes
  ``vmem_check=use_pallas``, since the one-pass XLA analogue has no VMEM
  ceiling): the fused kernel shrinks its final-row band to fit the
  budget, but its floor cell is one final row — when even THAT cell's
  modelled footprint (``kernels.fused_cell_bytes`` for single-conv
  groups, ``kernels.chain_cell_bytes`` — every stage's full-width
  weights resident plus the peak per-stage band/patch live set — for
  chains) exceeds the budget, the planner first drops the LRN tail,
  then falls back to successively SHORTER chains (the detached tail
  layers re-enter the scan and may group among themselves) before
  declining fusion outright.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Tuple, Union

from repro.core.methods import Method
from repro.core.netdefs import LayerSpec, NetworkDef

#: methods whose kernels support the fused pooling epilogue
FUSABLE_METHODS = frozenset({
    Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8,
})

#: methods whose fused kernel stages a full im2col patch matrix (the
#: advanced oc-blocked kernels; basic_simd holds one [rows, C] slice)
IM2COL_METHODS = frozenset({Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8})

SUPPORTED_POOL_KINDS = frozenset({"max", "avg"})

#: oc tile width each advanced method's fused kernel actually runs with
#: (``conv2d_pool_fused`` maps the method to ``advanced_simd_4``/``_8``
#: and ``conv2d.ops`` parses the block out of that name)
_ADVANCED_OC_BLOCK = {Method.ADVANCED_SIMD_4: 4, Method.ADVANCED_SIMD_8: 8}


@dataclass(frozen=True)
class FusedLayerSpec:
    """A conv→[ReLU]→…→conv→[ReLU]→[pool]→[ReLU]→[LRN] super-layer
    (one dispatch).  ``convs`` is the chain of consecutive conv stages;
    ``relus[i]`` is the ReLU after stage i (the conv's own or an absorbed
    standalone one).  ``pool`` is None for a chain fused without a pool
    tail."""
    convs: Tuple[LayerSpec, ...]
    relus: Tuple[bool, ...]
    pool: Optional[LayerSpec]
    pool_relu: bool   # ReLU after the pool (pool's own or absorbed)
    names: Tuple[str, ...]  # original layer names this group covers
    lrn: Optional[LayerSpec] = None  # trailing LRN absorbed into the cell
    #: chain-only: oc-grid block the final stage runs with (None = full
    #: width).  Set by the planner's admission ladder when a chain's
    #: full-width resident weights bust the budget; incompatible with a
    #: fused LRN tail (the kernel raises).
    oc_block_final: Optional[int] = None

    kind = "fused"  # sentinel so plan items can be dispatched on .kind

    @property
    def conv(self) -> LayerSpec:
        """The first conv of the chain (single-conv groups: THE conv)."""
        return self.convs[0]

    @property
    def relu(self) -> bool:
        """ReLU between the last conv stage and the pool."""
        return self.relus[-1]

    @property
    def name(self) -> str:
        return "+".join(self.names)


PlanItem = Union[LayerSpec, FusedLayerSpec]


def _conv_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h + 2 * spec.padding[0] - kh) // spec.stride[0] + 1,
            (w + 2 * spec.padding[1] - kw) // spec.stride[1] + 1)


def _pool_out_hw(h: int, w: int, spec: LayerSpec) -> Tuple[int, int]:
    kh, kw = spec.kernel
    return ((h - kh) // spec.stride[0] + 1,
            (w - kw) // spec.stride[1] + 1)


def fused_working_set(conv: LayerSpec, pool: LayerSpec, method: Method,
                      cin: int, w_in: int, *,
                      lrn: bool = False,
                      lrn_n: Optional[int] = None) -> int:
    """Modelled VMEM bytes of the smallest possible fused grid cell (one
    pooled row — one pool window of conv rows) for this conv+pool pair.

    Mirrors what ``conv2d.ops`` + the kernels will actually stage: the
    input channel count is padded to the sublane multiple, the advanced
    methods charge a full im2col patch matrix and the 4/8-wide oc tile
    their fused kernel runs with.  With ``lrn`` set the oc width follows
    ``kernels.resolve_lrn_ocb``: the historical full-width tile when the
    full-width floor cell fits the budget, else the two-pass
    channel-halo cell's ``ocb + lrn_n - 1`` widened tile (``lrn_n`` is
    the LRN window; ``None`` keeps the conservative full-width charge —
    basic_simd is always full width).
    """
    from repro.kernels.conv2d import kernels as K  # deferred: keeps the
    from repro.kernels.conv2d.ops import SUBLANES  # planner importable
    # without pulling Pallas in at module-import time

    c = -(-cin // SUBLANES) * SUBLANES
    oc = conv.out_channels
    im2col = method in IM2COL_METHODS
    _, ow = _conv_out_hw(0, w_in, conv)  # h unused for the width
    wp = w_in + 2 * conv.padding[1]
    pool_t = (pool.kernel[0], pool.kernel[1], pool.stride[0],
              pool.stride[1])
    kh, kw = conv.kernel
    sy = conv.stride[0]
    oc_halo = 0
    if lrn and im2col and lrn_n is not None:
        ocb, oc_halo = K.resolve_lrn_ocb(
            oc, _ADVANCED_OC_BLOCK[method], (lrn_n, 1e-4, 0.75, 1.0),
            None, ow, wp, c, kh, kw, sy, pool_t, im2col=im2col)
    elif lrn or not im2col:
        ocb = oc
    else:
        ocb = min(_ADVANCED_OC_BLOCK[method], oc)
    return K.fused_cell_bytes(1, ow, wp, c, kh, kw, sy, ocb, pool_t,
                              im2col=im2col, oc_halo=oc_halo)


def layers_as_chain(convs) -> Tuple[Tuple, Tuple]:
    """``LayerSpec`` convs → the kernels' chain description: per-stage
    ``(kh, kw, sy, sx, py, px)`` tuples plus the SUBLANES-padded
    per-stage output-channel counts (what ``conv2d.ops`` will actually
    stage — inter-stage channel padding composes through the chain)."""
    from repro.kernels.conv2d.ops import SUBLANES

    chain = tuple((cv.kernel[0], cv.kernel[1], cv.stride[0], cv.stride[1],
                   cv.padding[0], cv.padding[1]) for cv in convs)
    ocs = tuple(-(-cv.out_channels // SUBLANES) * SUBLANES for cv in convs)
    return chain, ocs


def chain_working_set(convs, pool, method: Optional[Method],
                      cin: int, h_in: int, w_in: int,
                      oc_block_final: Optional[int] = None) -> int:
    """Modelled VMEM bytes of the smallest possible chain grid cell (one
    final row — one pool window of final-conv rows when ``pool`` is set)
    for this run of consecutive convs.  Chains run every *intermediate*
    stage at full output-channel width (the next stage consumes every
    channel), so the dominant term is the resident weights of all stages
    (``kernels.chain_cell_bytes``); ``oc_block_final`` restores oc-grid
    blocking on the final stage, shrinking its resident-weights and
    output-band terms."""
    from repro.kernels.conv2d import kernels as K
    from repro.kernels.conv2d.ops import SUBLANES

    c = -(-cin // SUBLANES) * SUBLANES
    chain, ocs = layers_as_chain(convs)
    pool_t = (None if pool is None else
              (pool.kernel[0], pool.kernel[1], pool.stride[0],
               pool.stride[1]))
    im2col = method is None or method in IM2COL_METHODS
    return K.chain_cell_bytes(1, h_in, w_in, c, chain, ocs, pool_t,
                              im2col=im2col,
                              oc_block_final=oc_block_final)


#: a fusion cost gate: ``gate(candidate_group, method, in_shape) -> bool``
#: — True admits the group, False sends the planner down the same
#: shorter-chain fallback ladder the VMEM check uses.  Built by
#: ``repro.core.cost.fusion_cost_gate``.
CostGate = Callable[["FusedLayerSpec", Optional[Method],
                     Tuple[int, int, int]], bool]


def plan_fusion(net: NetworkDef, *,
                method_for: Optional[Callable[[str], Method]] = None,
                no_fuse: Iterable[str] = (),
                fuse_relu: bool = True,
                vmem_budget: Optional[int] = None,
                vmem_check: bool = True,
                cost_gate: Optional[CostGate] = None) -> List[PlanItem]:
    """Greedy left-to-right grouping of conv-chain[+relu][+pool][+lrn]
    runs.

    ``method_for`` maps a conv layer name to its execution ``Method`` (the
    engine passes its per-layer resolution; ``None`` assumes the widest
    fused working set, the advanced im2col kernels).  ``vmem_budget``
    overrides the VMEM budget the working-set check runs against (None =
    ``kernels.VMEM_BUDGET_BYTES`` for single-conv groups and
    ``kernels.CHAIN_VMEM_BUDGET_BYTES`` for chains, whose grid-invariant
    resident weights are not double-buffered); ``vmem_check=False`` skips
    the check entirely — the engine passes its ``use_pallas`` here, since
    the one-NHWC-pass XLA analogue has no VMEM ceiling to respect.

    ``cost_gate`` (the cost-model flag) REPLACES the raw budget check:
    each candidate group is admitted by the gate instead of by
    ``_fits_vmem``, so a group can be declined for being modelled SLOWER
    than its per-layer ladder even though it fits VMEM (and the gate is
    consulted on the XLA path too, where there is no VMEM ceiling).  A
    declined candidate walks the same fallback ladder: drop the LRN
    tail, then trailing convs, then decline outright.
    Returns the layer sequence with each fused run replaced by one
    ``FusedLayerSpec``; ungrouped layers pass through unchanged.
    """
    no_fuse = frozenset(no_fuse)
    layers = list(net.layers)
    plan: List[PlanItem] = []
    c, h, w = net.input_shape
    i = 0
    while i < len(layers):
        spec = layers[i]
        if spec.kind == "conv":
            group = _try_group(layers, i, method_for, no_fuse, fuse_relu,
                               c, h, w, vmem_budget, vmem_check, cost_gate)
            if group is not None:
                plan.append(group)
                for cv in group.convs:
                    h, w = _conv_out_hw(h, w, cv)
                c = group.convs[-1].out_channels
                if group.pool is not None:
                    h, w = _pool_out_hw(h, w, group.pool)
                i += len(group.names)
                continue
            h, w = _conv_out_hw(h, w, spec)
            c = spec.out_channels
        elif spec.kind == "pool":
            h, w = _pool_out_hw(h, w, spec)
        plan.append(spec)
        i += 1
    return plan


def _try_group(layers, i, method_for, no_fuse, fuse_relu, cin, h_in, w_in,
               vmem_budget, vmem_check=True,
               cost_gate: Optional[CostGate] = None,
               ) -> Optional[FusedLayerSpec]:
    """A FusedLayerSpec for the run starting at conv ``layers[i]``, or
    None when any eligibility check fails (the per-layer fallback)."""
    first = layers[i]
    if first.name in no_fuse:
        return None
    method = method_for(first.name) if method_for is not None else None
    if method is not None and method not in FUSABLE_METHODS:
        return None
    # -- collect the maximal conv chain (absorbing standalone ReLUs) -------
    convs = [first]
    relus = [first.relu]
    conv_names = [[first.name]]  # per-stage names incl. absorbed ReLUs
    h, w = _conv_out_hw(h_in, w_in, first)
    j = i + 1
    blocked_by_relu = False  # an un-foldable standalone ReLU ends the run
    while True:
        if j < len(layers) and layers[j].kind == "relu":
            if not fuse_relu:
                blocked_by_relu = True
                break
            relus[-1] = True
            conv_names[-1].append(layers[j].name)
            j += 1
        nxt = layers[j] if j < len(layers) else None
        if (nxt is None or nxt.kind != "conv" or nxt.name in no_fuse
                or (method_for is not None
                    and method_for(nxt.name) != method)):
            break
        oh2, ow2 = _conv_out_hw(h, w, nxt)
        if oh2 < 1 or ow2 < 1:
            break
        convs.append(nxt)
        relus.append(nxt.relu)
        conv_names.append([nxt.name])
        h, w = oh2, ow2
        j += 1
    # -- optional pool (+ReLU) and LRN tail on the last conv ---------------
    pool = None
    pool_relu = False
    pool_names: List[str] = []
    lrn = None
    if not blocked_by_relu and j < len(layers) and layers[j].kind == "pool":
        p = layers[j]
        pkh, pkw = p.kernel
        if (p.name not in no_fuse and p.pool_kind in SUPPORTED_POOL_KINDS
                and pkh >= 1 and pkw >= 1
                and p.stride[0] >= 1 and p.stride[1] >= 1
                and pkh <= h and pkw <= w):
            pool = p
            pool_relu = p.relu
            pool_names = [p.name]
            k = j + 1
            if fuse_relu and k < len(layers) and layers[k].kind == "relu":
                pool_relu = True
                pool_names.append(layers[k].name)
                k += 1
            if (k < len(layers) and layers[k].kind == "lrn"
                    and layers[k].name not in no_fuse):
                lrn = layers[k]
    # -- admission check with shorter-chain fallback -----------------------
    # Raw VMEM working-set check (Pallas path only): the fused kernel
    # shrinks its final-row band to fit, but never below one final row —
    # when even that floor cell busts the budget, first drop the LRN
    # tail, then trailing convs (the detached pool/convs re-enter the
    # greedy scan), and only decline outright at a single conv+pool that
    # still cannot fit.  A ``cost_gate`` REPLACES the raw check (and
    # binds on the XLA path too): the same fallback ladder, but a group
    # is declined when the cost model scores it slower than its
    # per-layer ladder, not only when it busts VMEM.
    oc_block_final = None
    if vmem_check or cost_gate is not None:
        while True:
            if len(convs) == 1 and pool is None:
                return None
            if cost_gate is not None:
                cand = FusedLayerSpec(
                    convs=tuple(convs), relus=tuple(relus), pool=pool,
                    pool_relu=pool_relu,
                    names=(tuple(n for stage in conv_names for n in stage)
                           + tuple(pool_names)
                           + ((lrn.name,) if lrn is not None else ())),
                    lrn=lrn, oc_block_final=oc_block_final)
                admitted = cost_gate(cand, method, (cin, h_in, w_in))
            else:
                admitted = _fits_vmem(convs, pool, method, cin, h_in, w_in,
                                      lrn, vmem_budget, oc_block_final)
            if admitted:
                break
            if lrn is not None:
                lrn = None
                continue
            if len(convs) > 1 and oc_block_final is None:
                # chain rung: block the final stage's oc grid (its
                # channels feed no further stage) before shortening the
                # chain — incompatible with LRN, which is gone by here
                oc_block_final = _ADVANCED_OC_BLOCK.get(method, 8)
                continue
            if len(convs) == 1:
                return None  # single conv+pool whose floor cell busts
            convs.pop()
            relus.pop()
            conv_names.pop()
            pool, pool_relu, pool_names = None, False, []
            oc_block_final = None
    if len(convs) == 1 and pool is None:
        return None  # a lone conv is not a super-layer
    names = (tuple(n for stage in conv_names for n in stage)
             + tuple(pool_names) + ((lrn.name,) if lrn is not None else ()))
    return FusedLayerSpec(convs=tuple(convs), relus=tuple(relus), pool=pool,
                          pool_relu=pool_relu, names=names, lrn=lrn,
                          oc_block_final=oc_block_final)


def _fits_vmem(convs, pool, method, cin, h_in, w_in, lrn,
               vmem_budget, oc_block_final=None) -> bool:
    from repro.kernels.conv2d import kernels as K

    if len(convs) > 1:
        # chain cells: full width at every intermediate stage, resident
        # weights — checked against the near-full-VMEM chain budget
        # (method=None charges im2col staging, the widest any fusable
        # method stages); ``oc_block_final`` shrinks the final stage
        budget = (K.CHAIN_VMEM_BUDGET_BYTES if vmem_budget is None
                  else vmem_budget)
        return chain_working_set(convs, pool, method, cin, h_in, w_in,
                                 oc_block_final=oc_block_final) <= budget
    budget = K.VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    # unknown method (method_for=None): charge the widest cell any
    # fusable method would stage — basic_simd's full-width oc terms and
    # the advanced kernels' im2col staging dominate different regimes
    methods = ((method,) if method is not None
               else (Method.BASIC_SIMD, Method.ADVANCED_SIMD_8))
    lrn_n = None if lrn is None else lrn.lrn_n
    return max(fused_working_set(convs[0], pool, m, cin, w_in,
                                 lrn=lrn is not None, lrn_n=lrn_n)
               for m in methods) <= budget


def group_fits_vmem(group: FusedLayerSpec, method: Optional[Method],
                    in_shape: Tuple[int, int, int],
                    vmem_budget: Optional[int] = None) -> bool:
    """The planner's working-set admission check, for an already-formed
    group: True when the group's one-final-row floor cell fits the
    (chain or fused) VMEM budget.  This is the budget leg a cost-model
    gate (``repro.core.cost.fusion_cost_gate``) runs before comparing
    modelled latencies — same accounting, public entry point."""
    c, h, w = in_shape
    return _fits_vmem(list(group.convs), group.pool, method, c, h, w,
                      group.lrn, vmem_budget, group.oc_block_final)


def fusion_summary(plan: Iterable[PlanItem]) -> List[Tuple[str, ...]]:
    """The fused groups in a plan, as tuples of original layer names."""
    return [it.names for it in plan if isinstance(it, FusedLayerSpec)]


def group_band_params(group: FusedLayerSpec, method: Method,
                      in_shape: Tuple[int, int, int],
                      oh_block: Optional[int], *,
                      pool_carry: Optional[bool] = None,
                      lrn_oc_block: Optional[bool] = None) -> dict:
    """The FULL resolved band geometry + VMEM accounting of one fused
    group's Pallas cell, re-derived from the same kernel resolvers the
    dispatch path runs (``resolve_ph_block`` / ``resolve_chain_block`` /
    ``chain_band_geometry``) — the single source the engine's geometry
    report AND the static plan verifier read.

    Keys:

    * ``kind``: ``"fused"`` (single conv + pool) or ``"chain"``,
    * ``blk`` / ``n_tiles`` / ``total``: final rows per grid cell, bands
      per frame, and the valid final-row count they must partition,
    * ``band`` / ``row_step`` / ``in_base``: input rows one cell stages,
      the per-band input-row advance, and the stage-0 padded-coordinate
      offset of band 0 (≤ 0: the kernel pre-pads ``-in_base`` extra top
      zero rows),
    * ``carry`` / ``steps``: input rows re-used from VMEM scratch each
      band step (``K*sy`` for the sliding-window carry cell, 0
      otherwise) and the physical grid steps along the band axis
      (``n_tiles + 1`` for the carry cell's sacrificial seed step,
      ``n_tiles`` otherwise),
    * ``stride_eff`` / ``window_eff``: the group collapsed to ONE
      effective conv — input rows advanced per final row, and input rows
      one final row reads (``band == (blk-1)*stride_eff + window_eff``),
    * ``padded_h``: the genuine zero-padded input frame height (rows at
      or past it that a band touches are bottom overshoot — pad fetched
      and sliced off),
    * ``cell_bytes`` / ``floor_bytes`` / ``budget``: the modelled VMEM
      working set of the resolved cell, of the one-final-row floor cell,
      and the budget both are admitted against,
    * ``out_hw``: the group's output spatial size.
    """
    from repro.kernels.conv2d import kernels as K
    from repro.kernels.conv2d.ops import SUBLANES

    c, h, w = in_shape
    im2col = method in IM2COL_METHODS
    cp = -(-c // SUBLANES) * SUBLANES
    pool_t = (None if group.pool is None else
              (group.pool.kernel[0], group.pool.kernel[1],
               group.pool.stride[0], group.pool.stride[1]))
    if len(group.convs) == 1:
        # single conv + pool: the oc-blocked epilogue kernel
        cv = group.convs[0]
        oh, ow = _conv_out_hw(h, w, cv)
        wp = w + 2 * cv.padding[1]
        oc = cv.out_channels
        kh, kw = cv.kernel
        sy = cv.stride[0]
        lrn_t = None
        if group.lrn is not None:
            lg = group.lrn
            lrn_t = (lg.lrn_n, lg.lrn_alpha, lg.lrn_beta, lg.lrn_k)
        if not im2col:
            ocb, oc_halo = oc, 0  # basic_simd: always full oc width
        else:
            ocb, oc_halo = K.resolve_lrn_ocb(
                oc, _ADVANCED_OC_BLOCK[method], lrn_t, lrn_oc_block, ow,
                wp, cp, kh, kw, sy, pool_t)
        pkh, _, psy, _ = pool_t
        ph = (oh - pkh) // psy + 1
        blk, n_tiles = K.resolve_ph_block(
            ph, oh, ow, wp, cp, kh, kw, sy, ocb, pool_t, oh_block,
            im2col=im2col, oc_halo=oc_halo)
        carry_on = K.resolve_pool_carry(pool_carry, im2col, lrn_t, pool_t,
                                        blk, n_tiles)
        stride_eff = psy * sy          # input rows per pooled row
        window_eff = (pkh - 1) * sy + kh
        carry = (pkh - psy) * sy if carry_on else 0
        geo = {
            "kind": "fused", "blk": blk, "n_tiles": n_tiles, "total": ph,
            "band": (blk - 1) * stride_eff + window_eff - carry,
            "row_step": blk * stride_eff, "in_base": 0,
            "carry": carry, "steps": n_tiles + (1 if carry_on else 0),
            "stride_eff": stride_eff, "window_eff": window_eff,
            "padded_h": h + 2 * cv.padding[0],
            "cell_bytes": K.fused_cell_bytes(blk, ow, wp, cp, kh, kw, sy,
                                             ocb, pool_t, im2col=im2col,
                                             oc_halo=oc_halo),
            "floor_bytes": K.fused_cell_bytes(1, ow, wp, cp, kh, kw, sy,
                                              ocb, pool_t, im2col=im2col,
                                              oc_halo=oc_halo),
            "budget": K.VMEM_BUDGET_BYTES,
        }
    else:
        chain, ocs = layers_as_chain(group.convs)
        obf = group.oc_block_final
        blk, n_tiles = K.resolve_chain_block(h, w, cp, chain, ocs, pool_t,
                                             oh_block, im2col=im2col,
                                             oc_block_final=obf)
        _, _, band, in_step, in_base = K.chain_band_geometry(blk, chain,
                                                             pool_t)
        hh, ww = h, w
        for cv in group.convs:
            hh, ww = _conv_out_hw(hh, ww, cv)
        if pool_t is not None:
            total = (hh - pool_t[0]) // pool_t[2] + 1
        else:
            total = hh
        stride_eff = in_step // blk    # in_step is blk whole strides
        geo = {
            "kind": "chain", "blk": blk, "n_tiles": n_tiles, "total": total,
            "band": band, "row_step": in_step, "in_base": in_base,
            "carry": 0, "steps": n_tiles,
            "stride_eff": stride_eff,
            "window_eff": band - (blk - 1) * stride_eff,
            "padded_h": h + 2 * chain[0][4],
            "cell_bytes": K.chain_cell_bytes(blk, h, w, cp, chain, ocs,
                                             pool_t, im2col=im2col,
                                             oc_block_final=obf),
            "floor_bytes": K.chain_cell_bytes(1, h, w, cp, chain, ocs,
                                              pool_t, im2col=im2col,
                                              oc_block_final=obf),
            "budget": K.CHAIN_VMEM_BUDGET_BYTES,
        }
    for cv in group.convs:
        h, w = _conv_out_hw(h, w, cv)
    if group.pool is not None:
        h, w = _pool_out_hw(h, w, group.pool)
    geo["out_hw"] = [h, w]
    return geo


def group_geometry(group: FusedLayerSpec, method: Method,
                   in_shape: Tuple[int, int, int],
                   oh_block: Optional[int], *,
                   pool_carry: Optional[bool] = None,
                   lrn_oc_block: Optional[bool] = None) -> dict:
    """The executed geometry of one fused group: the final-row band the
    Pallas cell resolves (``rows_per_cell`` pooled/final rows per grid
    cell × ``n_tiles`` bands per frame) plus the group's output spatial
    size.  A compact view over ``group_band_params`` — the report IS
    what a Pallas run would execute (the XLA analogue runs each group as
    one un-banded pass).  ``in_shape`` is the ``(C, H, W)`` activation
    entering the group — the plan IR carries it pre-resolved on each
    fused step."""
    geo = group_band_params(group, method, in_shape, oh_block,
                            pool_carry=pool_carry,
                            lrn_oc_block=lrn_oc_block)
    return {"group": group.name, "convs": len(group.convs),
            "rows_per_cell": geo["blk"], "n_tiles": geo["n_tiles"],
            "out_hw": geo["out_hw"]}
