"""Per-``PlanStep`` analytic cost model for compiled ExecutionPlans.

CNNdroid's whole thesis is that the right per-layer execution choice
(method, tiling, fusion) separates real-time from prohibitive; this
module replaces the planner's point heuristics with the per-layer
latency model of "Modeling the Resource Requirements of CNNs on Mobile
Devices" (arxiv 1709.09503), adapted to the plan IR.  Every step is
reduced to three measurable resources:

* **FLOPs** — the arithmetic the step must do (2 × MACs for conv/fc via
  ``kernels.conv_macs``; window/pointwise op counts for the tail kinds),
  attributed to a coefficient bucket: one per conv ladder method (the
  restagings differ in achieved throughput far more than in streamed
  bytes), one shared ``fc`` bucket (the fc path is method-invariant),
  and ``other`` for the cheap pool/lrn/softmax tail,
* **HBM bytes streamed** — input activation + weights + output, charged
  physically: a fused/chain step streams NO intermediate activations
  (the fusion win, visible to the model), and on the Pallas path the
  input charge is multiplied by ``kernels.band_overfetch_factor`` (the
  halo re-fetch cost of the resolved band geometry, so ``oh_block``
  choices move the prediction),
* **VMEM working set** — the resolved grid cell's modelled bytes via
  the existing ``conv_cell_bytes`` / ``fused_cell_bytes`` /
  ``chain_cell_bytes`` accounting (read off the same resolver-derived
  geometry the static verifier audits).  Not a latency term — it is the
  feasibility resource the autotuner trades against the overfetch
  factor.

Predicted microseconds come from fitted per-backend coefficients
(``us_per_gflop[bucket]``, ``us_per_gb``, ``dispatch_us``) loaded from a
committed ``COST_MODEL.json``, calibrated against ``BENCH_network.json``
history by ``benchmarks/cost_fit.py`` (non-negative least squares with a
deterministic fit/holdout split) and regression-gated in CI by
``tools/cost_validate.py`` (Spearman rank correlation between predicted
and measured ``us_per_call``).

Deliberate simplifications (documented so the fit absorbs them): weights
are charged once per dispatch, not per grid cell (the pipeline keeps the
grid-invariant block resident); the per-layer ladder's own band halos
are charged factor 1 (a conv's ``kh − sy`` overlap rows are noise next
to a chain's composed halo — this slightly favours the UNFUSED
alternative, so the fusion gate only fuses on a genuine modelled win);
im2col patch staging is not charged as HBM traffic (it is VMEM-resident
on the Pallas path and fused into the matmul by XLA) — the per-method
FLOP coefficients absorb the restaging cost.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, replace
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.fusion import (
    FUSABLE_METHODS,
    FusedLayerSpec,
    _conv_out_hw,
    _pool_out_hw,
    group_band_params,
    group_fits_vmem,
)
from repro.core.methods import Method
from repro.core.netdefs import LayerSpec
from repro.core.plan import ExecutionPlan, PlanStep

ITEMSIZE = 4  # fp32 staging end to end

def fused_flop_key(method: Method) -> str:
    """The coefficient bucket of a fused/chain dispatch running
    ``method``.  Fused execution is a genuinely different kernel with a
    different achieved throughput (measured fused speedups are 1.4–3.6×
    — far more than its byte/dispatch savings explain), so it earns its
    own per-method coefficient instead of riding the unfused one."""
    return f"{method.value}:fused"


#: coefficient buckets FLOPs are attributed to: one per ladder method,
#: one per fusable method's FUSED restaging, one for the
#: (method-invariant) fc matmul path, one for the cheap
#: pool/lrn/softmax/relu tail work
FLOP_KEYS: Tuple[str, ...] = (
    tuple(m.value for m in Method)
    + tuple(fused_flop_key(m) for m in Method if m in FUSABLE_METHODS)
    + ("fc", "other"))

#: default committed-model location (repo root), resolved relative to
#: this file so tools work from any cwd
DEFAULT_MODEL_PATH = Path(__file__).resolve().parents[3] / "COST_MODEL.json"


# -- resources of one step ---------------------------------------------------


@dataclass(frozen=True)
class StepCost:
    """One step's modelled resources (whole-batch numbers) plus, once a
    ``CostModel`` has priced them, predicted microseconds."""
    label: str
    kind: str
    key: str            # FLOP coefficient bucket (method value/"fc"/"other")
    flops: float
    hbm_bytes: float
    vmem_bytes: int     # resolved grid-cell working set (0: un-banded)
    dispatches: int
    us: float = 0.0


@dataclass(frozen=True)
class PlanCost:
    """A whole plan's modelled cost: per-step ``StepCost`` rows plus
    aggregate views.  ``us`` is meaningful only when built through a
    fitted ``CostModel`` (unit coefficients otherwise).

    ``model_backend``/``model_fallback_from`` echo the pricing model's
    provenance so a table built from cross-backend borrowed
    coefficients says so."""
    steps: Tuple[StepCost, ...]
    batch: int
    model_backend: str = ""
    model_fallback_from: Optional[str] = None

    @property
    def flops(self) -> float:
        return sum(s.flops for s in self.steps)

    @property
    def hbm_bytes(self) -> float:
        return sum(s.hbm_bytes for s in self.steps)

    @property
    def dispatches(self) -> int:
        return sum(s.dispatches for s in self.steps)

    @property
    def us(self) -> float:
        return sum(s.us for s in self.steps)

    @property
    def flops_by_key(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s in self.steps:
            if s.flops:
                out[s.key] = out.get(s.key, 0.0) + s.flops
        return out

    def table_markdown(self, title: str = "Plan cost") -> str:
        lines = [f"### {title} (batch {self.batch})", "",
                 "| step | kind | bucket | GFLOP | MB streamed "
                 "| VMEM KiB | pred us |",
                 "|---|---|---|---:|---:|---:|---:|"]
        for s in self.steps:
            lines.append(
                f"| {s.label} | {s.kind} | {s.key} | {s.flops / 1e9:.4f} "
                f"| {s.hbm_bytes / 1e6:.2f} | {s.vmem_bytes / 1024:.0f} "
                f"| {s.us:.1f} |")
        lines.append(f"| **total** |  |  | {self.flops / 1e9:.4f} "
                     f"| {self.hbm_bytes / 1e6:.2f} |  | {self.us:.1f} |")
        if self.model_fallback_from:
            lines += ["", f"> **Note**: no fitted coefficients for "
                          f"backend `{self.model_fallback_from}` — priced "
                          f"with the `{self.model_backend}` model "
                          f"(cross-backend fallback; ranks usually "
                          f"transfer, magnitudes do not)."]
        return "\n".join(lines)


# -- fitted coefficients -----------------------------------------------------


@dataclass(frozen=True)
class CostModel:
    """Fitted per-backend coefficients pricing the three resources.

    ``fallback_from`` records a cross-backend substitution made by
    ``load``: the backend that was REQUESTED when no committed entry
    existed for it and another backend's coefficients were returned
    instead.  ``None`` for an exact match.  Callers pricing plans for
    ranking can proceed (rank decisions usually transfer) but must be
    able to surface the substitution — a silently borrowed model looks
    identical to a calibrated one in every downstream report."""
    backend: str
    us_per_gflop: Mapping[str, float]
    us_per_gb: float
    dispatch_us: float
    fallback_from: Optional[str] = None

    def predict(self, flops_by_key: Mapping[str, float], hbm_bytes: float,
                dispatches: int) -> float:
        """Price aggregate features (a whole plan's, or one step's)."""
        us = (dispatches * self.dispatch_us
              + hbm_bytes * 1e-9 * self.us_per_gb)
        for k, f in flops_by_key.items():
            a = self.us_per_gflop.get(k)
            if a is None:
                a = self.us_per_gflop.get("other", 0.0)
            us += f * 1e-9 * a
        return us

    def step_us(self, key: str, flops: float, hbm_bytes: float,
                dispatches: int) -> float:
        return self.predict({key: flops}, hbm_bytes, dispatches)

    @staticmethod
    def unit(backend: str = "unit") -> "CostModel":
        """Unit coefficients: resource accounting without calibration
        (1 us per GFLOP / per GB / per dispatch).  Useful for resource
        comparisons when no committed model applies."""
        return CostModel(backend=backend,
                         us_per_gflop={k: 1.0 for k in FLOP_KEYS},
                         us_per_gb=1.0, dispatch_us=1.0)

    def to_dict(self) -> dict:
        return {"us_per_gflop": dict(self.us_per_gflop),
                "us_per_gb": self.us_per_gb,
                "dispatch_us": self.dispatch_us}

    @classmethod
    def from_dict(cls, d: Mapping, backend: str) -> "CostModel":
        return cls(backend=backend,
                   us_per_gflop=dict(d["us_per_gflop"]),
                   us_per_gb=float(d["us_per_gb"]),
                   dispatch_us=float(d["dispatch_us"]))

    @classmethod
    def load(cls, path: Optional[str] = None,
             backend: str = "cpu") -> "CostModel":
        """Load the committed ``COST_MODEL.json`` (schema:
        ``{"format_version": 1, "backends": {name: coefficients}}``).
        Falls back to the first fitted backend (sorted order) when
        ``backend`` has no entry — coefficient magnitudes will be off
        cross-backend, but rank decisions usually transfer.  The
        substitution is recorded in ``fallback_from`` (the requested
        backend) so reports can flag it instead of presenting borrowed
        coefficients as calibrated."""
        p = Path(path) if path is not None else DEFAULT_MODEL_PATH
        with open(p) as f:
            data = json.load(f)
        backends = data["backends"]
        if backend in backends:
            return cls.from_dict(backends[backend], backend)
        name = sorted(backends)[0]
        return replace(cls.from_dict(backends[name], name),
                       fallback_from=backend)


# -- per-kind resource accounting --------------------------------------------


def _act_bytes(shape: Sequence[int]) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * ITEMSIZE


def _conv_flops(spec: LayerSpec, in_shape: Tuple[int, int, int]) -> float:
    from repro.kernels.conv2d import kernels as K

    c, h, w = in_shape
    oh, ow = _conv_out_hw(h, w, spec)
    kh, kw = spec.kernel
    return 2.0 * K.conv_macs(oh, ow, c, kh, kw, spec.out_channels)


def _conv_weight_bytes(spec: LayerSpec, cin: int) -> int:
    kh, kw = spec.kernel
    return (spec.out_channels * cin * kh * kw + spec.out_channels) * ITEMSIZE


def _overfetch(geo: Optional[dict]) -> float:
    from repro.kernels.conv2d import kernels as K

    if geo is None:
        return 1.0
    # physical band-axis steps × rows fetched per step.  For the classic
    # cell steps == n_tiles and band is the full halo'd band; a
    # sliding-window carry cell runs one extra (prologue) step but each
    # step fetches `carry` fewer rows — the carried halo rows live in
    # VMEM scratch and are NOT re-streamed, which is exactly the traffic
    # win the model must see.
    steps = geo.get("steps", geo["n_tiles"])
    return K.band_overfetch_factor(steps, geo["band"], geo["padded_h"])


def _group_resources(group: FusedLayerSpec, method: Optional[Method],
                     in_shape: Tuple[int, int, int], batch: int,
                     use_pallas: bool,
                     oh_block: Optional[int] = None,
                     geo: Optional[dict] = None) -> StepCost:
    """Resources of ONE fused/chain dispatch: all conv stages' FLOPs plus
    the pool/LRN tail, input charged with the resolved band geometry's
    overfetch factor (Pallas), and NO intermediate activation traffic —
    that is precisely what fusion buys."""
    c, h, w = in_shape
    flops = 0.0
    weight_bytes = 0
    cc, hh, ww = c, h, w
    for cv in group.convs:
        flops += _conv_flops(cv, (cc, hh, ww))
        weight_bytes += _conv_weight_bytes(cv, cc)
        hh, ww = _conv_out_hw(hh, ww, cv)
        cc = cv.out_channels
    if group.pool is not None:
        ph, pw = _pool_out_hw(hh, ww, group.pool)
        flops += cc * ph * pw * group.pool.kernel[0] * group.pool.kernel[1]
        hh, ww = ph, pw
    if group.lrn is not None:
        flops += cc * hh * ww * (group.lrn.lrn_n + 4)
    flops *= batch
    if use_pallas and geo is None:
        geo = group_band_params(
            group, method if method is not None else Method.ADVANCED_SIMD_8,
            in_shape, oh_block)
    factor = _overfetch(geo) if use_pallas else 1.0
    hbm = (batch * _act_bytes(in_shape) * factor + weight_bytes
           + batch * _act_bytes((cc, hh, ww)))
    key = fused_flop_key(method if method is not None
                         else Method.ADVANCED_SIMD_8)
    kind = "chain" if len(group.convs) > 1 else "fused"
    return StepCost(label=group.name, kind=kind, key=key, flops=flops,
                    hbm_bytes=hbm,
                    vmem_bytes=(int(geo["cell_bytes"])
                                if geo and use_pallas else 0),
                    dispatches=1)


def _unfused_group_resources(group: FusedLayerSpec,
                             method: Optional[Method],
                             in_shape: Tuple[int, int, int],
                             batch: int) -> List[StepCost]:
    """The per-layer-ladder alternative of a candidate group: one
    dispatch per conv / pool / lrn, every intermediate activation
    written and re-read.  Input halos charged factor 1 (see module
    docstring) — an optimistic unfused baseline the fused candidate
    must genuinely beat."""
    key = (method.value if method is not None
           else Method.ADVANCED_SIMD_8.value)
    out: List[StepCost] = []
    c, h, w = in_shape
    for cv in group.convs:
        oh, ow = _conv_out_hw(h, w, cv)
        out.append(StepCost(
            label=cv.name, kind="conv", key=key,
            flops=batch * _conv_flops(cv, (c, h, w)),
            hbm_bytes=(batch * _act_bytes((c, h, w))
                       + _conv_weight_bytes(cv, c)
                       + batch * _act_bytes((cv.out_channels, oh, ow))),
            vmem_bytes=0, dispatches=1))
        c, h, w = cv.out_channels, oh, ow
    if group.pool is not None:
        ph, pw = _pool_out_hw(h, w, group.pool)
        out.append(StepCost(
            label=group.pool.name, kind="pool", key="other",
            flops=batch * c * ph * pw
            * group.pool.kernel[0] * group.pool.kernel[1],
            hbm_bytes=batch * (_act_bytes((c, h, w))
                               + _act_bytes((c, ph, pw))),
            vmem_bytes=0, dispatches=1))
        h, w = ph, pw
    if group.lrn is not None:
        out.append(StepCost(
            label=group.lrn.name, kind="lrn", key="other",
            flops=batch * c * h * w * (group.lrn.lrn_n + 4),
            hbm_bytes=batch * 2 * _act_bytes((c, h, w)),
            vmem_bytes=0, dispatches=1))
    return out


def step_resources(plan: ExecutionPlan, step: PlanStep,
                   batch: int = 1) -> StepCost:
    """The modelled resources of one compiled step (``us`` left 0 — a
    ``CostModel`` prices it).  Banded steps read their resolved geometry
    through ``analysis.verifier.step_band_params`` — the same resolver
    path the dispatch runs and the verifier audits."""
    # deferred: analysis imports core.plan at its top level
    from repro.analysis.verifier import step_band_params

    label = "+".join(step.names)
    if step.kind in ("fused", "chain"):
        geo, _ = step_band_params(plan, step)
        return replace(
            _group_resources(step.group, step.method, step.in_shape, batch,
                             plan.use_pallas, step.oh_block, geo=geo),
            label=label)
    if step.kind == "conv":
        geo, _ = step_band_params(plan, step)
        spec = step.spec
        c = step.in_shape[0]
        factor = _overfetch(geo) if plan.use_pallas else 1.0
        return StepCost(
            label=label, kind="conv", key=step.method.value,
            flops=batch * _conv_flops(spec, step.in_shape),
            hbm_bytes=(batch * _act_bytes(step.in_shape) * factor
                       + _conv_weight_bytes(spec, c)
                       + batch * _act_bytes(step.out_shape)),
            vmem_bytes=(int(geo["cell_bytes"])
                        if geo and plan.use_pallas else 0),
            dispatches=1)
    if step.kind == "fc":
        d_in = step.d_in
        d_out = step.spec.out_channels
        return StepCost(
            label=label, kind="fc", key="fc",
            flops=batch * 2.0 * d_in * d_out,
            hbm_bytes=(batch * d_in * ITEMSIZE
                       + (d_in * d_out + d_out) * ITEMSIZE
                       + batch * d_out * ITEMSIZE),
            vmem_bytes=0, dispatches=1)
    if step.kind == "pool":
        geo, _ = step_band_params(plan, step)
        c = step.in_shape[0]
        oh, ow = step.out_shape[1], step.out_shape[2]
        factor = _overfetch(geo) if plan.use_pallas else 1.0
        return StepCost(
            label=label, kind="pool", key="other",
            flops=batch * c * oh * ow
            * step.spec.kernel[0] * step.spec.kernel[1],
            hbm_bytes=batch * (_act_bytes(step.in_shape) * factor
                               + _act_bytes(step.out_shape)),
            vmem_bytes=(int(geo["cell_bytes"])
                        if geo and plan.use_pallas else 0),
            dispatches=1)
    if step.kind == "lrn":
        n_elems = 1
        for d in step.in_shape:
            n_elems *= int(d)
        return StepCost(
            label=label, kind="lrn", key="other",
            flops=batch * n_elems * (step.spec.lrn_n + 4),
            hbm_bytes=batch * 2 * _act_bytes(step.in_shape),
            vmem_bytes=0, dispatches=1)
    if step.kind in ("relu", "softmax"):
        n_elems = 1
        for d in step.in_shape:
            n_elems *= int(d)
        per_elem = 1 if step.kind == "relu" else 5
        return StepCost(
            label=label, kind=step.kind, key="other",
            flops=batch * n_elems * per_elem,
            hbm_bytes=batch * 2 * _act_bytes(step.in_shape),
            vmem_bytes=0, dispatches=1)
    # flatten: a metadata reshape under jit — free
    return StepCost(label=label, kind=step.kind, key="other",
                    flops=0.0, hbm_bytes=0.0, vmem_bytes=0, dispatches=0)


def plan_cost(plan: ExecutionPlan, model: Optional[CostModel] = None,
              batch: int = 1) -> PlanCost:
    """Price a whole compiled plan: per-step resources via
    ``step_resources``, microseconds via ``model`` (unit coefficients
    when None — resource totals stay exact, the us column becomes a
    resource blend rather than a latency)."""
    m = model if model is not None else CostModel.unit()
    steps = []
    for step in plan.steps:
        sc = step_resources(plan, step, batch)
        steps.append(replace(
            sc, us=m.step_us(sc.key, sc.flops, sc.hbm_bytes, sc.dispatches)))
    return PlanCost(steps=tuple(steps), batch=batch,
                    model_backend=m.backend,
                    model_fallback_from=m.fallback_from)


# -- cost-model fusion gate --------------------------------------------------


def fusion_cost_gate(model: Optional[CostModel] = None, *, batch: int = 1,
                     use_pallas: bool = False,
                     vmem_budget: Optional[int] = None):
    """Build the ``cost_gate`` callable ``plan_fusion`` accepts: a
    candidate group is admitted only when (a) its floor cell still fits
    the VMEM budget (Pallas path — same ``group_fits_vmem`` accounting
    as the raw check) and (b) the model scores the single fused dispatch
    no slower than its per-layer ladder.  This is the decision the raw
    budget check structurally cannot make: a chain that FITS but whose
    composed-halo overfetch makes it slower than running unfused is
    declined, and the planner's fallback ladder then tries the shorter
    chains."""
    m = model if model is not None else CostModel.unit()

    def gate(group: FusedLayerSpec, method: Optional[Method],
             in_shape: Tuple[int, int, int]) -> bool:
        if use_pallas and not group_fits_vmem(group, method, in_shape,
                                              vmem_budget):
            return False
        fused = _group_resources(group, method, in_shape, batch, use_pallas)
        fused_us = m.step_us(fused.key, fused.flops, fused.hbm_bytes,
                             fused.dispatches)
        unfused_us = sum(
            m.step_us(s.key, s.flops, s.hbm_bytes, s.dispatches)
            for s in _unfused_group_resources(group, method, in_shape, batch))
        return fused_us <= unfused_us

    return gate


# -- fitting + rank validation (numpy only — no scipy in the image) ----------


def _ranks(v) -> "object":
    import numpy as np

    v = np.asarray(v, dtype=float)
    order = np.argsort(v, kind="mergesort")
    ranks = np.empty(v.size, dtype=float)
    ranks[order] = np.arange(1, v.size + 1, dtype=float)
    for val in np.unique(v):  # average ties
        mask = v == val
        if mask.sum() > 1:
            ranks[mask] = ranks[mask].mean()
    return ranks


def spearman(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Spearman rank correlation (average-tie ranks, Pearson of ranks).
    Returns 0.0 for degenerate inputs (n < 2 or a constant series)."""
    import numpy as np

    x = np.asarray(xs, dtype=float)
    y = np.asarray(ys, dtype=float)
    if x.size != y.size:
        raise ValueError(f"length mismatch: {x.size} vs {y.size}")
    if x.size < 2:
        return 0.0
    rx, ry = _ranks(x), _ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx == 0.0 or sy == 0.0:
        return 0.0
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


def fit_coefficients(rows: Sequence[Mapping], backend: str) -> CostModel:
    """Fit the coefficient vector from measured rows — each row
    ``{"flops_by_key": {bucket: flops}, "hbm_bytes": b, "dispatches": d,
    "us": measured}`` — by RELATIVE least squares (each row scaled by
    1/measured-us, so a lenet5 row at 2 ms and an alexnet row at 12 s
    pull equally — absolute least squares would fit only the biggest
    net) with iterative negative-column pruning (a simplified NNLS: the
    most-negative coefficient is dropped and the system re-solved until
    all remaining are ≥ 0), so every fitted coefficient prices its
    resource non-negatively and the model stays monotone for the
    autotuner.  FLOP buckets never observed in the rows (or pruned
    away) get the LARGEST fitted bucket coefficient — unmeasured
    methods look expensive, never spuriously fast."""
    import numpy as np

    keys = sorted({k for r in rows
                   for k, v in r["flops_by_key"].items() if v > 0})
    cols = list(keys) + ["__gb__", "__dispatch__"]
    A = np.zeros((len(rows), len(cols)))
    y = np.ones(len(rows))  # each row normalized by its measured us
    for i, r in enumerate(rows):
        us = float(r["us"])
        for j, k in enumerate(keys):
            A[i, j] = r["flops_by_key"].get(k, 0.0) * 1e-9 / us
        A[i, len(keys)] = float(r["hbm_bytes"]) * 1e-9 / us
        A[i, len(keys) + 1] = float(r["dispatches"]) / us
    coef = np.zeros(len(cols))
    active = list(range(len(cols)))
    while active:
        sol, _, _, _ = np.linalg.lstsq(A[:, active], y, rcond=None)
        if (sol >= 0).all():
            for j, cj in enumerate(active):
                coef[cj] = float(sol[j])
            break
        drop = int(np.argmin(sol))
        active.pop(drop)
    fitted = {k: coef[j] for j, k in enumerate(keys)}
    positive = [v for v in fitted.values() if v > 0]
    fallback = max(positive) if positive else 1.0
    us_per_gflop = {k: (fitted[k] if fitted.get(k, 0.0) > 0 else fallback)
                    for k in FLOP_KEYS}
    return CostModel(backend=backend, us_per_gflop=us_per_gflop,
                     us_per_gb=float(coef[len(keys)]),
                     dispatch_us=float(coef[len(keys) + 1]))
