"""Checkpointing for transformer training — the deploy format of
``repro.core.deploy`` plus optimizer state and step metadata.

Saves are atomic (write to a temp dir, rename) so an interrupted run never
corrupts the latest checkpoint; restore verifies the weight checksum.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deploy import _flatten, _unflatten

# npy files cannot store bfloat16/float16-exotic dtypes; store a lossless
# float32 upcast plus the original dtype for exact restoration.
_NPY_UNSAFE = ("bfloat16",)


def _encode(flat):
    enc, dtypes = {}, {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        enc[k] = v.astype(np.float32) if str(v.dtype) in _NPY_UNSAFE else v
    return enc, dtypes


def _decode(data, dtypes):
    out = {}
    for k in data.files:
        arr = data[k]
        dt = dtypes.get(k, str(arr.dtype))
        out[k] = jnp.asarray(arr).astype(dt) if dt in _NPY_UNSAFE else arr
    return out


def save_checkpoint(path, params, opt_state, step: int, extra: dict = None):
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    p_enc, p_dt = _encode(_flatten(params))
    o_enc, o_dt = _encode(_flatten(opt_state))
    np.savez(tmp / "params.npz", **p_enc)
    np.savez(tmp / "opt.npz", **o_enc)
    (tmp / "meta.json").write_text(json.dumps(
        {"step": int(step), "extra": extra or {},
         "param_dtypes": p_dt, "opt_dtypes": o_dt}))
    if path.exists():
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_checkpoint(path) -> Tuple[dict, dict, int, dict]:
    path = Path(path)
    p = np.load(path / "params.npz")
    o = np.load(path / "opt.npz")
    meta = json.loads((path / "meta.json").read_text())
    params = _unflatten(_decode(p, meta.get("param_dtypes", {})))
    opt = _unflatten(_decode(o, meta.get("opt_dtypes", {})))
    return params, opt, meta["step"], meta["extra"]
