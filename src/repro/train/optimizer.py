"""AdamW with fp32 moments, global-norm clipping, and ZeRO-1 sharding.

Moments are described as Param trees so the sharding machinery applies.
With ``zero1=True`` each moment tensor additionally shards its largest
dp-divisible replicated axis over the data axes (logical axis "zero") —
optimizer state per device drops by ~dp×, which is what makes grok-1-314b
trainable on a 16 GB/chip pod (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import TrainConfig
from repro.nn.param import Param, is_param


# logical axes that may already be mapped to the dp mesh axes — a second
# dp-sharded axis in the same spec would collide (GSPMD allows each mesh
# axis on at most one positional dimension)
_DP_LOGICAL = ("batch", "zero", "embed")


def _zero1_axes(p: Param, dp_size: int, dp_logical=("batch", "zero")) -> Param:
    """Shard the largest still-replicated axis over the dp axes."""
    if any(a in dp_logical for a in p.axes):
        return p  # already dp-sharded somewhere (e.g. FSDP'd "embed")
    best, best_size = -1, 0
    for i, (ax, size) in enumerate(zip(p.axes, p.shape)):
        if ax is None and size % dp_size == 0 and size > best_size:
            best, best_size = i, size
    if best < 0:
        return p
    axes = tuple("zero" if i == best else a for i, a in enumerate(p.axes))
    return Param(p.shape, axes, p.init, p.scale, p.dtype)


def adamw_init_spec(param_spec, zero1: bool = True, dp_size: int = 1,
                    fsdp: bool = False, moment_dtype: str = "float32") -> dict:
    """Moment specs mirroring the parameter spec.

    With ``fsdp`` the "embed" axis is already dp-sharded, so ZeRO-1 must not
    add a second dp axis.  ``moment_dtype`` supports the documented bf16-
    optimizer variant for grok-1-scale models (EXPERIMENTS.md §Dry-run)."""
    dp_logical = ("batch", "zero", "embed") if fsdp else ("batch", "zero")

    def moment(p: Param) -> Param:
        m = Param(p.shape, p.axes, init="zeros", dtype=moment_dtype)
        return (_zero1_axes(m, dp_size, dp_logical)
                if zero1 and dp_size > 1 else m)

    return {
        "m": jax.tree_util.tree_map(moment, param_spec, is_leaf=is_param),
        "v": jax.tree_util.tree_map(moment, param_spec, is_leaf=is_param),
        "step": Param((), (), init="zeros", dtype="int32"),
    }


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t
    )
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def lr_schedule(step, tcfg: TrainConfig) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps) / max(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * cos


def adamw_update(
    grads, opt_state, params, tcfg: TrainConfig
) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, tcfg)
    b1, b2, eps = tcfg.b1, tcfg.b2, tcfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if p.ndim >= 2:  # no weight decay on norms/biases/scalars
            u = u + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
