"""Train-step builder: loss, grads, optimizer update — one jit-able function."""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, TrainConfig
from repro.train.optimizer import adamw_update


def cross_entropy(logits, labels, vocab_size: int) -> jnp.ndarray:
    """Mean CE over all tokens.  logits fp32 [b,s,V_padded]; labels [b,s].

    The gold logit is extracted with a masked reduction (NOT
    ``take_along_axis``): a gather along the vocab axis would force GSPMD to
    all-gather the vocab-sharded logits (~67 GB/device at train_4k scale);
    the masked sum keeps every op sharded exactly like the logits.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold = jnp.sum(
        jnp.where(labels[..., None] == vocab_iota, logits, 0.0), axis=-1
    )
    return jnp.mean(lse - gold)


def make_loss_fn(model, *, dp_size: int = 1, window_override: int = 0,
                 use_pallas: bool = False) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(
            params, batch, mode="train", dp_size=dp_size,
            window_override=window_override, use_pallas=use_pallas,
        )
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        loss = ce
        metrics = {"ce": ce}
        for k in ("load_balance_loss", "router_z_loss"):
            if k in aux:
                loss = loss + aux[k]
                metrics[k] = aux[k]
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(model, tcfg: TrainConfig, *, dp_size: int = 1,
                    window_override: int = 0, microbatches: int = 1,
                    grad_acc_dtype: str = "float32",
                    use_pallas: bool = False) -> Callable:
    """With ``microbatches > 1`` the global batch is split along the batch
    axis and gradients are accumulated by a ``lax.scan`` (activation memory
    scales 1/k; the split is strided — ``reshape(b//k, k, s)`` — so each
    microbatch keeps the full data-parallel sharding of the batch axis)."""
    loss_fn = make_loss_fn(model, dp_size=dp_size,
                           window_override=window_override,
                           use_pallas=use_pallas)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, metrics), grads = grads_of(params, batch)
        else:
            k = microbatches

            def split(x):
                b = x.shape[0]
                assert b % k == 0, (b, k)
                xr = x.reshape(b // k, k, *x.shape[1:])
                return jnp.moveaxis(xr, 1, 0)  # [k, b//k, ...]

            mbs = jax.tree_util.tree_map(split, batch)

            def mb_step(acc, mb):
                g_acc, m_acc = acc
                (_, metrics), g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                m_acc = jax.tree_util.tree_map(lambda a, b_: a + b_,
                                               m_acc, metrics)
                return (g_acc, m_acc), None

            acc_dt = jnp.dtype(grad_acc_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, metrics), _ = jax.lax.scan(
                mb_step, (g0, _zero_metrics(model)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / k, metrics)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg
        )
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def _zero_metrics(model) -> dict:
    # scan_layers always emits the two aux-loss accumulators (zero for
    # non-MoE models), so the metric structure is uniform across families.
    return {k: jnp.zeros((), jnp.float32) for k in
            ("ce", "loss", "load_balance_loss", "router_z_loss")}


def default_microbatches(tokens: int, dp_size: int,
                         max_local_tokens: int = 8_192) -> int:
    """Pick the accumulation factor so each device sees <= max_local_tokens
    activations at a time; must divide the per-shard batch."""
    k = max(1, -(-tokens // (dp_size * max_local_tokens)))
    return k
