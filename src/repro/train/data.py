"""Data pipeline: synthetic LM corpora with deterministic generation and
host-side prefetch.

``markov_corpus`` builds a fixed random first-order Markov chain; its
per-token entropy is computable in closed form, so a training run has a
known CE floor — the loss curve is a real convergence check, not vibes.
``batches`` yields (tokens, labels) with double-buffered host prefetch
(the Fig. 5 host/device overlap applied to training input).
"""
from __future__ import annotations

import threading
from queue import Queue
from typing import Iterator, Tuple

import numpy as np


class MarkovLM:
    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        probs = rng.dirichlet(np.full(vocab, concentration), size=vocab)
        self.vocab = vocab
        self.P = probs.astype(np.float64)

    def entropy(self) -> float:
        """Stationary per-token entropy (nats) — the CE floor."""
        evals, evecs = np.linalg.eig(self.P.T)
        i = int(np.argmin(np.abs(evals - 1.0)))
        pi = np.real(evecs[:, i])
        pi = np.abs(pi) / np.abs(pi).sum()
        row_h = -np.sum(self.P * np.log(np.maximum(self.P, 1e-12)), axis=1)
        return float(pi @ row_h)

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        out = np.empty((batch, seq + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            cdf = np.cumsum(self.P[out[:, t]], axis=1)
            u = rng.random((batch, 1))
            out[:, t + 1] = (u > cdf).sum(axis=1)
        return out


def batches(
    lm: MarkovLM, batch: int, seq: int, seed: int = 1, prefetch: int = 2
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens [b,s], labels [b,s]) with background prefetch."""
    q: Queue = Queue(maxsize=prefetch)

    def worker():
        rng = np.random.default_rng(seed)
        while True:
            chunk = lm.sample(rng, batch, seq)
            q.put((chunk[:, :-1], chunk[:, 1:]))

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        yield q.get()
