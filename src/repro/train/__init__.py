from repro.train.optimizer import adamw_init_spec, adamw_init, adamw_update
from repro.train.step import make_train_step, cross_entropy

__all__ = [
    "adamw_init_spec",
    "adamw_init",
    "adamw_update",
    "make_train_step",
    "cross_entropy",
]
