"""RWKV6 ("Finch") — data-dependent-decay linear attention.

Time mixing implements the WKV6 recurrence per 64-wide head:

    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    o_t = r_t · (S_{t-1} + diag(u) k_t ⊗ v_t)

with per-channel data-dependent decay ``w_t = exp(-exp(w0 + lora(x)))`` and
data-dependent token-shift interpolation (ddlerp) per RWKV6.  The forward
pass is *chunked*: within a chunk the pairwise decay products
``exp(cw_{i-1} - cw_j)`` (always ≤ 1, numerically safe) are computed
explicitly; across chunks a per-head [hd, hd] state is carried by one
``lax.scan``.  ``wkv6_reference`` is the per-timestep oracle.

Simplifications vs the released checkpoint (documented in DESIGN.md §7):
the output group-norm is a per-head RMSNorm; the five ddlerp branches share
one LoRA trunk with per-branch heads (same parameter budget and dataflow).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.linear import linear_spec, dense
from repro.nn.norm import rmsnorm_spec, rmsnorm_apply
from repro.nn.param import Param
from repro.sharding.ctx import shard_act

_BRANCHES = ("r", "k", "v", "w", "g")


def rwkv_dims(cfg: ModelConfig):
    d = cfg.d_model
    h = d // cfg.rwkv.head_dim
    return d, h


def rwkv_time_spec(cfg: ModelConfig) -> dict:
    r = cfg.rwkv
    d, h = rwkv_dims(cfg)
    spec = {
        # ddlerp: shared trunk + per-branch head
        "mu": Param((len(_BRANCHES), d), (None, "embed"), init="zeros",
                    dtype="float32"),
        "mu_x": Param((d,), ("embed",), init="zeros", dtype="float32"),
        "lora_A": Param((d, len(_BRANCHES) * r.tokenshift_lora),
                        ("embed", None), init="fan_in", dtype="float32"),
        "lora_B": Param((len(_BRANCHES), r.tokenshift_lora, d),
                        (None, None, "embed"), init="zeros", dtype="float32"),
        # decay lora
        "w0": Param((d,), ("embed",), init="zeros", dtype="float32"),
        "w_A": Param((d, r.decay_lora), ("embed", None), init="fan_in",
                     dtype="float32"),
        "w_B": Param((r.decay_lora, d), (None, "embed"), init="zeros",
                     dtype="float32"),
        "u": Param((d,), ("embed",), init="zeros", dtype="float32"),
        "wr": linear_spec(d, d, "embed", "ssm_inner"),
        "wk": linear_spec(d, d, "embed", "ssm_inner"),
        "wv": linear_spec(d, d, "embed", "ssm_inner"),
        "wg": linear_spec(d, d, "embed", "ssm_inner"),
        "wo": linear_spec(d, d, "ssm_inner", "embed"),
        "out_norm": rmsnorm_spec(cfg.rwkv.head_dim),
    }
    return spec


def rwkv_channel_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "mu_k": Param((d,), ("embed",), init="zeros", dtype="float32"),
        "mu_r": Param((d,), ("embed",), init="zeros", dtype="float32"),
        "wk": linear_spec(d, cfg.d_ff, "embed", "ff"),
        "wv": linear_spec(cfg.d_ff, d, "ff", "embed"),
        "wr": linear_spec(d, d, "embed", "embed"),
    }


def _token_shift(x, last: Optional[jnp.ndarray]):
    """sx_t = x_{t-1} - x_t; `last` is the final token of the previous
    segment ([b, d]) for streaming decode."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return prev - x


def _ddlerp(params, x, sx):
    """Data-dependent interpolation producing the 5 branch inputs."""
    nb = len(_BRANCHES)
    xf = x.astype(jnp.float32)
    sxf = sx.astype(jnp.float32)
    base = xf + sxf * params["mu_x"][None, None]
    t = jnp.tanh(base @ params["lora_A"])  # [b,s,nb*L]
    t = t.reshape(*t.shape[:-1], nb, -1)  # [b,s,nb,L]
    adj = jnp.einsum("bsnl,nld->bsnd", t, params["lora_B"])  # [b,s,nb,d]
    mix = params["mu"][None, None] + adj  # [b,s,nb,d]
    out = xf[:, :, None, :] + sxf[:, :, None, :] * mix
    return tuple(out[:, :, i].astype(x.dtype) for i in range(nb))


def _wkv6_chunked(r, k, v, logw, u, chunk: int, state=None):
    """r,k,v: [b,s,h,e]; logw: [b,s,h,e] (log decay, <0); u: [h,e].

    Returns (o [b,s,h,e], final state [b,h,e,e] with layout [key, value])."""
    b, s, h, e = r.shape
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        logw = jnp.pad(logw, z)
    nc = (s + pad) // L
    rc = jnp.moveaxis(r.reshape(b, nc, L, h, e), 1, 0).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nc, L, h, e), 1, 0).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(b, nc, L, h, e), 1, 0).astype(jnp.float32)
    wc = jnp.moveaxis(logw.reshape(b, nc, L, h, e), 1, 0).astype(jnp.float32)

    li = jnp.arange(L)
    strict = li[:, None] > li[None, :]  # j < i

    def step(S, inp):
        r_c, k_c, v_c, w_c = inp  # [b,L,h,e]
        cw = jnp.cumsum(w_c, axis=1)  # inclusive
        cw_prev = cw - w_c  # cumulative decay up to t-1 (exclusive)
        # intra-chunk: A[i,j] = sum_e r_i[e] k_j[e] exp(cw_prev_i - cw_j), j<i
        decay = jnp.exp(
            cw_prev[:, :, None, :, :] - cw[:, None, :, :, :]
        )  # [b,I,J,h,e]
        A = jnp.einsum(
            "bihe,bijhe,bjhe->bhij", r_c, decay, k_c,
        )
        A = jnp.where(strict[None, None], A, 0.0)
        # diagonal bonus: (r_i ⊙ u ⊙ k_i) v_i
        diag = jnp.einsum("bihe,he,bihe->bih", r_c, u.astype(jnp.float32), k_c)
        o = jnp.einsum("bhij,bjhe->bihe", A, v_c)
        o = o + diag[..., None] * v_c
        # inter-chunk: o_i += (r_i ⊙ exp(cw_prev_i)) @ S
        o = o + jnp.einsum("bihe,bhef->bihf", r_c * jnp.exp(cw_prev), S)
        # state update: S' = diag(exp(cw_L)) S + sum_j exp(cw_L - cw_j) k_j v_j
        total = cw[:, -1]  # [b,h,e]
        Sc = jnp.einsum("bjhe,bjhf->bhef", k_c * jnp.exp(total[:, None] - cw), v_c)
        S_new = S * jnp.exp(total)[..., None] + Sc
        return S_new, o

    S0 = jnp.zeros((b, h, e, e), jnp.float32) if state is None else state
    S_final, os_ = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    o = jnp.moveaxis(os_, 0, 1).reshape(b, s + pad, h, e)[:, :s]
    return o.astype(r.dtype), S_final


def wkv6_reference(r, k, v, logw, u, state=None):
    """Per-timestep recurrence oracle (fp32)."""
    b, s, h, e = r.shape
    S0 = jnp.zeros((b, h, e, e), jnp.float32) if state is None else state

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [b,h,e]
        kv = jnp.einsum("bhe,bhf->bhef", k_t, v_t)
        o = jnp.einsum(
            "bhe,bhef->bhf", r_t, S + u[None].astype(jnp.float32) [..., None] * kv
        )
        S = S * jnp.exp(w_t)[..., None] + kv
        return S, o

    xs = tuple(
        jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, logw)
    )
    S_final, os_ = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(os_, 0, 1).astype(r.dtype), S_final


def rwkv_time_apply(
    params,
    x,  # [b, s, d]
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,  # {"last": [b,d], "state": [b,h,e,e]}
    mode: str = "full",
) -> Tuple[jnp.ndarray, Optional[dict]]:
    d, h = rwkv_dims(cfg)
    e = cfg.rwkv.head_dim
    b, s, _ = x.shape
    last = cache.get("last") if cache else None
    sx = _token_shift(x, last)
    xr, xk, xv, xw, xg = _ddlerp(params, x, sx)

    r = shard_act(dense(params["wr"], xr).reshape(b, s, h, e),
                  ("batch", "seq", "heads", None))
    k = shard_act(dense(params["wk"], xk).reshape(b, s, h, e),
                  ("batch", "seq", "heads", None))
    v = shard_act(dense(params["wv"], xv).reshape(b, s, h, e),
                  ("batch", "seq", "heads", None))
    g = dense(params["wg"], xg)
    loww = (
        params["w0"][None, None]
        + jnp.tanh(xw.astype(jnp.float32) @ params["w_A"]) @ params["w_B"]
    )
    logw = -jnp.exp(loww).reshape(b, s, h, e)  # log decay < 0
    u = params["u"].reshape(h, e)

    state = cache.get("state") if cache else None
    if mode == "full" and s > 1:
        o, S_final = _wkv6_chunked(r, k, v, logw, u, cfg.rwkv.chunk_size, state)
    else:
        o, S_final = wkv6_reference(r, k, v, logw, u, state)

    o = rmsnorm_apply(params["out_norm"], o, cfg.norm_eps)
    o = o.reshape(b, s, d) * jax.nn.silu(g)
    out = dense(params["wo"], o)
    new_cache = None
    if cache is not None:
        new_cache = {"last": x[:, -1].astype(jnp.float32), "state": S_final}
    return out, new_cache


def rwkv_channel_apply(params, x, cfg: ModelConfig, cache: Optional[dict] = None):
    last = cache.get("last") if cache else None
    sx = _token_shift(x, last)
    xf = x.astype(jnp.float32)
    xk = (xf + sx.astype(jnp.float32) * params["mu_k"]).astype(x.dtype)
    xr = (xf + sx.astype(jnp.float32) * params["mu_r"]).astype(x.dtype)
    kk = dense(params["wk"], xk, act="relu")
    kk = kk * kk
    vv = dense(params["wv"], kk)
    rr = jax.nn.sigmoid(dense(params["wr"], xr).astype(jnp.float32)).astype(x.dtype)
    out = rr * vv
    new_cache = {"last": x[:, -1].astype(jnp.float32)} if cache is not None else None
    return out, new_cache
