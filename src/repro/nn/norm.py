"""Normalization layers (RMSNorm, LayerNorm) with fp32 statistics."""
from __future__ import annotations

import jax.numpy as jnp

from repro.nn.param import Param


def rmsnorm_spec(dim: int) -> dict:
    return {"scale": Param((dim,), ("embed",), init="ones", dtype="float32")}


def rmsnorm_apply(params, x, eps: float = 1e-6, plus_one: bool = False):
    """RMSNorm.  ``plus_one=True`` uses the gemma convention scale=(1+w)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * (var + eps) ** -0.5
    w = params["scale"].astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (xf * w).astype(dtype)


def layernorm_spec(dim: int) -> dict:
    return {
        "scale": Param((dim,), ("embed",), init="ones", dtype="float32"),
        "bias": Param((dim,), ("embed",), init="zeros", dtype="float32"),
    }


def layernorm_apply(params, x, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
