"""Parameter descriptors.

A :class:`Param` records shape, logical sharding axes, and initializer for
one tensor.  Modules build pytrees of Params; :func:`init_tree` materializes
them, :func:`axes_tree` extracts the logical-axes pytree (which
``repro.sharding.spec_tree`` maps to PartitionSpecs for a concrete mesh).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Param(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | fan_in
    scale: float = 1.0
    dtype: Optional[str] = None

    def check(self) -> "Param":
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        return self


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def _initialize(p: Param, key, default_dtype: str):
    dtype = jnp.dtype(p.dtype or default_dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        return (p.scale * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "embed":
        return (p.scale * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "fan_in":
        fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[0], 1)
        # stacked / expert leading dims do not contribute to fan-in
        if len(p.shape) == 3:
            fan_in = p.shape[1]
        std = p.scale / math.sqrt(fan_in)
        return (std * jax.random.normal(key, p.shape)).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def init_tree(spec, key, default_dtype: str = "bfloat16"):
    """Materialize a pytree of Params into arrays, splitting `key` per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    arrs = [_initialize(p.check(), k, default_dtype) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def axes_tree(spec):
    """Extract the logical-axes pytree (leaves are tuples of axis names)."""
    return jax.tree_util.tree_map(lambda p: p.axes, spec, is_leaf=is_param)


def shapes_tree(spec):
    return jax.tree_util.tree_map(lambda p: p.shape, spec, is_leaf=is_param)


def stack_spec(spec, n: int, axis_name: Optional[str] = "layers"):
    """Prepend a stacked (scan) dimension of size `n` to every Param."""

    def f(p: Param) -> Param:
        return Param((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale, p.dtype)

    return jax.tree_util.tree_map(f, spec, is_leaf=is_param)


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))
