"""Pure-JAX neural-network substrate.

Modules are pairs of functions: ``<module>_spec(cfg) -> pytree[Param]``
describing parameters (shape + logical sharding axes + initializer), and
``<module>_apply(params, ...)`` computing the forward pass.  No framework
dependency; everything composes with jit/pjit/shard_map/scan.
"""
from repro.nn.param import Param, init_tree, axes_tree, stack_spec

__all__ = ["Param", "init_tree", "axes_tree", "stack_spec"]
