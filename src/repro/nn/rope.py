"""Rotary position embeddings (half-split convention, llama-style)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions [...,] -> (cos, sin) of shape [..., head_dim/2], fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions broadcastable to [..., seq].

    Uses the split-halves rotation (x1, x2) -> (x1*c - x2*s, x2*c + x1*s).
    """
    head_dim = x.shape[-1]
    cos, sin = rope_angles(positions, head_dim, theta)  # [..., seq, half]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)
