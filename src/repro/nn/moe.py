"""Mixture-of-Experts with hierarchical capacity-bounded dispatch.

Design (DESIGN.md §5):

* Tokens are viewed as ``[D, T_l, ...]`` where ``D`` = number of data
  shards.  Routing, sort, and dispatch are *per data-shard group*, so every
  gather/scatter is batched along the dp-sharded leading axis and stays
  local under GSPMD — no token tensor is ever all-gathered.
* Expert weights shard over the model axis either on the expert dim
  (``shard_mode="expert"``, many small experts) or on each expert's ff dim
  (``shard_mode="tensor"``, few large experts).
* Dispatch is sort-based (argsort by expert id + capacity clamp), so the
  expert matmuls perform exactly ``tokens × top_k × capacity_factor`` worth
  of FLOPs — HLO FLOPs ≈ active FLOPs, unlike dense one-hot mixing.
* Training uses ``capacity_factor`` with token dropping (standard); decode
  uses worst-case capacity (no drops — a dropped token at inference would
  corrupt a user request).

Aux outputs: load-balance loss (Switch-style) and router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.linear import act_fn
from repro.nn.param import Param
from repro.sharding.ctx import shard_act


def moe_spec(cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.num_experts
    e_ax = "experts" if moe.shard_mode == "expert" else None
    f_ax = None if moe.shard_mode == "expert" else "expert_ff"
    return {
        "router": Param((d, E), ("embed", None), init="fan_in", dtype="float32"),
        "we_gate": Param((E, d, f), (e_ax, "embed", f_ax), init="fan_in"),
        "we_up": Param((E, d, f), (e_ax, "embed", f_ax), init="fan_in"),
        "we_down": Param((E, f, d), (e_ax, f_ax, "embed"), init="fan_in"),
    }


def _group_count(tokens: int, dp_size: int) -> int:
    """Largest divisor of `tokens` that is <= dp_size (handles tiny decode
    batches where tokens < dp)."""
    d = min(tokens, dp_size)
    while tokens % d:
        d -= 1
    return d


def moe_apply(
    params,
    x,  # [b, s, d]
    cfg: ModelConfig,
    *,
    dp_size: int = 1,
    mode: str = "train",  # "train" | "prefill" | "decode"
) -> Tuple[jnp.ndarray, dict]:
    moe = cfg.moe
    E, k = moe.num_experts, moe.num_experts_per_token
    b, s, d = x.shape
    T = b * s
    D = _group_count(T, dp_size)
    T_l = T // D

    xf = shard_act(x.reshape(D, T_l, d), ("batch", None, None))
    logits = jnp.einsum(
        "gtd,de->gte", xf.astype(jnp.float32), params["router"].astype(jnp.float32)
    )  # [D, T_l, E]
    probs = jax.nn.softmax(logits, axis=-1)
    p_k, e_k = jax.lax.top_k(probs, k)  # [D, T_l, k]
    p_k = p_k / jnp.maximum(jnp.sum(p_k, axis=-1, keepdims=True), 1e-9)

    if mode == "decode":
        cap = T_l * k  # worst case — no token is ever dropped at decode
    else:
        cf = moe.capacity_factor if mode == "train" else moe.eval_capacity_factor
        cap = max(1, math.ceil(T_l * k * cf / E))
        cap = min(cap, T_l * k)

    # --- sort-based dispatch (per group) ------------------------------------
    flat_e = e_k.reshape(D, T_l * k)
    flat_p = p_k.reshape(D, T_l * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # [D, T_l*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    pos_in_e = jnp.arange(T_l * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1
    )
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, E * cap)  # sentinel=E*cap
    src_tok = order // k  # source token per sorted entry

    # slot -> source token map (sentinel row T_l = zeros)
    gidx = jnp.arange(D)[:, None]
    src_map = jnp.full((D, E * cap + 1), T_l, dtype=jnp.int32)
    src_map = src_map.at[gidx, slot].set(src_tok.astype(jnp.int32), mode="drop")
    src_map = src_map[:, : E * cap]

    xf_pad = jnp.concatenate([xf, jnp.zeros((D, 1, d), xf.dtype)], axis=1)
    buf = jnp.take_along_axis(xf_pad, src_map[..., None], axis=1)  # [D, E*cap, d]
    buf = buf.reshape(D, E, cap, d)
    buf = shard_act(buf, ("batch", "experts", None, None))

    # --- expert computation (sharded over the model axis) -------------------
    act = act_fn(cfg.act)
    g = jnp.einsum("gecd,edf->gecf", buf, params["we_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["we_up"])
    gu = shard_act(act(g) * u, ("batch", "experts", None, "expert_ff"))
    y = jnp.einsum("gecf,efd->gecd", gu, params["we_down"])
    y = shard_act(y, ("batch", "experts", None, None))
    y = y.reshape(D, E * cap, d)
    y_pad = jnp.concatenate([y, jnp.zeros((D, 1, d), y.dtype)], axis=1)

    # --- combine -------------------------------------------------------------
    # slot index for each (token, k) pair in original order (sentinel E*cap)
    inv_slot = jnp.full((D, T_l * k), E * cap, dtype=jnp.int32)
    inv_slot = inv_slot.at[gidx, order].set(
        jnp.where(keep, slot, E * cap).astype(jnp.int32)
    )
    picked = jnp.take_along_axis(y_pad, inv_slot[..., None], axis=1)  # [D,T_l*k,d]
    picked = picked.reshape(D, T_l, k, d)
    out = jnp.sum(picked * flat_p.reshape(D, T_l, k, 1).astype(picked.dtype), axis=2)
    out = shard_act(out, ("batch", None, None))

    # --- aux losses ----------------------------------------------------------
    # Switch-style load balance: E * sum_e f_e * P_e
    assign = jax.nn.one_hot(e_k, E, dtype=jnp.float32)  # [D,T_l,k,E]
    f_e = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1))  # fraction per expert *k
    P_e = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(f_e / k * P_e)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = {
        "load_balance_loss": moe.load_balance_loss * lb,
        "router_z_loss": moe.router_z_loss * z,
        "expert_fraction": f_e / k,
    }
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Dense reference (tiny shapes only — oracle for tests)
# ---------------------------------------------------------------------------


def moe_reference(params, x, cfg: ModelConfig):
    """O(T·E·d·f) dense mixing — bitwise-independent oracle for tests."""
    moe = cfg.moe
    E, k = moe.num_experts, moe.num_experts_per_token
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    p_k, e_k = jax.lax.top_k(probs, k)
    p_k = p_k / jnp.maximum(jnp.sum(p_k, axis=-1, keepdims=True), 1e-9)
    gate = jnp.zeros_like(probs).at[jnp.arange(xf.shape[0])[:, None], e_k].set(p_k)
    act = act_fn(cfg.act)
    g = jnp.einsum("td,edf->tef", xf, params["we_gate"])
    u = jnp.einsum("td,edf->tef", xf, params["we_up"])
    y = jnp.einsum("tef,efd->ted", act(g) * u, params["we_down"])
    out = jnp.einsum("ted,te->td", y, gate.astype(y.dtype))
    return out.reshape(b, s, d)
