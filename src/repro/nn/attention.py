"""Attention: GQA, RoPE, sliding-window, softcap, cross-attention, KV cache.

Two execution paths:

* ``chunked_attention`` — training/prefill.  A *pair-list* flash-style
  attention in pure jnp: the (q-chunk, kv-chunk) pairs that are visible
  under the causal/sliding-window mask are enumerated statically at trace
  time and processed by one ``lax.scan`` with online softmax.  Memory is
  O(chunk²) instead of O(seq²) and HLO FLOPs match the true masked FLOPs
  (no full s×s score tensor is ever built).  This is also the oracle for
  the flash Pallas kernel in ``repro.kernels.attention``.

* ``decode_attention`` — single-token decode against a (possibly
  ring-buffered sliding-window) KV cache with per-request positions.

GQA sharding note: q heads shard over the model axis when divisible; k/v
heads are stored un-expanded in the cache and repeated to full heads at
compute time (repetition is bytes-free in FLOPs and keeps the head axis
sharding consistent — see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.linear import linear_spec, dense
from repro.nn.norm import rmsnorm_spec, rmsnorm_apply
from repro.nn.param import Param
from repro.nn.rope import apply_rope
from repro.sharding.ctx import shard_act

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter spec
# ---------------------------------------------------------------------------


def attention_spec(cfg: ModelConfig, cross: bool = False, kv_dim: Optional[int] = None) -> dict:
    """QKV + output projections.  ``cross=True`` reads K/V from a context
    stream of width ``kv_dim`` (defaults to d_model)."""
    d = cfg.d_model
    kv_in = kv_dim or d
    spec = {
        "wq": linear_spec(d, cfg.q_dim, "embed", "heads", bias=cfg.use_qkv_bias),
        "wk": linear_spec(kv_in, cfg.kv_dim, "embed", "kv_heads", bias=cfg.use_qkv_bias),
        "wv": linear_spec(kv_in, cfg.kv_dim, "embed", "kv_heads", bias=cfg.use_qkv_bias),
        "wo": linear_spec(cfg.q_dim, d, "heads", "embed"),
    }
    if cfg.qk_norm:
        spec["q_norm"] = rmsnorm_spec(cfg.head_dim)
        spec["k_norm"] = rmsnorm_spec(cfg.head_dim)
    return spec


# ---------------------------------------------------------------------------
# Masked-pair enumeration (static, trace-time)
# ---------------------------------------------------------------------------


def _visible_pairs(
    n_q: int, n_kv: int, cq: int, ck: int, causal: bool, window: int, q_start: int
):
    """Static list of (q_chunk, kv_chunk) pairs with any unmasked element.

    q positions of chunk i: [q_start + i*cq, q_start + (i+1)*cq).
    kv positions of chunk j: [j*ck, (j+1)*ck).
    """
    pairs = []
    for i in range(n_q):
        q_lo = q_start + i * cq
        q_hi = q_start + (i + 1) * cq - 1
        for j in range(n_kv):
            k_lo = j * ck
            k_hi = (j + 1) * ck - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window > 0 and k_hi < q_lo - window + 1:
                continue  # entirely outside the sliding window
            pairs.append((i, j))
    return pairs


def softcap(x, cap: float):
    if cap and cap > 0.0:
        return cap * jnp.tanh(x / cap)
    return x


# ---------------------------------------------------------------------------
# Chunked flash-style attention (train / prefill) with custom VJP
#
# Differentiating naively through the pair-scan would make JAX save every
# per-pair score/prob tensor — the full O(s²) attention matrix (measured:
# ~130 GB/device for gemma2 train_4k, EXPERIMENTS.md §Perf).  The custom
# VJP saves only (q, k, v, out, m, l) and recomputes each pair's scores in
# a second pair-scan — the flash-attention backward, and the exact
# semantics the Pallas kernel implements on TPU.
# ---------------------------------------------------------------------------


def _pair_mask(i, j, cq, ck, causal, window, q_start, skv):
    """Additive mask [cq, ck] (0 where visible, NEG_INF where masked).

    Kept as a small fp32 tile — a boolean mask broadcast to the full
    [b,h,cq,ck] score shape gets stacked across the whole pair-scan by
    XLA's hoisting (measured ~1.7 GB/device at train_4k; EXPERIMENTS.md
    §Perf)."""
    q_pos = q_start + i * cq + jnp.arange(cq)  # [cq]
    k_pos = j * ck + jnp.arange(ck)  # [ck]
    mask = jnp.ones((cq, ck), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask &= (k_pos < skv)[None, :]  # kv padding
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def _scores(qb, kb, scale, cap, addmask):
    s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                   preferred_element_type=jnp.float32)
    s_pre = s * scale
    s = softcap(s_pre, cap)
    s = s + addmask[None, None]
    return s, s_pre


def _flash_fwd_scan(q, k, v, pair_arr, meta):
    causal, window, cap, scale, q_start, cq, ck, skv = meta
    b, sq_p, h, hd = q.shape
    m0 = jnp.full((b, sq_p, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq_p, h), jnp.float32)
    a0 = jnp.zeros((b, sq_p, h, hd), jnp.float32)

    def step(carry, pair):
        m, l, acc = carry
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        addmask = _pair_mask(i, j, cq, ck, causal, window, q_start, skv)
        s, _ = _scores(qb, kb, scale, cap, addmask)
        mb = jax.lax.dynamic_slice_in_dim(m, i * cq, cq, axis=1)  # [b,cq,h]
        lb = jax.lax.dynamic_slice_in_dim(l, i * cq, cq, axis=1)
        ab = jax.lax.dynamic_slice_in_dim(acc, i * cq, cq, axis=1)
        s_max = jnp.max(s, axis=-1).transpose(0, 2, 1)  # [b,cq,h]
        m_new = jnp.maximum(mb, s_max)
        # rows that have seen no visible key yet keep p == 0 (guard against
        # exp(NEG_INF - NEG_INF) == 1 on fully-masked rows)
        row_ok = (m_new > NEG_INF / 2).transpose(0, 2, 1)[..., None]
        p = jnp.exp(s - m_new.transpose(0, 2, 1)[..., None])  # [b,h,cq,ck]
        p = p * row_ok
        alpha = jnp.exp(mb - m_new)
        l_new = lb * alpha + jnp.sum(p, axis=-1).transpose(0, 2, 1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        a_new = ab * alpha[..., None] + pv
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * cq, axis=1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * cq, axis=1)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * cq, axis=1)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pair_arr)
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    return out, m, l


def _flash_bwd_scan(q, k, v, out, m, l, do, pair_arr, meta):
    causal, window, cap, scale, q_start, cq, ck, skv = meta
    b, sq_p, h, hd = q.shape
    l_safe = jnp.maximum(l, 1e-30)
    # D_i = do_i · o_i  (rowsum of do*out)
    D = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    skv_p = k.shape[1]
    dq0 = jnp.zeros((b, sq_p, h, hd), jnp.float32)
    dk0 = jnp.zeros((b, skv_p, h, hd), jnp.float32)
    dv0 = jnp.zeros((b, skv_p, h, hd), jnp.float32)

    def step(carry, pair):
        dq, dk, dv = carry
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        dob = jax.lax.dynamic_slice_in_dim(do, i * cq, cq, axis=1)
        mb = jax.lax.dynamic_slice_in_dim(m, i * cq, cq, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(l_safe, i * cq, cq, axis=1)
        Db = jax.lax.dynamic_slice_in_dim(D, i * cq, cq, axis=1)  # [b,cq,h]
        addmask = _pair_mask(i, j, cq, ck, causal, window, q_start, skv)
        s, s_pre = _scores(qb, kb, scale, cap, addmask)
        row_ok = (mb > NEG_INF / 2).transpose(0, 2, 1)[..., None]
        p = jnp.exp(s - mb.transpose(0, 2, 1)[..., None]) * row_ok
        p = p / lb.transpose(0, 2, 1)[..., None]  # normalized probs
        dp = jnp.einsum("bqhd,bkhd->bhqk", dob.astype(jnp.float32),
                        vb.astype(jnp.float32))
        ds = p * (dp - Db.transpose(0, 2, 1)[..., None])
        if cap and cap > 0.0:
            t = jnp.tanh(s_pre / cap)
            ds = ds * (1.0 - t * t)
        ds = ds * scale
        dq_b = jnp.einsum("bhqk,bkhd->bqhd", ds, kb.astype(jnp.float32))
        dk_b = jnp.einsum("bhqk,bqhd->bkhd", ds, qb.astype(jnp.float32))
        dv_b = jnp.einsum("bhqk,bqhd->bkhd", p, dob.astype(jnp.float32))
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * cq, cq, axis=1) + dq_b,
            i * cq, axis=1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * ck, ck, axis=1) + dk_b,
            j * ck, axis=1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * ck, ck, axis=1) + dv_b,
            j * ck, axis=1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), pair_arr)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attention(q, k, v, meta, pairs):
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)
    out, _, _ = _flash_fwd_scan(q, k, v, pair_arr, meta)
    return out


def _flash_attention_fwd(q, k, v, meta, pairs):
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)
    out, m, l = _flash_fwd_scan(q, k, v, pair_arr, meta)
    return out, (q, k, v, out, m, l)


def _flash_attention_bwd(meta, pairs, res, do):
    q, k, v, out, m, l = res
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)
    return _flash_bwd_scan(q, k, v, out, m, l, do, pair_arr, meta)


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def chunked_attention(
    q,  # [b, sq, h, hd]
    k,  # [b, skv, kvh, hd]
    v,  # [b, skv, kvh, hd]
    *,
    causal: bool = True,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_start: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 512,
):
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv)
    pad_q = (-sq) % cq
    pad_k = (-skv) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_k
    n_q, n_kv = sq_p // cq, skv_p // ck

    pairs = tuple(_visible_pairs(n_q, n_kv, cq, ck, causal, window, q_start))

    # expand kv heads to full heads (bytes-only; keeps head-axis sharding)
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "heads", None))
    v = shard_act(v, ("batch", "seq", "heads", None))
    meta = (causal, window, attn_softcap, scale, q_start, cq, ck, skv)
    out = _flash_attention(q, k, v, meta, pairs)
    return shard_act(out[:, :sq], ("batch", "seq", "heads", None))


# ---------------------------------------------------------------------------
# Reference (materialized) attention — oracle for tests, small shapes only
# ---------------------------------------------------------------------------


def reference_attention(
    q, k, v, *, causal=True, window=0, attn_softcap=0.0, scale=None, q_start=0
):
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = softcap(s * scale, attn_softcap)
    q_pos = q_start + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (per-slot, per-head scales)
# ---------------------------------------------------------------------------


def quantize_kv(x):
    """x: [b, s, kvh, hd] -> (int8 values, f16 scales [b, s, kvh]).

    The scale is rounded to f16 BEFORE quantizing so the dequantization
    error is bounded by scale/2 exactly (hypothesis-tested invariant)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8).astype(jnp.float16)
    sf = scale.astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sf[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale):
    return q.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16)


def decode_attention_quant(
    q,  # [b, 1, h, hd]
    k_q, k_s, v_q, v_s,  # int8 caches + f16 scales
    positions,  # [b]
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale=None,
    block: int = 4096,
):
    """Chunked decode attention over an int8 cache.  The per-slot scales are
    folded into the score / probability vectors, so the int8 tensors are
    only ever dot operands (int8-capable MXU on TPU); each scan step
    dequantizes at most one [block] tile's worth of work."""
    b, _, h, hd = q.shape
    S = k_q.shape[1]
    kvh = k_q.shape[2]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    blk = min(block, S)
    assert S % blk == 0, (S, blk)
    nblk = S // blk

    qf = q[:, 0].astype(jnp.float32)  # [b, h, hd]
    pos = positions[:, None]  # [b, 1]

    def step(carry, j):
        m, l, acc = carry  # [b,h], [b,h], [b,h,hd]
        kb = jax.lax.dynamic_slice_in_dim(k_q, j * blk, blk, axis=1)
        ks = jax.lax.dynamic_slice_in_dim(k_s, j * blk, blk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_q, j * blk, blk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v_s, j * blk, blk, axis=1)
        if group > 1:
            kb = jnp.repeat(kb, group, axis=2)
            ks = jnp.repeat(ks, group, axis=2)
            vb = jnp.repeat(vb, group, axis=2)
            vs = jnp.repeat(vs, group, axis=2)
        # s = (q . k_i8) * k_scale  — exact (scale is per (b, slot, head))
        s = jnp.einsum("bhd,bkhd->bhk", qf, kb.astype(jnp.float32))
        s = s * ks.astype(jnp.float32).transpose(0, 2, 1)
        s = softcap(s * scale, attn_softcap)
        idx = j * blk + jnp.arange(blk)[None, :]  # [1, blk]
        if window > 0:
            p_slot = pos - jnp.mod(pos - idx, S)
            valid = (p_slot >= 0) & (p_slot >= pos - window + 1)
        else:
            valid = idx <= pos
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        row_ok = m_new > NEG_INF / 2
        p = jnp.exp(s - m_new[..., None]) * row_ok[..., None]
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        # pv = (p * v_scale) . v_i8 — exact
        pv = jnp.einsum(
            "bhk,bkhd->bhd",
            p * vs.astype(jnp.float32).transpose(0, 2, 1),
            vb.astype(jnp.float32),
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    a0 = jnp.zeros((b, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nblk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out[:, None].astype(q.dtype)


def cache_update_quant(cache, k_new, v_new, positions, window: int = 0):
    """Quantize one new (k, v) per request and scatter into the int8 cache."""
    S = cache["k"].shape[1]
    slots = jnp.mod(positions, S) if window > 0 else positions
    b = cache["k"].shape[0]
    bidx = jnp.arange(b)
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    return {
        "k": cache["k"].at[bidx, slots].set(kq[:, 0]),
        "k_scale": cache["k_scale"].at[bidx, slots].set(ks[:, 0]),
        "v": cache["v"].at[bidx, slots].set(vq[:, 0]),
        "v_scale": cache["v_scale"].at[bidx, slots].set(vs[:, 0]),
    }


# ---------------------------------------------------------------------------
# Decode attention against a KV cache
# ---------------------------------------------------------------------------


def decode_attention(
    q,  # [b, 1, h, hd]
    k_cache,  # [b, S, kvh, hd]   (S = full seq or ring-buffer window)
    v_cache,
    positions,  # [b] int32: index of the *current* token
    *,
    window: int = 0,  # >0 -> cache is a ring buffer of size S == window
    attn_softcap: float = 0.0,
    scale: Optional[float] = None,
):
    b, _, h, hd = q.shape
    S = k_cache.shape[1]
    kvh = k_cache.shape[2]
    group = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    if group > 1:
        k_cache = jnp.repeat(k_cache, group, axis=2)
        v_cache = jnp.repeat(v_cache, group, axis=2)

    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache, preferred_element_type=jnp.float32)
    s = softcap(s * scale, attn_softcap)

    idx = jnp.arange(S)[None, :]  # [1, S]
    pos = positions[:, None]  # [b, 1]
    if window > 0:
        # slot i holds absolute position p_i = pos - ((pos - i) mod S)
        p_slot = pos - jnp.mod(pos - idx, S)
        valid = (p_slot >= 0) & (p_slot >= pos - window + 1)
    else:
        valid = idx <= pos
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, positions, window: int = 0):
    """Scatter one new (k, v) per request into the cache.

    k_new/v_new: [b, 1, kvh, hd]; positions: [b] absolute token index.
    With ``window>0`` the cache is a ring buffer and the slot is pos % S.
    """
    S = k_cache.shape[1]
    slots = jnp.mod(positions, S) if window > 0 else positions
    b = k_cache.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slots].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, slots].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Cross-attention KV caching (VLM / encoder-decoder decode path)
# ---------------------------------------------------------------------------


def cross_kv(params, context, cfg: ModelConfig):
    """Precompute cross-attention K/V from the context stream (prefill)."""
    b, t, _ = context.shape
    k = dense(params["wk"], context).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = dense(params["wv"], context).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    return k, v


def cross_attention_cached(params, x, ck, cv, cfg: ModelConfig):
    """Decode-time cross-attention against precomputed K/V (all positions
    visible).  x: [b, s, d]; ck/cv: [b, t, kvh, hd]."""
    b, s, _ = x.shape
    q = dense(params["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
    t = ck.shape[1]
    pos = jnp.full((b,), t - 1, jnp.int32)  # all slots valid
    out = decode_attention(
        q, ck, cv, pos, window=0, attn_softcap=cfg.attn_softcap,
        scale=cfg.attn_logit_scale or None,
    )
    out = out.reshape(b, s, cfg.q_dim)
    return dense(params["wo"], out)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attention + output)
# ---------------------------------------------------------------------------


def attention_apply(
    params,
    x,  # [b, s, d]
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: int = 0,
    positions=None,  # [b, s] or None -> arange
    mode: str = "full",  # "full" | "decode"
    cache: Optional[dict] = None,  # {"k","v"} for decode / cache prefill
    context=None,  # [b, t, d_ctx] for cross-attention (disables rope on kv)
    use_rope: bool = True,
    use_pallas: bool = False,
):
    """Returns (out [b,s,d], new_cache or None)."""
    b, s, d = x.shape
    q = dense(params["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    kv_src = context if context is not None else x
    k = dense(params["wk"], kv_src).reshape(b, kv_src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = dense(params["wv"], kv_src).reshape(b, kv_src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    # pin activation shardings (GSPMD ambiguity under FSDP — sharding/ctx.py)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))

    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)

    scale = cfg.attn_logit_scale or None

    if context is not None:
        # cross-attention: no rope, no causal mask, no kv cache growth
        out = chunked_attention(
            q, k, v, causal=False, window=0, attn_softcap=cfg.attn_softcap,
            scale=scale, chunk_q=cfg.attn_chunk, chunk_kv=cfg.attn_chunk,
        )
        new_cache = None
    elif mode == "full":
        if positions is None:
            positions = jnp.arange(s)[None, :]
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if use_pallas:
            from repro.kernels.attention import ops as attn_ops

            out = attn_ops.flash_attention(
                q, k, v, causal=causal, window=window,
                attn_softcap=cfg.attn_softcap, scale=scale,
            )
        else:
            out = chunked_attention(
                q, k, v, causal=causal, window=window,
                attn_softcap=cfg.attn_softcap, scale=scale,
                chunk_q=cfg.attn_chunk, chunk_kv=cfg.attn_chunk,
            )
        new_cache = None
        if cache is not None:
            # prefill: write k/v into the cache buffers.  For a ring buffer
            # (S < s) position p lives in slot p % S, so the last S tokens
            # are written rolled by (s - S) % S.
            S = cache["k"].shape[1]
            quant = "k_scale" in cache
            srcs = {"k": k, "v": v}
            if quant:
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                srcs = {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
            new_cache = {}
            for name, src in srcs.items():
                if S >= s:
                    upd = jax.lax.dynamic_update_slice_in_dim(
                        cache[name], src.astype(cache[name].dtype), 0, axis=1
                    )
                else:
                    shift = (s - S) % S
                    upd = jnp.roll(src[:, -S:], shift, axis=1).astype(
                        cache[name].dtype)
                axes = ("batch", "kv_seq", "kv_heads", None)[: upd.ndim]
                new_cache[name] = shard_act(upd, axes)
    else:  # decode
        assert cache is not None and positions is not None
        pos = positions if positions.ndim == 1 else positions[:, 0]
        if use_rope:
            q = apply_rope(q, pos[:, None], cfg.rope_theta)
            k = apply_rope(k, pos[:, None], cfg.rope_theta)
        if "k_scale" in cache:  # int8 cache
            new_cache = cache_update_quant(cache, k, v, pos, window)
            new_cache = {
                n: shard_act(c, ("batch", "kv_seq", "kv_heads", None)[: c.ndim])
                for n, c in new_cache.items()
            }
            out = decode_attention_quant(
                q, new_cache["k"], new_cache["k_scale"],
                new_cache["v"], new_cache["v_scale"], pos, window=window,
                attn_softcap=cfg.attn_softcap, scale=scale,
            )
        else:
            kc, vc = cache_update(cache["k"], cache["v"], k, v, pos, window)
            kc = shard_act(kc, ("batch", "kv_seq", "kv_heads", None))
            vc = shard_act(vc, ("batch", "kv_seq", "kv_heads", None))
            out = decode_attention(
                q, kc, vc, pos, window=window, attn_softcap=cfg.attn_softcap,
                scale=scale,
            )
            new_cache = {"k": kc, "v": vc}

    out = shard_act(out, ("batch", "seq", "heads", None))
    out = out.reshape(b, s, cfg.q_dim)
    o = dense(params["wo"], out)
    o = shard_act(o, ("batch", "seq", "embed_act"))
    return o, new_cache
