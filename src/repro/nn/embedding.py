"""Token embedding and LM head (optionally tied), vocab-sharded.

The table is padded to ``cfg.padded_vocab`` (lane-aligned, divisible by the
model axis); logits are sliced back to the true vocab — the paper's
channel-padding trick ("pad C to the vector width") applied to the vocab.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.param import Param
from repro.nn.attention import softcap
from repro.sharding.ctx import shard_act


def embedding_spec(cfg: ModelConfig) -> dict:
    spec = {
        "tok": Param((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                     init="embed", scale=0.02)
    }
    if not cfg.tie_embeddings:
        spec["head"] = Param((cfg.d_model, cfg.padded_vocab),
                             ("embed", "vocab"), init="fan_in")
    return spec


def embed_tokens(params, tokens, cfg: ModelConfig, scale_by_dim: bool = False):
    x = params["tok"][tokens]
    if scale_by_dim:  # gemma convention
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if x.ndim == 3:
        x = shard_act(x, ("batch", "seq_res", "embed_act"))
    return x


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["tok"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"])
    if logits.ndim == 3:
        logits = shard_act(logits, ("batch", "seq", "vocab"))
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask (not slice) the padding — keeps the vocab axis evenly sharded
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits
