"""Mamba2 (SSD) block — chunked parallel scan + O(1)-state decode.

The chunked algorithm follows the SSD formulation (Dao & Gu 2024):
within a chunk the recurrence is computed in attention-like quadratic form;
across chunks a [heads, head_dim, d_state] state is carried by a short
``lax.scan``.  This is the temporal analogue of the paper's "advanced SIMD"
blocking: one loaded chunk of activations is reused for all intra-chunk
interactions before the state is written back (DESIGN.md §Arch-applicability).

``ssm_scan_reference`` is the naive per-timestep recurrence used as the
test oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.linear import linear_spec, dense
from repro.nn.norm import rmsnorm_spec, rmsnorm_apply
from repro.nn.param import Param
from repro.sharding.ctx import shard_act


def ssm_dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads


def ssm_spec(cfg: ModelConfig) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, h = ssm_dims(cfg)
    n = ssm.d_state
    # in_proj emits [z, x, B, C, dt]
    return {
        "in_proj": linear_spec(d, 2 * d_inner + 2 * n + h, "embed", "ssm_inner"),
        "conv_w": Param((ssm.d_conv, d_inner + 2 * n), (None, "ssm_inner"),
                        init="fan_in"),
        "conv_b": Param((d_inner + 2 * n,), ("ssm_inner",), init="zeros",
                        dtype="float32"),
        "A_log": Param((h,), (None,), init="zeros", dtype="float32"),
        "D": Param((h,), (None,), init="ones", dtype="float32"),
        "dt_bias": Param((h,), (None,), init="zeros", dtype="float32"),
        "out_norm": rmsnorm_spec(d_inner),
        "out_proj": linear_spec(d_inner, d, "ssm_inner", "embed"),
    }


def _split_proj(proj, cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner, h = ssm_dims(cfg)
    n = ssm.d_state
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] convolved together


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv over time.  xbc: [b, s, c]; w: [K, c].

    With ``state`` ([b, K-1, c], the trailing inputs of the previous call)
    performs the streaming update and returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [b, s+K-1, c]
    y = sum(xp[:, i : i + xbc.shape[1]] * w[i][None, None] for i in range(K))
    y = y + b.astype(y.dtype)
    new_state = xp[:, -(K - 1) :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """x: [b,s,h,p], dt: [b,s,h] (post-softplus), A: [h] (<0),
    B, C: [b,s,n].  Returns y [b,s,h,p] and final state [b,h,p,n].

    Chunks are processed *sequentially* by one lax.scan carrying the state,
    so peak memory is O(b·L²·h) for a single chunk, never O(b·nc·L²·h).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // L
    # scan-major layout: [nc, b, L, ...]
    xc = jnp.moveaxis(x.reshape(b, nc, L, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, L, h), 1, 0).astype(jnp.float32)
    Bc = jnp.moveaxis(B.reshape(b, nc, L, n), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, nc, L, n), 1, 0)

    li = jnp.arange(L)
    causal = li[:, None] >= li[None, :]

    def step(S, inp):
        x_c, dt_c, B_c, C_c = inp  # [b,L,h,p], [b,L,h], [b,L,n], [b,L,n]
        dA = dt_c * A[None, None, :]  # [b,L,h] (negative)
        cs = jnp.cumsum(dA, axis=1)  # inclusive cumulative log-decay
        scores = jnp.einsum("bln,bmn->blm", C_c, B_c,
                            preferred_element_type=jnp.float32)
        # decay from step m (exclusive) to step l (inclusive)
        M = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # [b,L,M,h]
        M = jnp.where(causal[None, :, :, None], M, 0.0)
        W = scores[..., None] * M * dt_c[:, None, :, :]  # [b,L,M,h]
        y = jnp.einsum("blmh,bmhp->blhp", W, x_c.astype(jnp.float32))
        # contribution of the state entering this chunk
        y = y + jnp.einsum(
            "bln,bhpn,blh->blhp", C_c.astype(jnp.float32), S, jnp.exp(cs)
        )
        # end-of-chunk state
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)  # [b,L,h]
        Sc = jnp.einsum(
            "bln,blh,blhp->bhpn",
            B_c.astype(jnp.float32),
            decay_to_end * dt_c,
            x_c.astype(jnp.float32),
        )
        S_new = S * jnp.exp(cs[:, -1, :])[:, :, None, None] + Sc
        return S_new, y.astype(x_c.dtype)

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    S_final, ys = jax.lax.scan(step, S0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s + pad, h, p)[:, :s]
    return y, S_final


def ssm_apply(
    params,
    x,  # [b, s, d]
    cfg: ModelConfig,
    *,
    mode: str = "full",  # "full" | "decode"
    cache: Optional[dict] = None,  # {"conv": [b,K-1,c], "state": [b,h,p,n]}
) -> Tuple[jnp.ndarray, Optional[dict]]:
    ssm = cfg.ssm
    d_inner, h = ssm_dims(cfg)
    n = ssm.d_state
    p = ssm.head_dim

    proj = dense(params["in_proj"], x)
    proj = shard_act(proj, ("batch", "seq", "ssm_inner"))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])  # [h], negative

    conv_state = cache.get("conv") if cache else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    bsz, s, _ = x.shape
    xh = xs.reshape(bsz, s, h, p)

    if mode == "full":
        y, S_final = _ssd_chunked(xh, dt, A, B, C, ssm.chunk_size)
        new_cache = (
            {"conv": new_conv, "state": S_final} if cache is not None else None
        )
    else:  # decode: s == 1
        S = cache["state"]  # [b,h,p,n]
        dA = jnp.exp(dt[:, 0] * A[None, :])  # [b,h]
        dBx = jnp.einsum(
            "bn,bh,bhp->bhpn", B[:, 0].astype(jnp.float32), dt[:, 0],
            xh[:, 0].astype(jnp.float32),
        )
        S = S * dA[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), S)
        y = y[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "state": S}

    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(params["out_norm"], y, cfg.norm_eps)
    return shard_act(dense(params["out_proj"], y),
                     ("batch", "seq", "embed_act")), new_cache


# ---------------------------------------------------------------------------
# Naive per-step recurrence — test oracle
# ---------------------------------------------------------------------------


def ssd_reference(x, dt, A, B, C):
    """Same inputs as _ssd_chunked; per-timestep lax.scan recurrence."""
    b, s, h, p = x.shape

    def step(S, inp):
        x_t, dt_t, B_t, C_t = inp  # [b,h,p], [b,h], [b,n], [b,n]
        dA = jnp.exp(dt_t * A[None, :])  # [b,h]
        S = S * dA[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", B_t, dt_t, x_t
        )
        y = jnp.einsum("bn,bhpn->bhp", C_t, S)
        return S, y

    S0 = jnp.zeros((b, h, p, B.shape[-1]), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    S_final, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_final
