"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain 2-matrix MLPs."""
from __future__ import annotations

from repro.core.config import ModelConfig
from repro.nn.linear import linear_spec, dense, act_fn
from repro.sharding.ctx import shard_act


def mlp_spec(cfg: ModelConfig, d_ff: int = 0) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_gated:
        return {
            "w_gate": linear_spec(d, f, "embed", "ff"),
            "w_up": linear_spec(d, f, "embed", "ff"),
            "w_down": linear_spec(f, d, "ff", "embed"),
        }
    return {
        "w_up": linear_spec(d, f, "embed", "ff", bias=True),
        "w_down": linear_spec(f, d, "ff", "embed", bias=True),
    }


def mlp_apply(params, x, cfg: ModelConfig, use_pallas: bool = False):
    if cfg.mlp_gated:
        g = dense(params["w_gate"], x, act=cfg.act, use_pallas=use_pallas)
        u = dense(params["w_up"], x, use_pallas=use_pallas)
        h = shard_act(g * u, ("batch", "seq", "ff"))
        return shard_act(dense(params["w_down"], h, use_pallas=use_pallas),
                         ("batch", "seq_res", "embed_act"))
    h = dense(params["w_up"], x, act=cfg.act, use_pallas=use_pallas)
    h = shard_act(h, ("batch", "seq", "ff"))
    return shard_act(dense(params["w_down"], h, use_pallas=use_pallas),
                     ("batch", "seq_res", "embed_act"))
