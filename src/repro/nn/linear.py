"""Linear / projection layers.

All projections keep the contraction dimension ("embed") unsharded and shard
the output feature dimension over the model axis (or vice versa for the
down-projection) — the standard Megatron 2-collective pattern that GSPMD
recovers from the parameter shardings.

The bias+activation epilogue here is the pure-jnp twin of the fused Pallas
matmul kernel in ``repro.kernels.matmul_fused`` (the paper's FC
acceleration); model code routes through :func:`dense` so the kernel can be
swapped in on TPU via ``use_pallas``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.nn.param import Param

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * (1.0 / (1.0 + jnp.exp(-x.astype(jnp.float32)))).astype(x.dtype),
    "gelu": lambda x: 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3))),
    "none": lambda x: x,
}


def act_fn(name: str):
    return _ACTS[name]


def linear_spec(
    d_in: int,
    d_out: int,
    in_axis: str = "embed",
    out_axis: str = "ff",
    bias: bool = False,
    init: str = "fan_in",
    scale: float = 1.0,
) -> dict:
    spec = {"w": Param((d_in, d_out), (in_axis, out_axis), init=init, scale=scale)}
    if bias:
        spec["b"] = Param((d_out,), (out_axis,), init="zeros", dtype="float32")
    return spec


def dense(params, x, act: str = "none", use_pallas: bool = False):
    """y = act(x @ w + b).  With ``use_pallas`` the fused TPU kernel is used
    (only valid on TPU backends; the jnp path is the oracle)."""
    if use_pallas:
        from repro.kernels.matmul_fused import ops as mm_ops

        return mm_ops.matmul_fused(
            x, params["w"], params.get("b"), act=act
        )
    y = jnp.einsum("...d,df->...f", x, params["w"])
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return _ACTS[act](y)
