"""Static analysis over the ExecutionPlan IR and the repo source.

Two passes, both pure Python (no kernel execution, no tracing):

* ``repro.analysis.verifier`` — the **plan verifier**: re-derives every
  compiled ``PlanStep``'s shape flow and Pallas band geometry from the
  same kernel resolvers the dispatch path runs, proves band coverage
  (output bands partition the frame, halo bands cover every row each
  window reads), and audits modelled VMEM working sets against the
  kernel budgets.  ``core.plan.compile_plan(verify=True)`` — the
  default — runs it on every compiled plan.
* ``repro.analysis.lint`` — the **repo lint**: AST rules enforcing the
  repo's kernel/engine invariants (``pallas_call`` kwargs threading,
  knob-mutation cache invalidation, resolver-owned ``Unblocked`` index
  maps, no silent excepts, no magic-number budgets).

See ``repro/analysis/README.md`` for the rule taxonomy and CLI usage
(``tools/lint.py``, ``tools/verify_sweep.py``).
"""
from repro.analysis.findings import (  # noqa: F401
    Finding,
    PlanVerificationError,
    RULES,
    findings_json,
    findings_markdown,
)
