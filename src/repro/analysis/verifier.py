"""Static plan verifier: prove an ``ExecutionPlan``'s geometry on paper.

``verify_plan(plan)`` walks the compiled ``PlanStep``s and checks, with
no kernel execution:

* **shape flow** (V1xx) — every step's output shape re-derives from its
  input shape + layer spec, consecutive steps chain, and conv/fc
  parameter geometry matches ``infer_param_shapes``,
* **band coverage** (V2xx) — for every banded step (SIMD conv, fused
  conv+pool, chain, Pallas pool) the geometry is re-resolved through
  the SAME kernel resolvers the dispatch path runs
  (``resolve_oh_block`` / ``resolve_ph_block`` / ``resolve_chain_block``
  via ``fusion.group_band_params``) and the per-cell interval lists
  (``kernels.band_intervals``) are proven to cover: output bands
  partition ``[0, OH)`` exactly once, every input halo band stays at or
  below the pre-padded frame origin and contains every row its output
  band's windows read, ragged last bands are equalized (the PR 3
  over-fetch regression, statically),
* **VMEM budget** (V3xx) — the modelled working set of the resolved
  cell AND of the one-final-row floor cell are audited against the
  budget the planner admitted with.  Severity is ``error`` only where
  the bust would bind: the Pallas path with auto band resolution; an
  explicit ``oh_block`` override downgrades to ``warning`` (the user
  asked for it) and the XLA path to ``info`` (no VMEM ceiling).

``compile_plan(verify=True)`` — the default — raises
``PlanVerificationError`` on any error finding, so every engine
construction and ``deploy.load_model`` self-checks before a batch
arrives.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.findings import (  # noqa: F401  (re-exported for
    Finding,                           # compile_plan's deferred import)
    PlanVerificationError,
)
from repro.core.fusion import (
    IM2COL_METHODS,
    _ADVANCED_OC_BLOCK,
    _conv_out_hw,
    _pool_out_hw,
    group_band_params,
)
from repro.core.methods import Method
from repro.core.netdefs import NetworkDef
from repro.core.plan import ExecutionPlan, PlanStep, infer_param_shapes

#: methods that band their output rows on the Pallas path (seq_ref and
#: basic_parallel run whole frames per grid cell — nothing to cover)
_BANDED_METHODS = frozenset({
    Method.BASIC_SIMD, Method.ADVANCED_SIMD_4, Method.ADVANCED_SIMD_8,
})


def check_band_coverage(geo: dict, step: str, *,
                        equalized: bool = True) -> List[Finding]:
    """Pure coverage checker over one resolved band geometry (the dict
    shape of ``fusion.group_band_params``).  Everything here is
    arithmetic over the interval lists — the unit the mutation tests
    drive directly with hand-built geometries."""
    from repro.kernels.conv2d import kernels as K

    blk, n_tiles, total = geo["blk"], geo["n_tiles"], geo["total"]
    # the sliding-window carry cell re-uses ``carry`` input rows from
    # VMEM scratch each band step, so the FRESH fetch of logical band t
    # starts ``carry`` rows below the classic halo band (the carried
    # rows were fetched — and convolved — by the previous physical step;
    # step 0 is the sacrificial seed that fills the scratch)
    carry = geo.get("carry", 0)
    out_iv, in_iv = K.band_intervals(n_tiles, blk, total, geo["row_step"],
                                     geo["band"],
                                     base=geo["in_base"] + carry)
    findings: List[Finding] = []
    # V201: output bands partition [0, total) exactly once
    pos = 0
    contiguous = True
    for start, rows in out_iv:
        if start != pos or rows < 0:
            contiguous = False
            break
        pos = start + rows
    if not contiguous or pos != total:
        findings.append(Finding(
            "error", step, "V201",
            f"output bands {out_iv} do not partition [0, {total}) "
            f"(gap/overlap or wrong coverage)"))
    # V205: the scalars must agree with the effective-conv model (the
    # carry cell's fresh band is the classic band minus the carried rows,
    # and its sacrificial seed adds one physical grid step)
    want_band = (blk - 1) * geo["stride_eff"] + geo["window_eff"] - carry
    want_step = blk * geo["stride_eff"]
    want_phys = n_tiles + (1 if carry else 0)
    if (geo["band"] != want_band or geo["row_step"] != want_step
            or geo.get("steps", n_tiles) != want_phys):
        findings.append(Finding(
            "error", step, "V205",
            f"band={geo['band']} row_step={geo['row_step']} "
            f"steps={geo.get('steps', n_tiles)} inconsistent with "
            f"blk={blk} stride_eff={geo['stride_eff']} "
            f"window_eff={geo['window_eff']} carry={carry} "
            f"(want band={want_band}, row_step={want_step}, "
            f"steps={want_phys})"))
    # V202: no halo band may start above the pre-padded frame origin
    for t, (start, _rows) in enumerate(in_iv):
        if start < geo["in_base"]:
            findings.append(Finding(
                "error", step, "V202",
                f"band {t} input start {start} is above the pre-padded "
                f"frame origin {geo['in_base']}"))
    # V203: each halo band must contain every row its output windows read
    # — in carry mode the first ``carry`` rows of the demand are served
    # from scratch (band t-1's tail; band 0's from the seed step), so the
    # fresh fetch must start EXACTLY ``carry`` rows into the demand: any
    # other offset consumes stale or misaligned scratch rows
    for t, ((o0, o_rows), (i0, i_rows)) in enumerate(zip(out_iv, in_iv)):
        if o_rows <= 0:
            continue
        need_lo = geo["in_base"] + o0 * geo["stride_eff"]
        need_hi = (geo["in_base"] + (o0 + o_rows - 1) * geo["stride_eff"]
                   + geo["window_eff"])
        if carry:
            if i0 - need_lo != carry or i0 + i_rows < need_hi:
                findings.append(Finding(
                    "error", step, "V203",
                    f"band {t} stages fresh input rows [{i0}, "
                    f"{i0 + i_rows}) + {carry} carried rows but its "
                    f"output rows [{o0}, {o0 + o_rows}) read "
                    f"[{need_lo}, {need_hi}) — carry misalignment or "
                    f"under-fetch"))
        elif i0 > need_lo or i0 + i_rows < need_hi:
            findings.append(Finding(
                "error", step, "V203",
                f"band {t} stages input rows [{i0}, {i0 + i_rows}) but its "
                f"output rows [{o0}, {o0 + o_rows}) read "
                f"[{need_lo}, {need_hi}) — under-fetch"))
    # V204: ragged last band must be equalized to its fair share
    if equalized and blk != -(-total // n_tiles):
        findings.append(Finding(
            "error", step, "V204",
            f"blk={blk} over {n_tiles} bands of {total} rows is not "
            f"equalized (fair share {-(-total // n_tiles)}): the ragged "
            f"last band fetches mostly-pad input rows"))
    return findings


def step_band_params(plan: ExecutionPlan,
                     step: PlanStep) -> Tuple[Optional[dict], bool]:
    """The resolved band geometry of one step (``None`` for steps that
    do not band) and whether its resolver equalizes the ragged band.
    Fused/chain steps read ``fusion.group_band_params``; unfused SIMD
    convs and Pallas pools re-derive the same fields from the kernel
    resolvers their dispatch path runs."""
    from repro.kernels.conv2d import kernels as K
    from repro.kernels.conv2d.ops import SUBLANES

    if step.kind in ("fused", "chain"):
        kw = step.kwargs or {}
        return (group_band_params(step.group, step.method, step.in_shape,
                                  step.oh_block,
                                  pool_carry=kw.get("pool_carry"),
                                  lrn_oc_block=kw.get("lrn_oc_block")),
                True)
    if step.kind == "conv" and step.method in _BANDED_METHODS:
        spec = step.spec
        c, h, w = step.in_shape
        kh, kw = spec.kernel
        sy = spec.stride[0]
        oh, ow = _conv_out_hw(h, w, spec)
        cp = -(-c // SUBLANES) * SUBLANES
        wp = w + 2 * spec.padding[1]
        im2col = step.method in IM2COL_METHODS
        ocb = (min(_ADVANCED_OC_BLOCK[step.method], spec.out_channels)
               if im2col else spec.out_channels)
        blk = K.resolve_oh_block(oh, ow, wp, cp, kh, kw, sy, ocb,
                                 step.oh_block, im2col=im2col)
        return ({
            "kind": "conv", "blk": blk, "n_tiles": -(-oh // blk),
            "total": oh, "band": K._band_rows(blk, kh, sy),
            "row_step": blk * sy, "in_base": 0, "stride_eff": sy,
            "carry": 0, "steps": -(-oh // blk),
            "window_eff": kh, "padded_h": h + 2 * spec.padding[0],
            "cell_bytes": K.conv_cell_bytes(blk, ow, wp, cp, kh, kw, sy,
                                            ocb, im2col=im2col),
            "floor_bytes": K.conv_cell_bytes(1, ow, wp, cp, kh, kw, sy,
                                             ocb, im2col=im2col),
            "budget": K.VMEM_BUDGET_BYTES, "out_hw": [oh, ow],
        }, False)
    if step.kind == "pool" and plan.use_pallas:
        from repro.kernels.pool2d.kernels import auto_oh_block_pool

        spec = step.spec
        c, h, w = step.in_shape
        kh, _kw = spec.kernel
        sy = spec.stride[0]
        oh, ow = _pool_out_hw(h, w, spec)
        cp = -(-c // SUBLANES) * SUBLANES
        blk = auto_oh_block_pool(oh, ow, w, cp, kh, sy)
        blk = max(1, min(blk, oh))
        return ({
            "kind": "pool", "blk": blk, "n_tiles": -(-oh // blk),
            "total": oh, "band": K._band_rows(blk, kh, sy),
            "row_step": blk * sy, "in_base": 0, "stride_eff": sy,
            "carry": 0, "steps": -(-oh // blk),
            "window_eff": kh, "padded_h": h,  # VALID pooling: no pad
            "cell_bytes": K.conv_cell_bytes(blk, ow, w, cp, kh, _kw, sy, 0,
                                            im2col=False),
            "floor_bytes": K.conv_cell_bytes(1, ow, w, cp, kh, _kw, sy, 0,
                                             im2col=False),
            "budget": K.VMEM_BUDGET_BYTES, "out_hw": [oh, ow],
        }, False)
    return None, False


def _derived_out_shape(step: PlanStep) -> Optional[Tuple[int, ...]]:
    cur = tuple(step.in_shape)
    if step.kind == "conv":
        _, h, w = cur
        h, w = _conv_out_hw(h, w, step.spec)
        return (step.spec.out_channels, h, w)
    if step.kind in ("fused", "chain"):
        _, h, w = cur
        for cv in step.group.convs:
            h, w = _conv_out_hw(h, w, cv)
        if step.group.pool is not None:
            h, w = _pool_out_hw(h, w, step.group.pool)
        return (step.group.convs[-1].out_channels, h, w)
    if step.kind == "pool":
        c, h, w = cur
        h, w = _pool_out_hw(h, w, step.spec)
        return (c, h, w)
    if step.kind == "flatten":
        return ((int(cur[0] * cur[1] * cur[2]),) if len(cur) == 3 else cur)
    if step.kind == "fc":
        return (step.spec.out_channels,)
    if step.kind in ("lrn", "relu", "softmax"):
        return cur
    return None


def _shape_findings(step: PlanStep, label: str, cur: Tuple[int, ...],
                    shapes: dict) -> List[Finding]:
    findings: List[Finding] = []
    if tuple(step.in_shape) != tuple(cur):
        findings.append(Finding(
            "error", label, "V102",
            f"step input shape {tuple(step.in_shape)} != upstream "
            f"activation {tuple(cur)}"))
    want = _derived_out_shape(step)
    if want is not None:
        if any(d < 1 for d in want):
            findings.append(Finding(
                "error", label, "V101",
                f"derived output shape {want} has a non-positive dim "
                f"(kernel/pool larger than its input)"))
        elif tuple(step.out_shape) != want:
            findings.append(Finding(
                "error", label, "V101",
                f"step output shape {tuple(step.out_shape)} != derived "
                f"{want}"))
    # parameter geometry vs infer_param_shapes
    if step.kind == "conv":
        kh, kw = step.spec.kernel
        want_w = (step.spec.out_channels, step.in_shape[0], kh, kw)
        if shapes.get(step.spec.name) != want_w:
            findings.append(Finding(
                "error", label, "V103",
                f"conv {step.spec.name} weight {shapes.get(step.spec.name)} "
                f"!= step-derived {want_w}"))
    elif step.kind in ("fused", "chain"):
        c = step.in_shape[0]
        for cv in step.group.convs:
            kh, kw = cv.kernel
            want_w = (cv.out_channels, c, kh, kw)
            if shapes.get(cv.name) != want_w:
                findings.append(Finding(
                    "error", label, "V103",
                    f"conv {cv.name} weight {shapes.get(cv.name)} != "
                    f"step-derived {want_w}"))
            c = cv.out_channels
    elif step.kind == "fc":
        d_in = (int(step.in_shape[0] * step.in_shape[1] * step.in_shape[2])
                if len(step.in_shape) == 3 else int(step.in_shape[0]))
        want_w = (d_in, step.spec.out_channels)
        if step.d_in != d_in or shapes.get(step.spec.name) != want_w:
            findings.append(Finding(
                "error", label, "V103",
                f"fc {step.spec.name}: weight {shapes.get(step.spec.name)} "
                f"/ step d_in {step.d_in} != step-derived {want_w}"))
    return findings


def _budget_findings(geo: dict, label: str, plan: ExecutionPlan,
                     explicit_block: bool) -> List[Finding]:
    # the planner admits fused/chain groups against the compile-time
    # vmem_budget override; unfused conv/pool cells always auto-fit to
    # the kernel-module constants, so the override does not apply there
    budget = geo["budget"]
    if plan.vmem_budget is not None and geo["kind"] in ("fused", "chain"):
        budget = plan.vmem_budget
    if not plan.use_pallas:
        sev = "info"   # the XLA analogue has no VMEM ceiling
    elif explicit_block:
        sev = "warning"  # the user pinned the band; respect but flag
    else:
        sev = "error"  # auto resolution must always fit
    findings: List[Finding] = []
    rule = "V302" if geo["kind"] == "chain" else "V301"
    if geo["cell_bytes"] > budget:
        findings.append(Finding(
            sev, label, rule,
            f"resolved cell (blk={geo['blk']}) models "
            f"{geo['cell_bytes']} B > budget {budget} B"))
    if geo["floor_bytes"] > budget:
        findings.append(Finding(
            sev, label, "V303",
            f"one-final-row floor cell models {geo['floor_bytes']} B > "
            f"budget {budget} B — the planner should not have admitted "
            f"this step"))
    return findings


def verify_plan(plan: ExecutionPlan, net: Optional[NetworkDef] = None,
                input_shape: Optional[Tuple[int, int, int]] = None,
                ) -> List[Finding]:
    """All findings for ``plan``, most severe first.  ``net`` /
    ``input_shape`` default to the plan's own — pass them to check a
    plan against an independently-trusted definition (deploy does)."""
    net = net if net is not None else plan.net
    cur: Tuple[int, ...] = tuple(input_shape if input_shape is not None
                                 else net.input_shape)
    shapes = infer_param_shapes(net)
    findings: List[Finding] = []
    for idx, step in enumerate(plan.steps):
        label = f"step{idx}:{'+'.join(step.names)}"
        findings += _shape_findings(step, label, cur, shapes)
        geo, equalized = step_band_params(plan, step)
        if geo is not None:
            findings += check_band_coverage(geo, label, equalized=equalized)
            findings += _budget_findings(geo, label, plan,
                                         step.oh_block is not None)
        cur = tuple(step.out_shape)
    # headless nets (tests, feature extractors) end wherever they end; a
    # classifier tail must land exactly on the class distribution
    if (plan.steps and plan.steps[-1].kind in ("fc", "softmax")
            and tuple(cur) != (net.num_classes,)):
        findings.append(Finding(
            "warning", "plan", "V102",
            f"final activation {tuple(cur)} != (num_classes="
            f"{net.num_classes},)"))
    order = {"error": 0, "warning": 1, "info": 2}
    findings.sort(key=lambda f: order[f.severity])
    return findings
