"""Repo lint: AST rules enforcing the kernel/engine invariants.

Rules (see ``findings.RULES`` / ``analysis/README.md``):

* **R001** — every ``pl.pallas_call`` threads ``interpret=`` (so the
  CPU/interpret test path exists for every kernel) and
  ``compiler_params=`` (dimension semantics are part of the kernel's
  contract, never left to the default).
* **R002** — the engine's knob machinery cannot regress into the PR 5
  stale-plan bug: ``_KnobDict`` mutators must reach ``_on_change``
  (directly or by delegating to a checked mutator), class-level
  ``name = _knob("name")`` descriptors must name the attribute they
  wrap, and ``clear_caches`` must clear every cache dict ``__init__``
  creates.
* **R003** — ``pl.BlockSpec(..., indexing_mode=pl.Unblocked())`` index
  maps may only scale grid indices by *named* offsets (``row_step``,
  ``in_step``, …) that come from the geometry resolvers; inline numeric
  arithmetic (any literal other than a standalone ``0``) hides band
  math the verifier cannot see.
* **R004** — no silent handlers: a bare/broad ``except`` whose body is
  only ``pass``/``...`` swallows planner and IO failures.
* **R005** — byte budgets appear in comparisons only through the named
  kernel constants, never as magic numbers (≥ 1 MiB literals).
* **R006** — serving-path supervision cannot swallow errors: every
  ``except`` handler in a ``serving/`` module must re-raise, reference
  its bound exception (``except X as e`` + use of ``e`` — recording the
  failure), or name a typed failure result (``FailedResult`` /
  ``ShedResult`` / the engine-fault types).  A handler that does none
  of these turns a supervisor error into a silent drop.
* **R007** — kernel-body ``astype`` discipline: inside ``kernels/``
  functions that take ``*_ref`` parameters (Pallas kernel bodies),
  every ``.astype(...)`` must target the named accumulation constant
  ``ACC_DTYPE`` or a ref's ``.dtype``; inline dtype literals fork the
  fp32-accumulate / single-downcast contract the kernel sanitizer
  proves (K103).

All rules are file-local AST walks — no imports of the linted modules,
so the linter runs on any tree (including deliberately-broken test
snippets).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional

from repro.analysis.findings import Finding

#: _KnobDict methods that mutate the mapping (must invalidate)
KNOB_DICT_MUTATORS = frozenset({
    "__setitem__", "__delitem__", "__ior__", "update", "setdefault",
    "pop", "popitem", "clear",
})

#: caches clear_caches must drop (matched against __init__-created dicts)
_CACHE_HINTS = ("plan", "jit", "bucket", "cache")

_MAGIC_BUDGET_MIN = 1 << 20  # 1 MiB: anything this big is a byte budget


def _call_name(node: ast.Call) -> str:
    """Dotted tail of a call target: ``pl.pallas_call`` -> ``pallas_call``."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _loc(path: str, node: ast.AST) -> str:
    return f"{path}:{getattr(node, 'lineno', 0)}"


# -- R001 -------------------------------------------------------------------

def _r001(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) == "pallas_call":
            kws = {kw.arg for kw in node.keywords}
            missing = sorted({"interpret", "compiler_params"} - kws)
            if missing:
                out.append(Finding(
                    "error", _loc(path, node), "R001",
                    f"pallas_call missing keyword(s): {', '.join(missing)}"))
    return out


# -- R002 -------------------------------------------------------------------

def _calls_in(node: ast.AST) -> Iterable[ast.Call]:
    return (n for n in ast.walk(node) if isinstance(n, ast.Call))


def _r002_knob_dict(cls: ast.ClassDef, path: str) -> List[Finding]:
    out = []
    for item in cls.body:
        if not isinstance(item, ast.FunctionDef):
            continue
        if item.name not in KNOB_DICT_MUTATORS or item.name == "__init__":
            continue
        ok = False
        for call in _calls_in(item):
            f = call.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "self"
                    and (f.attr == "_on_change"
                         or f.attr in KNOB_DICT_MUTATORS)):
                ok = True
                break
        if not ok:
            out.append(Finding(
                "error", _loc(path, item), "R002",
                f"_KnobDict.{item.name} mutates without reaching "
                f"_on_change (stale-plan bug class)"))
    return out


def _r002_knob_names(cls: ast.ClassDef, path: str) -> List[Finding]:
    out = []
    for item in cls.body:
        if not (isinstance(item, ast.Assign) and len(item.targets) == 1
                and isinstance(item.targets[0], ast.Name)
                and isinstance(item.value, ast.Call)
                and _call_name(item.value) in ("_knob", "_dict_knob")):
            continue
        args = item.value.args
        if (len(args) != 1 or not isinstance(args[0], ast.Constant)
                or args[0].value != item.targets[0].id):
            out.append(Finding(
                "error", _loc(path, item), "R002",
                f"knob descriptor {item.targets[0].id} must wrap the "
                f"attribute of the same name"))
    return out


def _r002_clear_caches(cls: ast.ClassDef, path: str) -> List[Finding]:
    init = next((f for f in cls.body if isinstance(f, ast.FunctionDef)
                 and f.name == "__init__"), None)
    clear = next((f for f in cls.body if isinstance(f, ast.FunctionDef)
                  and f.name == "clear_caches"), None)
    if init is None or clear is None:
        return []
    caches = set()
    for node in ast.walk(init):
        tgt = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            tgt, val = node.target, node.value
        else:
            continue
        if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self" and isinstance(val, ast.Dict)
                and any(h in tgt.attr.lower() for h in _CACHE_HINTS)):
            caches.add(tgt.attr)
    touched = {n.attr for n in ast.walk(clear)
               if isinstance(n, ast.Attribute)
               and isinstance(n.value, ast.Name) and n.value.id == "self"}
    out = []
    for name in sorted(caches - touched):
        out.append(Finding(
            "error", _loc(path, clear), "R002",
            f"clear_caches does not clear self.{name} created in __init__"))
    return out


def _r002(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if node.name == "_KnobDict":
            out += _r002_knob_dict(node, path)
        out += _r002_knob_names(node, path)
        out += _r002_clear_caches(node, path)
    return out


# -- R003 -------------------------------------------------------------------

def _has_unblocked(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "indexing_mode":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Attribute) and n.attr == "Unblocked":
                    return True
                if isinstance(n, ast.Name) and n.id == "Unblocked":
                    return True
    return False


def _r003(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _call_name(node) == "BlockSpec"
                and _has_unblocked(node)):
            continue
        lam = next((a for a in node.args if isinstance(a, ast.Lambda)), None)
        if lam is None:
            lam = next((kw.value for kw in node.keywords
                        if kw.arg == "index_map"
                        and isinstance(kw.value, ast.Lambda)), None)
        if lam is None:
            continue
        for n in ast.walk(lam.body):
            if (isinstance(n, ast.Constant)
                    and isinstance(n.value, (int, float))
                    and n.value != 0):
                out.append(Finding(
                    "error", _loc(path, node), "R003",
                    f"Unblocked index map uses inline literal {n.value!r}; "
                    f"offsets must come from a geometry resolver name"))
                break
    return out


# -- R004 -------------------------------------------------------------------

def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = []
    for n in ast.walk(t):
        if isinstance(n, ast.Attribute):
            names.append(n.attr)
        elif isinstance(n, ast.Name):
            names.append(n.id)
    return any(n in ("Exception", "BaseException") for n in names)


def _r004(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        body_is_silent = all(
            isinstance(s, ast.Pass)
            or (isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant)
                and s.value.value is Ellipsis)
            for s in node.body)
        if body_is_silent and _is_broad(node):
            out.append(Finding(
                "error", _loc(path, node), "R004",
                "silent broad except: narrow the exception and handle (or "
                "at least record) the failure"))
    return out


# -- R005 -------------------------------------------------------------------

_FOLD_OPS = {ast.Add: lambda a, b: a + b, ast.Sub: lambda a, b: a - b,
             ast.Mult: lambda a, b: a * b, ast.LShift: lambda a, b: a << b,
             ast.Pow: lambda a, b: a ** b}


def _const_int(node: ast.AST) -> Optional[int]:
    """Fold a constants-only arithmetic expression (``8 * 1024 * 1024``,
    ``14 << 20``) to its int value; None if any leaf is a name."""
    if (isinstance(node, ast.Constant) and isinstance(node.value, int)
            and not isinstance(node.value, bool)):
        return node.value
    if isinstance(node, ast.BinOp) and type(node.op) in _FOLD_OPS:
        left, right = _const_int(node.left), _const_int(node.right)
        if left is not None and right is not None:
            return _FOLD_OPS[type(node.op)](left, right)
    return None


def _r005(tree: ast.AST, path: str) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        for side in [node.left, *node.comparators]:
            value = _const_int(side)
            if value is not None and value >= _MAGIC_BUDGET_MIN:
                out.append(Finding(
                    "warning", _loc(path, node), "R005",
                    f"magic byte budget {value} in a comparison — use "
                    f"the named kernel budget constants"))
    return out


# -- R006 -------------------------------------------------------------------

#: typed failure results / fault types whose mention in a handler counts
#: as recording the error (the serving failure taxonomy)
R006_TYPED_NAMES = frozenset({
    "FailedResult", "ShedResult", "EngineFault", "TransientEngineFault",
    "PersistentEngineFault", "ServerWedgedError", "NonFiniteInputError",
})


def _r006(tree: ast.AST, path: str) -> List[Finding]:
    """serving/ except handlers must re-raise or record a typed failure
    (no swallowed supervisor errors).  File-scoped: the rule only binds
    on modules under a ``serving/`` directory."""
    if "serving/" not in path.replace("\\", "/"):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        body_nodes = [n for stmt in node.body for n in ast.walk(stmt)]
        if any(isinstance(n, ast.Raise) for n in body_nodes):
            continue
        names = {n.id for n in body_nodes if isinstance(n, ast.Name)}
        names |= {n.attr for n in body_nodes if isinstance(n, ast.Attribute)}
        if node.name and node.name in names:
            continue  # the bound exception is used: the error is recorded
        if names & R006_TYPED_NAMES:
            continue  # a typed failure result is produced
        out.append(Finding(
            "error", _loc(path, node), "R006",
            "serving/ except handler neither re-raises, uses its bound "
            "exception, nor records a typed failure result — the "
            "supervisor error is swallowed"))
    return out


# -- R007 -------------------------------------------------------------------


def _is_kernel_fn(fn: ast.FunctionDef) -> bool:
    """A Pallas kernel body: any positional parameter named ``*_ref``."""
    args = fn.args
    params = args.posonlyargs + args.args + args.kwonlyargs
    return any(a.arg.endswith("_ref") for a in params)


def _r007(tree: ast.AST, path: str) -> List[Finding]:
    """kernels/ astype discipline: inside a kernel body every
    ``.astype(ARG)`` must target the named accumulation constant
    (``ACC_DTYPE``) or a ref's ``.dtype`` — an inline dtype literal
    (``jnp.float32``, ``"bfloat16"``) silently forks the accumulate /
    downcast contract the sanitizer proves (K103)."""
    if "kernels/" not in path.replace("\\", "/"):
        return []
    out, seen = [], set()
    for fn in ast.walk(tree):
        if not (isinstance(fn, ast.FunctionDef) and _is_kernel_fn(fn)):
            continue
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Name) and arg.id == "ACC_DTYPE":
                continue
            if isinstance(arg, ast.Attribute) and arg.attr == "dtype":
                continue
            key = (node.lineno, node.col_offset)
            if key in seen:  # nested kernel fns walk the same call twice
                continue
            seen.add(key)
            out.append(Finding(
                "error", _loc(path, node), "R007",
                "kernel-body astype must target ACC_DTYPE or a ref's "
                ".dtype — inline dtype arguments break the fp32 "
                "accumulate/single-downcast contract"))
    return out


_RULES = (_r001, _r002, _r003, _r004, _r005, _r006, _r007)


def lint_source(src: str, path: str = "<string>",
                rules: Optional[Iterable] = None) -> List[Finding]:
    """Lint one source string (the unit the seeded-snippet tests use)."""
    tree = ast.parse(src)
    out: List[Finding] = []
    for rule in (rules or _RULES):
        out += rule(tree, path)
    return out


def lint_file(path: Path, root: Optional[Path] = None) -> List[Finding]:
    rel = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(), rel)


def lint_tree(root: Path) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (sorted, deterministic)."""
    root = Path(root)
    out: List[Finding] = []
    for path in sorted(root.rglob("*.py")):
        out += lint_file(path, root.parent)
    return out
