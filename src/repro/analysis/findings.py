"""Structured findings shared by the plan verifier and the repo lint.

A ``Finding`` is one rule violation: ``severity`` (``error`` — the
configuration is wrong and must not run; ``warning`` — explicitly
requested but suspect; ``info`` — advisory, e.g. a budget note on the
XLA path which has no VMEM ceiling), the ``step`` it anchors to (a plan
step label for V-rules, ``path:line`` for R-rules), the ``rule`` ID,
and a human-readable ``detail``.

``RULES`` is the canonical taxonomy — every emitted finding's ``rule``
must be a key here (enforced by the findings tests), and the README is
generated from the same table.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Iterable, List, Sequence

SEVERITIES = ("error", "warning", "info")

#: rule ID -> (pass, one-line summary).  V1xx: shape/dtype flow.
#: V2xx: band geometry / coverage.  V3xx: VMEM budget audit.
#: R0xx: repo lint (AST).  K1xx: kernel sanitizer (abstract interpretation).
RULES = {
    "V101": ("verifier",
             "step output shape disagrees with its re-derivation from the "
             "step's input shape and layer spec"),
    "V102": ("verifier",
             "activation shapes do not chain: a step's input shape is not "
             "the previous step's output shape (or the plan input)"),
    "V103": ("verifier",
             "conv/fc parameter geometry disagrees with infer_param_shapes "
             "(wrong in-channels, kernel, or fc fan-in)"),
    "V201": ("verifier",
             "output bands do not partition [0, OH) exactly once "
             "(gap or overlap between grid cells)"),
    "V202": ("verifier",
             "an input halo band starts above the pre-padded frame origin"),
    "V203": ("verifier",
             "an input halo band misses rows its output band's windows "
             "read (under-fetch / off-by-one halo)"),
    "V204": ("verifier",
             "ragged last band not equalized to its fair share — the cell "
             "fetches a full band of pad rows (the PR 3 over-fetch class)"),
    "V205": ("verifier",
             "band scalars inconsistent: band != (blk-1)*stride + window "
             "or row_step != blk*stride"),
    "V301": ("verifier",
             "resolved cell working set exceeds the VMEM budget"),
    "V302": ("verifier",
             "chain cell live set exceeds the chain VMEM budget"),
    "V303": ("verifier",
             "even the one-final-row floor cell exceeds the budget — the "
             "fusion planner should never have admitted this group"),
    "R001": ("lint",
             "pl.pallas_call must thread interpret= and compiler_params="),
    "R002": ("lint",
             "engine knob mutation paths must invalidate the plan/jit/"
             "bucket caches (knob name mismatch, _KnobDict mutator not "
             "calling _on_change, or clear_caches missing a cache)"),
    "R003": ("lint",
             "pl.Unblocked index maps must use resolver-named offsets — "
             "no inline numeric arithmetic (literal 0 excepted)"),
    "R004": ("lint",
             "silent exception handler: bare/broad except whose body is "
             "only pass"),
    "R005": ("lint",
             "magic-number byte budget in a comparison — use the named "
             "kernel budget constants"),
    "R006": ("lint",
             "serving/ except handler swallows a supervisor error: it "
             "must re-raise, reference its bound exception, or record a "
             "typed failure result (FailedResult/ShedResult/...)"),
    "R007": ("lint",
             "kernel-body astype must target the named accumulation-dtype "
             "constant (ACC_DTYPE) or a ref's .dtype — no inline dtype "
             "literals inside kernels/"),
    "K100": ("sanitizer",
             "the sanitizer could not complete its proof for a dispatch "
             "(unsupported construct, entry raised, or internal "
             "inconsistency) — the dispatch is unproven, not proven safe"),
    "K101": ("sanitizer",
             "a kernel load (x_ref/w_ref block, slice, or pl.ds) can read "
             "outside the padded operand extents for some grid index"),
    "K102": ("sanitizer",
             "the union of o_ref stores does not cover every output "
             "element exactly once across the grid (gap, overlap, or an "
             "unguarded overwrite on an accumulation axis)"),
    "K103": ("sanitizer",
             "precision flow violates the fp32-accumulate contract: "
             "accumulation not in fp32, or not exactly one downcast at "
             "the final o_ref store"),
    "K104": ("sanitizer",
             "intermediate-padding rows in a chain cell are not provably "
             "zero before the next stage consumes them (missing or "
             "mismatched row mask)"),
    "K105": ("sanitizer",
             "the sanitizer's independently derived band geometry "
             "disagrees with the resolver/verifier derivation — one of "
             "the two redundant derivations is wrong"),
    "K106": ("sanitizer",
             "VMEM scratch carry discipline violated: the carried grid "
             "axis is not 'arbitrary', the scratch ref is overwritten "
             "before its carried rows are consumed, or the store is not "
             "the tail row-slice of the fresh band (stale rows would be "
             "re-consumed by the next band step)"),
}


@dataclass(frozen=True)
class Finding:
    severity: str
    step: str
    rule: str
    detail: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")
        if self.rule not in RULES:
            raise ValueError(f"unknown rule {self.rule!r}")

    def __str__(self) -> str:
        return f"[{self.rule}:{self.severity}] {self.step}: {self.detail}"


class PlanVerificationError(ValueError):
    """Raised by ``compile_plan(verify=True)`` on error-severity findings.

    Subclasses ``ValueError`` so load/validation call sites that already
    guard deployment artifacts with ``except ValueError`` (checksum,
    dtype) treat geometry corruption the same way.  The structured
    findings stay available on ``.findings``.
    """

    def __init__(self, findings: Sequence[Finding]):
        self.findings = list(findings)
        detail = "; ".join(str(f) for f in self.findings)
        super().__init__(
            f"plan verification failed with {len(self.findings)} "
            f"error finding(s): {detail}")


def findings_json(findings: Iterable[Finding]) -> str:
    return json.dumps([asdict(f) for f in findings], indent=2)


def findings_markdown(findings: Iterable[Finding],
                      title: str = "Findings") -> str:
    """A GitHub-flavored markdown table (piped into CI step summaries)."""
    rows: List[Finding] = list(findings)
    out = [f"### {title}", ""]
    if not rows:
        out.append("No findings.")
        return "\n".join(out) + "\n"
    out += ["| severity | rule | where | detail |",
            "| --- | --- | --- | --- |"]
    for f in rows:
        detail = f.detail.replace("|", "\\|")
        out.append(f"| {f.severity} | {f.rule} | `{f.step}` | {detail} |")
    return "\n".join(out) + "\n"
