"""Kernel sanitizer: abstract-interpretation proofs for Pallas dispatches.

The plan verifier (``repro.analysis.verifier``) proves band coverage by
evaluating the SAME resolver functions the kernels call — a bug in
``resolve_oh_block`` or ``chain_band_geometry`` fools both sides at
once.  This module closes that loop-hole with an N-version check that
shares NOTHING with the trusted code:

* **Phase A** re-derives every piece of band geometry from scratch —
  output sizes, halo bands, VMEM cell-byte models, the auto-block
  candidate walks, band equalization, and the backward chain halo
  composition — as fresh arithmetic written against the paper's tiling
  contract, not against the kernel sources.
* **Phase B** symbolically executes the actual kernel **source text**
  (parsed with ``ast``, never imported): the entry function runs
  concretely for one dispatch config, except that every call into a
  trusted resolver is intercepted and answered by Phase A; the kernel
  *body* then runs with grid indices as affine symbols over
  ``[0, grid_dim)`` and block offsets as affine expressions, proving:

  K101  every ``x_ref``/``w_ref`` load (block, slice, ``pl.ds``) stays
        inside the padded operand extents for ALL grid indices,
  K102  the union of ``o_ref`` stores covers every output element
        exactly once (no gaps, overlaps, ragged tails, or unguarded
        overwrites on accumulation axes),
  K103  accumulation happens in fp32 with exactly one downcast at the
        final ``o_ref`` store,
  K104  masked intermediate-padding rows in chain cells are provably
        zero before the next stage consumes them.

Anything the interpreter cannot prove — an unsupported construct, an
entry that raises, an internal inconsistency — degrades to a K100
finding, never to a silent pass.

This module imports ONLY the stdlib and the findings taxonomy.  It must
never import ``repro.core.fusion``, ``repro.analysis.verifier`` or the
kernel modules themselves (asserted by the tests): the whole point is
that its numbers come from a second, independent derivation.  The
cross-check between the two derivations is K105, performed by
``tools/sanitize.py``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Any, Dict, List, Tuple

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# Phase A — fresh re-derivation of the band geometry (no shared code)
# ---------------------------------------------------------------------------

# The kernels target half of the ~16 MB/core VMEM for streamed cells and
# near-full capacity for chain cells (weights are grid-invariant).  Both
# constants are re-stated here on purpose: if the kernel side drifts,
# the K105 cross-check must see the disagreement.
_A_VMEM_BUDGET = 8 << 20
_A_CHAIN_BUDGET = 14 << 20
_A_BLOCK_CANDIDATES = (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _a_out(size: int, k: int, stride: int, pad: int) -> int:
    """Convolution output extent for SAME-style symmetric padding."""
    return (size + 2 * pad - k) // stride + 1


def _a_band(blk: int, k: int, stride: int) -> int:
    """Input rows a ``blk``-row output band reads, halo included."""
    return (blk - 1) * stride + k


def _a_equalize(blk: int, target: int) -> Tuple[int, int]:
    """Clamp, then re-snap a band to ``ceil(target / n_tiles)`` so the
    ragged last band shrinks to its fair share."""
    blk = max(1, min(blk, target))
    n_tiles = _ceil_div(target, blk)
    blk = _ceil_div(target, n_tiles)
    return blk, _ceil_div(target, blk)


def _a_intervals(n_tiles, blk, total, row_step, band, base=0):
    """Per-cell (start, rows) output/input intervals of a banded grid."""
    out_iv = [(t * blk, max(0, min(blk, total - t * blk)))
              for t in range(n_tiles)]
    in_iv = [(base + t * row_step, band) for t in range(n_tiles)]
    return out_iv, in_iv


def _a_conv_cell(ohb, ow, wp, c, kh, kw, sy, ocb, im2col=True, itemsize=4):
    patch_c = kh * kw * c if im2col else c
    return (_a_band(ohb, kh, sy) * wp * c + ohb * ow * patch_c
            + kh * kw * c * ocb + ohb * ow * ocb) * itemsize


def _a_auto_oh(oh, ow, wp, c, kh, kw, sy, oc_block,
               budget=_A_VMEM_BUDGET, itemsize=4, im2col=True):
    for ohb in [oh] + [b for b in _A_BLOCK_CANDIDATES if b < oh]:
        if _a_conv_cell(ohb, ow, wp, c, kh, kw, sy, oc_block,
                        im2col=im2col, itemsize=itemsize) <= budget:
            return ohb
    return 1


def _a_resolve_oh(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                  im2col=True):
    if oh_block is None:
        return _a_auto_oh(oh, ow, wp, c, kh, kw, sy, oc_block,
                          im2col=im2col)
    return max(1, min(oh_block, oh))


def _a_fused_cell(phb, ow, wp, c, kh, kw, sy, ocb, pool,
                  im2col=True, itemsize=4, oc_halo=0):
    pkh, pkw, psy, psx = pool
    pw = (ow - pkw) // psx + 1
    cband = _a_band(phb, pkh, psy)
    band = _a_band(cband, kh, sy)
    patch_c = kh * kw * c if im2col else c
    ocw = ocb + oc_halo
    return (band * wp * c + cband * ow * patch_c + kh * kw * c * ocw
            + cband * ow * ocw + phb * pw * ocw) * itemsize


def _a_auto_ph(ph, ow, wp, c, kh, kw, sy, oc_block, pool,
               budget=_A_VMEM_BUDGET, im2col=True, oc_halo=0):
    for phb in [ph] + [b for b in _A_BLOCK_CANDIDATES if b < ph]:
        if _a_fused_cell(phb, ow, wp, c, kh, kw, sy, oc_block, pool,
                         im2col=im2col, oc_halo=oc_halo) <= budget:
            return phb
    return 1


def _a_resolve_ph(ph, oh, ow, wp, c, kh, kw, sy, oc_block, pool, oh_block,
                  im2col=True, oc_halo=0):
    pkh, _, psy, _ = pool
    if oh_block is None:
        phb = _a_auto_ph(ph, ow, wp, c, kh, kw, sy, oc_block, pool,
                         im2col=im2col, oc_halo=oc_halo)
    else:
        ohb = max(1, min(oh_block, oh))
        phb = max(1, (ohb - pkh) // psy + 1) if ohb >= pkh else 1
    return _a_equalize(phb, ph)


def _a_resolve_lrn_ocb(oc, oc_block, lrn, lrn_oc_block, ow, wp, c, kh, kw,
                       sy, pool, im2col=True):
    """Phase-A re-derivation of the two-pass channel-halo split: the
    ``(ocb, oc_halo)`` a fused conv→pool→LRN dispatch runs with.  Auto
    keeps the classic full-width tile whenever the one-pooled-row floor
    cell fits the (re-stated) budget; otherwise the oc tile shrinks and
    every weight tile is widened by the LRN window's n-1 neighbours."""
    if lrn is None or not im2col:
        return (min(oc_block, oc) if im2col else oc), 0
    blocked = min(oc_block, oc)
    if blocked >= oc or lrn_oc_block is False:
        return oc, 0
    if lrn_oc_block is None and _a_fused_cell(
            1, ow, wp, c, kh, kw, sy, oc, pool) <= _A_VMEM_BUDGET:
        return oc, 0
    return blocked, lrn[0] - 1


def _a_resolve_pool_carry(pool_carry, im2col, lrn, pool, phb, n_tiles):
    """Phase-A re-derivation of the sliding-window carry gate: adjacent
    bands share ``K = pkh - psy`` conv rows, carried in VMEM scratch
    when overlap exists (K >= 1), fits one band's fresh rows
    (K <= phb*psy), and there is more than one band."""
    if pool is None or lrn is not None or not im2col \
            or pool_carry is False:
        return False
    k_rows = pool[0] - pool[2]
    return 1 <= k_rows <= phb * pool[2] and n_tiles > 1


def _a_chain_dims(h, w, c, chain, ocs):
    dims = []
    for (kh, kw, sy, sx, py, px), oc in zip(chain, ocs):
        oh, ow = _a_out(h, kh, sy, py), _a_out(w, kw, sx, px)
        dims.append((oh, ow, c, oc))
        h, w, c = oh, ow, oc
    return dims


def _a_chain_geom(blk, chain, pool):
    """Backward halo composition: rows/offsets every stage materializes
    for one cell of ``blk`` final (pooled) rows."""
    s = len(chain)
    m = [0] * s
    offs = [(0, 0)] * s
    if pool is not None:
        pkh, _, psy, _ = pool
        m[-1] = _a_band(blk, pkh, psy)
        offs[-1] = (blk * psy, 0)
    else:
        m[-1] = blk
        offs[-1] = (blk, 0)
    for i in range(s - 1, 0, -1):
        kh, _, sy, _, py, _ = chain[i]
        a, b = offs[i]
        m[i - 1] = _a_band(m[i], kh, sy)
        offs[i - 1] = (a * sy, b * sy - py)
    kh0, _, sy0, _, _, _ = chain[0]
    band = _a_band(m[0], kh0, sy0)
    a0, b0 = offs[0]
    return m, offs, band, a0 * sy0, b0 * sy0


def _a_chain_cell(blk, h, w, c, chain, ocs, pool, im2col=True, itemsize=4,
                  oc_block_final=None):
    dims = _a_chain_dims(h, w, c, chain, ocs)
    m, _, band, _, _ = _a_chain_geom(blk, chain, pool)
    last = len(chain) - 1
    weights = 0
    stage_peak = 0
    in_rows, in_w = band, w + 2 * chain[0][5]
    for i, ((kh, kw, sy, sx, py, px), (oh, ow, ci, oc)) in enumerate(
            zip(chain, dims)):
        if i == last and oc_block_final is not None:
            oc = min(oc_block_final, oc)
        weights += kh * kw * ci * oc
        patch_c = kh * kw * ci if im2col else ci
        stage_peak = max(stage_peak, in_rows * in_w * ci
                         + m[i] * ow * patch_c + m[i] * ow * oc)
        if i + 1 < len(chain):
            in_rows, in_w = m[i], ow + 2 * chain[i + 1][5]
    oh_f, ow_f, _, oc_f = dims[-1]
    if oc_block_final is not None:
        oc_f = min(oc_block_final, oc_f)
    if pool is not None:
        pkh, pkw, psy, psx = pool
        out_stream = blk * ((ow_f - pkw) // psx + 1) * oc_f
    else:
        out_stream = blk * ow_f * oc_f
    in_stream = band * (w + 2 * chain[0][5]) * c
    return (weights + stage_peak + in_stream + out_stream) * itemsize


def _a_auto_chain(target, h, w, c, chain, ocs, pool, budget=None,
                  im2col=True, oc_block_final=None):
    budget = _A_CHAIN_BUDGET if budget is None else budget
    for blk in [target] + [b for b in _A_BLOCK_CANDIDATES if b < target]:
        if _a_chain_cell(blk, h, w, c, chain, ocs, pool, im2col=im2col,
                         oc_block_final=oc_block_final) <= budget:
            return blk
    return 1


def _a_resolve_chain(h, w, c, chain, ocs, pool, oh_block, im2col=True,
                     budget=None, oc_block_final=None):
    dims = _a_chain_dims(h, w, c, chain, ocs)
    oh_f, ow_f = dims[-1][0], dims[-1][1]
    if pool is not None:
        pkh, pkw, psy, psx = pool
        target = (oh_f - pkh) // psy + 1
        if target < 1 or (ow_f - pkw) // psx + 1 < 1:
            raise KernelRaise(f"pool window ({pkh},{pkw}) larger than "
                              f"final conv output ({oh_f},{ow_f})")
    else:
        target = oh_f
    if oh_block is None:
        blk = _a_auto_chain(target, h, w, c, chain, ocs, pool,
                            budget=budget, im2col=im2col,
                            oc_block_final=oc_block_final)
    elif pool is not None:
        ohb = max(1, min(oh_block, oh_f))
        blk = max(1, (ohb - pkh) // psy + 1) if ohb >= pkh else 1
    else:
        blk = oh_block
    return _a_equalize(blk, target)


def _a_auto_oh_pool(oh, ow, wp, c, kh, sy, budget=_A_VMEM_BUDGET,
                    itemsize=4):
    """Pool tiler: the conv candidate walk with weight/oc terms zeroed."""
    return _a_auto_oh(oh, ow, wp, c, kh, 1, sy, 0, budget=budget,
                      itemsize=itemsize, im2col=False)


# ---------------------------------------------------------------------------
# Phase B — the abstract domain
# ---------------------------------------------------------------------------


class Unsupported(Exception):
    """The interpreter met a construct outside its proven subset."""


class KernelRaise(Exception):
    """The interpreted entry raised (ValueError / failed assert)."""


class Aff:
    """Affine integer expression over grid symbols: sum(c_i * g_i) + k."""

    __slots__ = ("coeffs", "const")

    def __init__(self, coeffs=None, const=0):
        self.coeffs = {s: c for s, c in (coeffs or {}).items() if c != 0}
        self.const = const

    @staticmethod
    def lift(v):
        if isinstance(v, Aff):
            return v
        if isinstance(v, bool) or not isinstance(v, int):
            raise Unsupported(f"non-integer in affine arithmetic: {v!r}")
        return Aff({}, v)

    def as_int(self):
        return self.const if not self.coeffs else None

    def __add__(self, other):
        other = Aff.lift(other)
        coeffs = dict(self.coeffs)
        for s, c in other.coeffs.items():
            coeffs[s] = coeffs.get(s, 0) + c
        return Aff(coeffs, self.const + other.const)

    __radd__ = __add__

    def __sub__(self, other):
        other = Aff.lift(other)
        return self + Aff({s: -c for s, c in other.coeffs.items()},
                          -other.const)

    def __rsub__(self, other):
        return Aff.lift(other) - self

    def __mul__(self, other):
        if isinstance(other, Aff):
            if other.coeffs and self.coeffs:
                raise Unsupported("non-affine product of grid symbols")
            if other.coeffs:
                return other * self.const
            other = other.const
        if not isinstance(other, int) or isinstance(other, bool):
            raise Unsupported(f"affine * {other!r}")
        return Aff({s: c * other for s, c in self.coeffs.items()},
                   self.const * other)

    __rmul__ = __mul__

    def bounds(self, sym_ranges):
        """(min, max) over every symbol's range [0, dim)."""
        lo = hi = self.const
        for s, c in self.coeffs.items():
            dim = sym_ranges[s]
            ext = c * (dim - 1)
            lo += min(0, ext)
            hi += max(0, ext)
        return lo, hi

    def __eq__(self, other):  # used by == in interpreted kernel code
        if isinstance(other, Aff):
            same = (self.coeffs == other.coeffs
                    and self.const == other.const)
            if same:
                return True
            other_i = other.as_int()
            if other_i is None:
                raise Unsupported("affine == affine comparison")
            other = other_i
        if isinstance(other, int):
            return Pred(self, other)
        return NotImplemented

    def __hash__(self):
        return hash((tuple(sorted(self.coeffs.items())), self.const))

    def __repr__(self):
        terms = [f"{c}*g{s}" for s, c in sorted(self.coeffs.items())]
        terms.append(str(self.const))
        return " + ".join(terms)


class Pred:
    """``affine == value`` guard predicate (the ``pl.when`` condition)."""

    __slots__ = ("aff", "value")

    def __init__(self, aff: Aff, value: int):
        self.aff = aff
        self.value = value

    def sym_eq(self):
        """As ``(sym, value)`` when the form is ``1*g_s + 0 == value``."""
        if len(self.aff.coeffs) == 1 and self.aff.const == 0:
            (s, c), = self.aff.coeffs.items()
            if c == 1:
                return s, self.value
        raise Unsupported(f"guard predicate not sym==const: {self.aff!r}")

    def __repr__(self):
        return f"({self.aff!r} == {self.value})"


class IotaV:
    """``broadcasted_iota`` along one axis (the chain row index)."""

    __slots__ = ("shape", "axis")

    def __init__(self, shape, axis):
        self.shape = shape
        self.axis = axis


class RowExpr:
    """``affine + iota``: the global row index of each band row."""

    __slots__ = ("aff", "iota")

    def __init__(self, aff, iota):
        self.aff = aff
        self.iota = iota

    def compare(self, op, value):
        if not isinstance(value, int):
            raise Unsupported(f"row compare against {value!r}")
        return RowPred(self, op, value)


class RowPred:
    """One half of a row-range predicate: ``rows >= v`` / ``rows < v``."""

    __slots__ = ("expr", "op", "value")

    def __init__(self, expr, op, value):
        self.expr = expr
        self.op = op
        self.value = value

    def __and__(self, other):
        if isinstance(other, RowPred):
            return RowRange(self, other)
        return NotImplemented


class RowRange:
    """``(rows >= lo) & (rows < hi)`` — a provable row mask."""

    __slots__ = ("lo_pred", "hi_pred")

    def __init__(self, a, b):
        if a.op == "ge" and b.op == "lt":
            self.lo_pred, self.hi_pred = a, b
        elif a.op == "lt" and b.op == "ge":
            self.lo_pred, self.hi_pred = b, a
        else:
            raise Unsupported("row mask is not a [lo, hi) range")
        if self.lo_pred.expr is not self.hi_pred.expr:
            raise Unsupported("row mask bounds test different row exprs")

    def key(self):
        """(coeffs, const, lo, hi) canonical mask identity."""
        aff = self.lo_pred.expr.aff
        return (tuple(sorted(aff.coeffs.items())), aff.const,
                self.lo_pred.value, self.hi_pred.value)


class DtypeMarker:
    """A concrete dtype literal (``jnp.float32`` / ``ACC_DTYPE`` / ...)."""

    __slots__ = ("tag",)

    def __init__(self, tag):
        self.tag = tag


class DtypeOf:
    """``some_ref.dtype`` / ``some_array.dtype`` — a deferred dtype."""

    __slots__ = ("tag", "of_out")

    def __init__(self, tag, of_out):
        self.tag = tag
        self.of_out = of_out


_DT_ORDER = ("weak", "bool", "i32", "f32", "io", "f64")


def _dt_join(a: str, b: str) -> str:
    return a if _DT_ORDER.index(a) >= _DT_ORDER.index(b) else b


def _broadcast(sa, sb):
    out = []
    for da, db in zip(((1,) * (len(sb) - len(sa)) + tuple(sa)),
                      ((1,) * (len(sa) - len(sb)) + tuple(sb))):
        if da == db or db == 1:
            out.append(da)
        elif da == 1:
            out.append(db)
        else:
            raise Unsupported(f"broadcast mismatch {sa} vs {sb}")
    return tuple(out)


class AArray:
    """Abstract array: concrete shape + precision-flow metadata.

    ``dt``         the dtype lattice tag ('io' = the dispatch I/O dtype),
    ``downcasts``  how many astype-to-a-ref-dtype casts the value passed,
    ``tainted``    arithmetic happened AFTER a downcast,
    ``from_out``   the value derives from an ``o_ref`` read (RMW),
    ``mask``       canonical row-mask key when the value is provably
                   zero outside an affine row range (chain K104),
    ``row_slice``  ``(start, stop, dim)`` when the value is exactly a
                   contiguous axis-0 row slice of another array (set only
                   by ``lax.slice_in_dim(axis=0)``; any other op clears
                   it) — the carry-discipline proof (K106) uses it to
                   show a scratch store keeps the band's TAIL rows.
    """

    __slots__ = ("shape", "dt", "downcasts", "tainted", "from_out", "mask",
                 "row_slice")

    def __init__(self, shape, dt="io", downcasts=0, tainted=False,
                 from_out=False, mask=None, row_slice=None):
        self.shape = tuple(shape)
        self.dt = dt
        self.downcasts = downcasts
        self.tainted = tainted
        self.from_out = from_out
        self.mask = mask
        self.row_slice = row_slice

    @property
    def ndim(self):
        return len(self.shape)

    def like(self, shape=None, dt=None, mask=None, downcasts=None,
             tainted=None, from_out=None):
        return AArray(self.shape if shape is None else shape,
                      self.dt if dt is None else dt,
                      self.downcasts if downcasts is None else downcasts,
                      self.tainted if tainted is None else tainted,
                      self.from_out if from_out is None else from_out,
                      mask)


def _arr_binop(a, b, interp):
    """Join two operands of an elementwise op into one AArray."""
    arrs = [v for v in (a, b) if isinstance(v, AArray)]
    shape = arrs[0].shape
    for v in arrs[1:]:
        shape = _broadcast(shape, v.shape)
    dt = "weak"
    downcasts = 0
    tainted = from_out = False
    for v in arrs:
        dt = _dt_join(dt, v.dt)
        downcasts = max(downcasts, v.downcasts)
        tainted = tainted or v.tainted
        from_out = from_out or v.from_out
    tainted = tainted or downcasts > 0
    if dt == "f64":
        interp.finding("K103", "arithmetic in float64 inside a kernel "
                               "body — accumulation must stay fp32")
    return AArray(shape, dt, downcasts, tainted, from_out)


class Ref:
    """A VMEM block ref bound to one kernel parameter.

    ``is_scratch`` marks a ``scratch_shapes`` VMEM ref: readable AND
    writable, persistent across grid steps on an 'arbitrary' axis — its
    load/store events feed the K106 carry-discipline proof instead of
    the K102 output-coverage lattice."""

    __slots__ = ("name", "shape", "dt", "is_out", "is_scratch")

    def __init__(self, name, shape, dt, is_out, is_scratch=False):
        self.name = name
        self.shape = tuple(shape)
        self.dt = dt
        self.is_out = is_out
        self.is_scratch = is_scratch

    @property
    def ndim(self):
        return len(self.shape)


class DS:
    """``pl.ds(start, size)`` — a (possibly affine) dynamic slice."""

    __slots__ = ("start", "size")

    def __init__(self, start, size):
        self.start = start
        self.size = size


class Closure:
    __slots__ = ("node", "env", "name", "module")

    def __init__(self, node, env, name, module):
        self.node = node
        self.env = env
        self.name = name
        self.module = module


class PyFn:
    """A Phase-A interception: answers a trusted-resolver call."""

    __slots__ = ("fn", "name")

    def __init__(self, fn, name):
        self.fn = fn
        self.name = name


class PartialV:
    __slots__ = ("fn", "args", "kwargs")

    def __init__(self, fn, args, kwargs):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs


class BlockSpecV:
    __slots__ = ("block_shape", "index_map", "unblocked")

    def __init__(self, block_shape, index_map, unblocked):
        self.block_shape = block_shape
        self.index_map = index_map
        self.unblocked = unblocked


class ShapeDtypeV:
    __slots__ = ("shape", "dt")

    def __init__(self, shape, dt):
        self.shape = shape
        self.dt = dt


class CompilerParamsV:
    __slots__ = ("dimension_semantics",)

    def __init__(self, dimension_semantics):
        self.dimension_semantics = dimension_semantics


class PlWhenV:
    """``pl.when(pred)`` decorator: runs the body under a guard."""

    __slots__ = ("pred",)

    def __init__(self, pred):
        self.pred = pred


class PallasV:
    """The configured ``pl.pallas_call(...)`` awaiting its operands."""

    __slots__ = ("kernel", "grid", "in_specs", "out_specs", "out_shape",
                 "dimension_semantics", "scratch_shapes")

    def __init__(self, kernel, grid, in_specs, out_specs, out_shape,
                 dimension_semantics, scratch_shapes=None):
        self.kernel = kernel
        self.grid = grid
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.out_shape = out_shape
        self.dimension_semantics = dimension_semantics
        self.scratch_shapes = list(scratch_shapes or [])


class ModuleHandle:
    """``jnp`` / ``jax`` / ``pl`` / ... — attribute access namespaces."""

    __slots__ = ("name",)

    def __init__(self, name):
        self.name = name


class Store:
    """One recorded ``o_ref`` store event."""

    __slots__ = ("guards", "value", "full_block", "line")

    def __init__(self, guards, value, full_block, line):
        self.guards = tuple(guards)
        self.value = value
        self.full_block = full_block
        self.line = line


class _Return(Exception):
    def __init__(self, value):
        self.value = value


class BoundMethod:
    __slots__ = ("obj", "name")

    def __init__(self, obj, name):
        self.obj = obj
        self.name = name


class _ModFn:
    """A function reached through a module handle (``jnp.pad`` ...)."""

    __slots__ = ("path",)

    def __init__(self, path):
        self.path = path


_UNBLOCKED = object()

_BUILTINS = {"range": range, "len": len, "min": min, "max": max,
             "enumerate": enumerate, "zip": zip, "tuple": tuple,
             "list": list, "int": int, "float": float, "abs": abs,
             "sum": sum}


class Env:
    __slots__ = ("vars", "parent")

    def __init__(self, vars=None, parent=None):
        self.vars = vars if vars is not None else {}
        self.parent = parent

    def get(self, name):
        env = self
        while env is not None:
            if name in env.vars:
                return env.vars[name]
            env = env.parent
        if name in _BUILTINS:
            return _BUILTINS[name]
        raise Unsupported(f"unresolved name {name!r}")

    def set(self, name, value):
        self.vars[name] = value


def _tag_of(dtype_arg):
    """Dtype tag of a dtype-position argument."""
    if isinstance(dtype_arg, DtypeMarker):
        return dtype_arg.tag
    if isinstance(dtype_arg, DtypeOf):
        return dtype_arg.tag
    raise Unsupported(f"unrecognized dtype argument {dtype_arg!r}")


class Interp:
    """Concrete-plus-affine AST interpreter for kernel source modules."""

    def __init__(self, modules, label, findings):
        self.modules = modules          # module name -> Env
        self.label = label
        self.findings = findings
        self.sym_ranges: Dict[int, int] = {}
        self.guards: List[Pred] = []
        self.stores: List[Store] = []
        self.scratch_events: List[Tuple] = []
        self.band_conv_masks: List[Any] = []
        self.line = 0

    # -- findings ----------------------------------------------------------

    def finding(self, rule, detail, severity="error"):
        f = Finding(severity, f"{self.label}:L{self.line}", rule, detail)
        if f not in self.findings:
            self.findings.append(f)

    # -- calls -------------------------------------------------------------

    def call(self, fn, args, kwargs):
        if isinstance(fn, PyFn):
            return fn.fn(*args, **kwargs)
        if isinstance(fn, PartialV):
            merged_kw = dict(fn.kwargs)
            merged_kw.update(kwargs)
            return self.call(fn.fn, list(fn.args) + list(args), merged_kw)
        if isinstance(fn, PlWhenV):
            (closure,) = args
            self.guards.append(fn.pred)
            try:
                self.call(closure, [], {})
            finally:
                self.guards.pop()
            return None
        if isinstance(fn, Closure):
            return self.call_closure(fn, args, kwargs)
        if isinstance(fn, _ModFn):
            return self.call_modfn(fn, args, kwargs)
        if isinstance(fn, PallasV):
            return self.analyze_dispatch(fn, args)
        if isinstance(fn, BoundMethod):
            return self.call_method(fn, args, kwargs)
        if callable(fn) and not isinstance(fn, (AArray, Ref, Aff)):
            return fn(*args, **kwargs)
        raise Unsupported(f"call of non-callable {fn!r}")

    def call_closure(self, clos, args, kwargs):
        if clos.name == "_band_conv" and args:
            x = args[0]
            self.band_conv_masks.append(
                x.mask if isinstance(x, AArray) else None)
        node = clos.node
        a = node.args
        if a.posonlyargs:
            raise Unsupported("positional-only parameters")
        env = Env(parent=clos.env)
        names = [p.arg for p in a.args]
        defaults = a.defaults
        n_required = len(names) - len(defaults)
        pos = list(args)
        kw = dict(kwargs)
        for i, name in enumerate(names):
            if pos:
                env.set(name, pos.pop(0))
            elif name in kw:
                env.set(name, kw.pop(name))
            elif i >= n_required:
                env.set(name,
                        self.eval(defaults[i - n_required], clos.env))
            else:
                raise Unsupported(
                    f"missing argument {name!r} calling {clos.name}")
        if a.vararg is not None:
            env.set(a.vararg.arg, tuple(pos))
            pos = []
        if pos:
            raise Unsupported(f"too many arguments calling {clos.name}")
        for p, dflt in zip(a.kwonlyargs, a.kw_defaults):
            if p.arg in kw:
                env.set(p.arg, kw.pop(p.arg))
            elif dflt is not None:
                env.set(p.arg, self.eval(dflt, clos.env))
            else:
                raise Unsupported(
                    f"missing keyword argument {p.arg!r} in {clos.name}")
        if kw:
            raise Unsupported(
                f"unexpected keyword(s) {sorted(kw)} calling {clos.name}")
        if isinstance(node, ast.Lambda):
            return self.eval(node.body, env)
        try:
            self.exec_block(node.body, env, clos.module)
        except _Return as r:
            return r.value
        return None

    def call_method(self, bm, args, kwargs):
        obj, name = bm.obj, bm.name
        if isinstance(obj, list) and name == "append":
            obj.append(args[0])
            return None
        if isinstance(obj, AArray) and name == "astype":
            (target,) = args
            if isinstance(target, DtypeOf):
                return obj.like(dt=target.tag,
                                downcasts=obj.downcasts + 1)
            tag = _tag_of(target)
            if tag == "f64":
                self.finding("K103", "astype to float64 inside a kernel "
                                     "body — accumulation must stay fp32")
            return obj.like(dt=tag, mask=obj.mask)
        if isinstance(obj, AArray) and name == "reshape":
            dims = list(args[0]) if len(args) == 1 and isinstance(
                args[0], (tuple, list)) else list(args)
            total = 1
            for d in obj.shape:
                total *= d
            if dims.count(-1) > 1:
                raise Unsupported("reshape with multiple -1 dims")
            if -1 in dims:
                known = 1
                for d in dims:
                    if d != -1:
                        known *= d
                dims[dims.index(-1)] = total // max(known, 1)
            prod = 1
            for d in dims:
                prod *= d
            if prod != total:
                self.finding("K100", f"reshape {obj.shape} -> {tuple(dims)}"
                                     " changes element count")
            return obj.like(shape=tuple(dims))
        raise Unsupported(f"method {name!r} on {type(obj).__name__}")

    # -- statements --------------------------------------------------------

    def exec_block(self, stmts, env, module):
        for st in stmts:
            self.exec_stmt(st, env, module)

    def exec_stmt(self, st, env, module):
        self.line = getattr(st, "lineno", self.line)
        if isinstance(st, ast.FunctionDef):
            clos = Closure(st, env, st.name, module)
            result = clos
            for dec in reversed(st.decorator_list):
                result = self.call(self.eval(dec, env), [result], {})
            env.set(st.name, result)
        elif isinstance(st, ast.Return):
            raise _Return(self.eval(st.value, env)
                          if st.value is not None else None)
        elif isinstance(st, ast.Assign):
            value = self.eval(st.value, env)
            for tgt in st.targets:
                self.assign(tgt, value, env)
        elif isinstance(st, ast.AugAssign):
            if isinstance(st.target, ast.Name):
                cur = env.get(st.target.id)
                env.set(st.target.id,
                        self.binop(st.op, cur, self.eval(st.value, env)))
            elif isinstance(st.target, ast.Subscript):
                ref = self.eval(st.target.value, env)
                if not isinstance(ref, Ref):
                    raise Unsupported("augmented store to non-ref")
                idx = self.eval_index(st.target.slice, env)
                cur = self.ref_load(ref, idx)
                self.ref_store(ref, idx,
                               self.binop(st.op, cur,
                                          self.eval(st.value, env)))
            else:
                raise Unsupported("augmented assignment target")
        elif isinstance(st, ast.Expr):
            self.eval(st.value, env)
        elif isinstance(st, ast.If):
            if self.truth(self.eval(st.test, env)):
                self.exec_block(st.body, env, module)
            else:
                self.exec_block(st.orelse, env, module)
        elif isinstance(st, ast.For):
            for item in self.iterate(self.eval(st.iter, env)):
                self.assign(st.target, item, env)
                self.exec_block(st.body, env, module)
            if st.orelse:
                self.exec_block(st.orelse, env, module)
        elif isinstance(st, ast.Raise):
            raise KernelRaise(self.describe_raise(st, env))
        elif isinstance(st, ast.Assert):
            if not self.truth(self.eval(st.test, env)):
                raise KernelRaise(f"assert failed at line {self.line}")
        elif isinstance(st, ast.ImportFrom):
            self.import_from(st, env)
        elif isinstance(st, ast.Pass):
            pass
        else:
            raise Unsupported(f"statement {type(st).__name__}")

    def describe_raise(self, st, env):
        if st.exc is None:
            return "bare raise"
        try:
            if isinstance(st.exc, ast.Call) and st.exc.args:
                msg = self.eval(st.exc.args[0], env)
                return str(msg)
        except Unsupported:
            pass
        return f"raise at line {self.line}"

    def import_from(self, st, env):
        mod = st.module or ""
        for known, envname in (("repro.kernels.conv2d.kernels", "conv2d"),
                               ("repro.kernels.pool2d.kernels", "pool2d"),
                               ("repro.kernels.matmul_fused.kernel",
                                "matmul")):
            if mod == known:
                src = self.modules.get(envname)
                if src is None:
                    raise Unsupported(f"import from unloaded module {mod}")
                for alias in st.names:
                    env.set(alias.asname or alias.name,
                            src.get(alias.name))
                return
        if mod == "repro.kernels.common":
            for alias in st.names:
                if alias.name != "ACC_DTYPE":
                    raise Unsupported(f"unknown common import {alias.name}")
                env.set(alias.asname or alias.name, DtypeMarker("f32"))
            return
        raise Unsupported(f"import from {mod!r} inside a kernel function")

    def assign(self, tgt, value, env):
        if isinstance(tgt, ast.Name):
            env.set(tgt.id, value)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            items = list(self.iterate(value))
            if len(items) != len(tgt.elts):
                raise Unsupported(
                    f"unpacking {len(items)} values into "
                    f"{len(tgt.elts)} targets")
            for t, v in zip(tgt.elts, items):
                self.assign(t, v, env)
        elif isinstance(tgt, ast.Subscript):
            ref = self.eval(tgt.value, env)
            if not isinstance(ref, Ref):
                raise Unsupported("subscript store to non-ref")
            self.ref_store(ref, self.eval_index(tgt.slice, env), value)
        else:
            raise Unsupported(f"assignment target {type(tgt).__name__}")

    def iterate(self, value):
        if isinstance(value, (list, tuple, range)):
            return list(value)
        if isinstance(value, (zip, enumerate)):
            return list(value)
        raise Unsupported(f"iteration over {type(value).__name__}")

    def truth(self, value):
        if value is None or isinstance(value, (bool, int, float, str,
                                               tuple, list)):
            return bool(value)
        raise Unsupported(
            f"truthiness of abstract value {type(value).__name__}")

    # -- expressions -------------------------------------------------------

    def eval(self, node, env):
        self.line = getattr(node, "lineno", self.line)
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            return env.get(node.id)
        if isinstance(node, ast.Attribute):
            return self.attribute(self.eval(node.value, env), node.attr)
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e, env) for e in node.elts)
        if isinstance(node, ast.List):
            return [self.eval(e, env) for e in node.elts]
        if isinstance(node, ast.BinOp):
            return self.binop(node.op, self.eval(node.left, env),
                              self.eval(node.right, env))
        if isinstance(node, ast.UnaryOp):
            return self.unaryop(node.op, self.eval(node.operand, env))
        if isinstance(node, ast.BoolOp):
            result = None
            for v in node.values:
                result = self.eval(v, env)
                t = self.truth(result)
                if isinstance(node.op, ast.And) and not t:
                    return result
                if isinstance(node.op, ast.Or) and t:
                    return result
            return result
        if isinstance(node, ast.Compare):
            return self.compare(node, env)
        if isinstance(node, ast.IfExp):
            branch = (node.body if self.truth(self.eval(node.test, env))
                      else node.orelse)
            return self.eval(branch, env)
        if isinstance(node, ast.Call):
            fn = self.eval(node.func, env)
            args = []
            for a in node.args:
                if isinstance(a, ast.Starred):
                    args.extend(self.iterate(self.eval(a.value, env)))
                else:
                    args.append(self.eval(a, env))
            kwargs = {}
            for kw in node.keywords:
                if kw.arg is None:
                    raise Unsupported("** call expansion")
                kwargs[kw.arg] = self.eval(kw.value, env)
            return self.call(fn, args, kwargs)
        if isinstance(node, ast.Subscript):
            obj = self.eval(node.value, env)
            idx = self.eval_index(node.slice, env)
            return self.subscript(obj, idx)
        if isinstance(node, ast.Lambda):
            return Closure(node, env, "<lambda>", None)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp)):
            return self.comprehension(node, env)
        if isinstance(node, ast.JoinedStr):
            parts = []
            for v in node.values:
                if isinstance(v, ast.Constant):
                    parts.append(str(v.value))
                elif isinstance(v, ast.FormattedValue):
                    parts.append(str(self.eval(v.value, env)))
                else:
                    raise Unsupported("f-string component")
            return "".join(parts)
        raise Unsupported(f"expression {type(node).__name__}")

    def comprehension(self, node, env):
        if len(node.generators) != 1:
            raise Unsupported("nested comprehension")
        gen = node.generators[0]
        if gen.is_async:
            raise Unsupported("async comprehension")
        out = []
        inner = Env(parent=env)
        for item in self.iterate(self.eval(gen.iter, env)):
            self.assign(gen.target, item, inner)
            if all(self.truth(self.eval(c, inner)) for c in gen.ifs):
                out.append(self.eval(node.elt, inner))
        return out

    def eval_index(self, node, env):
        """Evaluate a subscript index; slices stay as python slices."""
        if isinstance(node, ast.Tuple):
            return tuple(self.eval_index(e, env) for e in node.elts)
        if isinstance(node, ast.Slice):
            return slice(
                None if node.lower is None else self.eval(node.lower, env),
                None if node.upper is None else self.eval(node.upper, env),
                None if node.step is None else self.eval(node.step, env))
        return self.eval(node, env)

    def attribute(self, obj, attr):
        if isinstance(obj, ModuleHandle):
            return self.module_attr(obj.name, attr)
        if isinstance(obj, (Ref, AArray)):
            if attr == "shape":
                return obj.shape
            if attr == "ndim":
                return len(obj.shape)
            if attr == "dtype":
                is_out = isinstance(obj, Ref) and obj.is_out
                return DtypeOf(obj.dt, is_out)
            if attr in ("astype", "reshape") and isinstance(obj, AArray):
                return BoundMethod(obj, attr)
            raise Unsupported(f"attribute .{attr} on array/ref")
        if isinstance(obj, list) and attr == "append":
            return BoundMethod(obj, attr)
        raise Unsupported(f"attribute .{attr} on {type(obj).__name__}")

    _JNP_DTYPES = {"float32": "f32", "float64": "f64", "int32": "i32",
                   "bfloat16": "io"}

    def module_attr(self, mod, attr):
        if mod == "jnp":
            if attr in self._JNP_DTYPES:
                return DtypeMarker(self._JNP_DTYPES[attr])
            if attr == "inf":
                return float("inf")
            return _ModFn(("jnp", attr))
        if mod == "jax":
            if attr == "lax":
                return ModuleHandle("jax.lax")
            if attr == "ShapeDtypeStruct":
                return _ModFn(("jax", "ShapeDtypeStruct"))
            raise Unsupported(f"jax.{attr}")
        if mod == "jax.lax":
            return _ModFn(("lax", attr))
        if mod == "pl":
            return _ModFn(("pl", attr))
        if mod == "pltpu":
            return _ModFn(("pltpu", attr))
        if mod == "functools":
            if attr == "partial":
                return _ModFn(("functools", "partial"))
            raise Unsupported(f"functools.{attr}")
        raise Unsupported(f"module {mod}.{attr}")

    # -- operators ---------------------------------------------------------

    def binop(self, op, a, b):
        if isinstance(op, ast.BitAnd):
            if isinstance(a, RowPred) and isinstance(b, RowPred):
                return a & b
            if isinstance(a, int) and isinstance(b, int):
                return a & b
            raise Unsupported("& on non-predicates")
        if isinstance(a, AArray) or isinstance(b, AArray):
            if isinstance(op, (ast.Add, ast.Sub, ast.Mult, ast.Div,
                               ast.Pow)):
                return _arr_binop(a, b, self)
            raise Unsupported(f"array op {type(op).__name__}")
        if isinstance(a, IotaV) or isinstance(b, IotaV):
            iota = a if isinstance(a, IotaV) else b
            other = b if isinstance(a, IotaV) else a
            if not isinstance(op, ast.Add):
                raise Unsupported("iota only supports addition")
            return RowExpr(Aff.lift(other), iota)
        if isinstance(a, RowExpr) or isinstance(b, RowExpr):
            re = a if isinstance(a, RowExpr) else b
            other = b if isinstance(a, RowExpr) else a
            if not isinstance(op, ast.Add):
                raise Unsupported("row expr only supports addition")
            return RowExpr(re.aff + Aff.lift(other), re.iota)
        if isinstance(a, Aff) or isinstance(b, Aff):
            a, b = Aff.lift(a), Aff.lift(b)
            if isinstance(op, ast.Add):
                r = a + b
            elif isinstance(op, ast.Sub):
                r = a - b
            elif isinstance(op, ast.Mult):
                r = a * b
            else:
                raise Unsupported(
                    f"affine op {type(op).__name__} on grid indices")
            ri = r.as_int()
            return r if ri is None else ri
        table = {ast.Add: lambda x, y: x + y,
                 ast.Sub: lambda x, y: x - y,
                 ast.Mult: lambda x, y: x * y,
                 ast.Div: lambda x, y: x / y,
                 ast.FloorDiv: lambda x, y: x // y,
                 ast.Mod: lambda x, y: x % y,
                 ast.Pow: lambda x, y: x ** y}
        fn = table.get(type(op))
        if fn is None:
            raise Unsupported(f"operator {type(op).__name__}")
        return fn(a, b)

    def unaryop(self, op, v):
        if isinstance(op, ast.USub):
            if isinstance(v, (int, float)):
                return -v
            if isinstance(v, Aff):
                return v * -1
            if isinstance(v, AArray):
                return _arr_binop(v, v, self)
            raise Unsupported("unary minus on abstract value")
        if isinstance(op, ast.Not):
            return not self.truth(v)
        raise Unsupported(f"unary {type(op).__name__}")

    def compare(self, node, env):
        left = self.eval(node.left, env)
        result = True
        for op, comp in zip(node.ops, node.comparators):
            right = self.eval(comp, env)
            r = self.compare_one(left, op, right)
            if isinstance(r, (Pred, RowPred)):
                if len(node.ops) > 1:
                    raise Unsupported("chained abstract comparison")
                return r
            if not r:
                return False
            left = right
        return result

    def compare_one(self, left, op, right):
        if isinstance(op, (ast.Is, ast.IsNot)):
            if left is not None and right is not None:
                raise Unsupported("identity comparison of non-None values")
            same = left is right
            return same if isinstance(op, ast.Is) else not same
        if isinstance(left, RowExpr) or isinstance(right, RowExpr):
            re = left if isinstance(left, RowExpr) else right
            other = right if isinstance(left, RowExpr) else left
            flip = re is right
            if isinstance(op, ast.GtE) and not flip:
                return re.compare("ge", other)
            if isinstance(op, ast.Lt) and not flip:
                return re.compare("lt", other)
            raise Unsupported("row comparison form")
        if isinstance(left, Aff) or isinstance(right, Aff):
            diff = Aff.lift(left) - Aff.lift(right)
            di = diff.as_int()
            if di is not None:
                table = {ast.Eq: di == 0, ast.NotEq: di != 0,
                         ast.Lt: di < 0, ast.LtE: di <= 0,
                         ast.Gt: di > 0, ast.GtE: di >= 0}
                if type(op) in table:
                    return table[type(op)]
                raise Unsupported("comparison on grid indices")
            if isinstance(op, ast.Eq) and isinstance(left, Aff) \
                    and isinstance(right, int):
                return Pred(left, right)
            raise Unsupported("abstract comparison on grid indices")
        table = {ast.Eq: lambda x, y: x == y,
                 ast.NotEq: lambda x, y: x != y,
                 ast.Lt: lambda x, y: x < y,
                 ast.LtE: lambda x, y: x <= y,
                 ast.Gt: lambda x, y: x > y,
                 ast.GtE: lambda x, y: x >= y,
                 ast.In: lambda x, y: x in y,
                 ast.NotIn: lambda x, y: x not in y}
        fn = table.get(type(op))
        if fn is None:
            raise Unsupported(f"comparison {type(op).__name__}")
        return fn(left, right)

    # -- subscripts, loads, stores ----------------------------------------

    def subscript(self, obj, idx):
        if isinstance(obj, (tuple, list, str)):
            if isinstance(idx, (int, slice)):
                return obj[idx]
            raise Unsupported(f"sequence index {idx!r}")
        if isinstance(obj, Ref):
            return self.ref_load(obj, idx)
        if isinstance(obj, AArray):
            shape = self.index_shape(obj.shape, idx, f"<{obj.dt} array>")
            return obj.like(shape=shape, mask=None)
        raise Unsupported(f"subscript on {type(obj).__name__}")

    def _axis_bounds(self, start, size, dim, name, axis):
        """K101 check: [start, start+size) must sit inside [0, dim)."""
        aff = Aff.lift(start)
        lo, hi = aff.bounds(self.sym_ranges)
        if lo < 0 or hi + size > dim:
            self.finding(
                "K101",
                f"{name} axis {axis}: rows [{lo}, {hi + size}) can leave "
                f"the block/operand extent [0, {dim})")

    def index_shape(self, shape, idx, name):
        """Result shape of an index expression, bounds-checked (K101)."""
        if not isinstance(idx, tuple):
            idx = (idx,)
        if any(i is Ellipsis for i in idx):
            if len(idx) == 1:
                return tuple(shape)
            raise Unsupported("partial Ellipsis index")
        out = []
        for axis, dim in enumerate(shape):
            if axis >= len(idx):
                out.append(dim)
                continue
            i = idx[axis]
            if isinstance(i, (int, Aff)):
                if isinstance(i, int) and i < 0:
                    i += dim
                self._axis_bounds(i, 1, dim, name, axis)
                continue  # squeezed
            if isinstance(i, DS):
                self._axis_bounds(i.start, i.size, dim, name, axis)
                out.append(i.size)
                continue
            if isinstance(i, slice):
                if i.step not in (None, 1):
                    raise Unsupported("strided slice subscript")
                lo = 0 if i.start is None else i.start
                hi = dim if i.stop is None else i.stop
                if lo < 0:
                    lo += dim
                if hi < 0:
                    hi += dim
                hi = min(hi, dim)
                if lo < 0 or hi < lo:
                    self.finding("K101",
                                 f"{name} axis {axis}: slice [{lo}, {hi}) "
                                 f"outside [0, {dim})")
                    lo, hi = 0, dim
                out.append(hi - lo)
                continue
            if i is None:
                out.append(1)
                continue
            raise Unsupported(f"index component {i!r}")
        return tuple(out)

    def ref_load(self, ref, idx):
        shape = self.index_shape(ref.shape, idx, ref.name)
        if ref.is_scratch:
            self.scratch_events.append(
                ("load", ref, None, tuple(self.guards), self.line))
        return AArray(shape, ref.dt, from_out=ref.is_out)

    def ref_store(self, ref, idx, value):
        if ref.is_scratch:
            # scratch carry stores feed the K106 discipline proof, not
            # the K102 output lattice
            if not isinstance(value, AArray):
                raise Unsupported(
                    f"scratch store of non-array into {ref.name}")
            full = idx is Ellipsis or (
                isinstance(idx, tuple) and len(idx) == 1
                and idx[0] is Ellipsis)
            if not full:
                self.finding("K106", f"partial scratch store into "
                             f"{ref.name} — the carry must replace the "
                             "whole scratch block")
            elif value.shape != ref.shape:
                self.finding("K106", f"scratch store shape {value.shape} "
                             f"does not match {ref.name}'s block "
                             f"{ref.shape}")
            self.scratch_events.append(
                ("store", ref, value, tuple(self.guards), self.line))
            return
        if not ref.is_out:
            raise Unsupported(f"store into input ref {ref.name}")
        if not isinstance(value, AArray):
            value = AArray(ref.shape, "weak")
        full = idx is Ellipsis or (isinstance(idx, tuple) and len(idx) == 1
                                   and idx[0] is Ellipsis)
        if not full:
            self.index_shape(ref.shape, idx, ref.name)
        self.stores.append(Store(self.guards, value, full, self.line))

    # -- jnp / lax / pl dispatch ------------------------------------------

    def call_modfn(self, fn, args, kwargs):
        path = ".".join(fn.path)
        if path == "functools.partial":
            return PartialV(args[0], args[1:], kwargs)
        if path == "jax.ShapeDtypeStruct":
            return ShapeDtypeV(tuple(args[0]), _tag_of(args[1]))
        if path.startswith("jnp."):
            return self.call_jnp(fn.path[1], args, kwargs)
        if path.startswith("lax."):
            return self.call_lax(fn.path[1], args, kwargs)
        if path == "pl.pallas_call":
            kernel = args[0]
            cp = kwargs.get("compiler_params")
            sem = cp.dimension_semantics if isinstance(
                cp, CompilerParamsV) else None
            out_specs = kwargs["out_specs"]
            if isinstance(out_specs, (tuple, list)):
                raise Unsupported("multiple output specs")
            scratch = kwargs.get("scratch_shapes")
            if scratch is not None and not all(
                    isinstance(s, ShapeDtypeV) for s in scratch):
                raise Unsupported("non-VMEM scratch_shapes entry")
            return PallasV(kernel, tuple(kwargs["grid"]),
                           list(kwargs["in_specs"]), out_specs,
                           kwargs["out_shape"], sem, scratch)
        if path == "pl.BlockSpec":
            block_shape = tuple(args[0])
            index_map = args[1]
            mode = kwargs.get("indexing_mode")
            return BlockSpecV(block_shape, index_map, mode is _UNBLOCKED)
        if path == "pl.Unblocked":
            return _UNBLOCKED
        if path == "pl.program_id":
            axis = args[0]
            if axis not in self.sym_ranges:
                raise Unsupported(f"program_id({axis}) outside a kernel "
                                  "body or beyond the grid rank")
            return Aff({axis: 1}, 0)
        if path == "pl.when":
            pred = args[0]
            if not isinstance(pred, Pred):
                raise Unsupported("pl.when on a non-affine predicate")
            return PlWhenV(pred)
        if path == "pl.ds":
            return DS(args[0], args[1])
        if path == "pltpu.TPUCompilerParams":
            return CompilerParamsV(tuple(kwargs["dimension_semantics"]))
        if path == "pltpu.VMEM":
            return ShapeDtypeV(tuple(args[0]), _tag_of(args[1]))
        raise Unsupported(f"call to {path}")

    def call_jnp(self, name, args, kwargs):
        if name == "pad":
            arr, widths = args[0], args[1]
            if not isinstance(arr, AArray):
                raise Unsupported("jnp.pad of non-array")
            if widths and not isinstance(widths[0], (tuple, list)):
                widths = [tuple(widths)]
            widths = [tuple(w) for w in widths]
            if len(widths) != len(arr.shape):
                raise Unsupported("jnp.pad width rank mismatch")
            shape = tuple(d + lo + hi
                          for d, (lo, hi) in zip(arr.shape, widths))
            keep_mask = arr.mask is not None and widths[0] == (0, 0)
            return arr.like(shape=shape,
                            mask=arr.mask if keep_mask else None)
        if name in ("zeros", "full"):
            shape = tuple(args[0]) if isinstance(
                args[0], (tuple, list)) else (args[0],)
            dt_arg = args[-1] if len(args) > (1 if name == "zeros" else 2) \
                else kwargs.get("dtype")
            tag = _tag_of(dt_arg) if dt_arg is not None else "f32"
            return AArray(shape, tag)
        if name == "zeros_like":
            src = args[0]
            if isinstance(src, (Ref, AArray)):
                return AArray(src.shape, src.dt)
            raise Unsupported("zeros_like of non-array")
        if name == "maximum":
            return _arr_binop(args[0], args[1], self)
        if name == "concatenate":
            seq = [a for a in self.iterate(args[0])]
            axis = kwargs.get("axis", args[1] if len(args) > 1 else 0)
            if not seq or not all(isinstance(a, AArray) for a in seq):
                raise Unsupported("concatenate of non-arrays")
            nd = seq[0].ndim
            axis = axis % nd
            base = list(seq[0].shape)
            total = 0
            joined = seq[0]
            for a in seq:
                if len(a.shape) != nd or any(
                        a.shape[i] != base[i] for i in range(nd)
                        if i != axis):
                    self.finding("K100", "concatenate shape mismatch "
                                         f"{[s.shape for s in seq]}")
                total += a.shape[axis]
                joined = _arr_binop(joined, a, self)
            base[axis] = total
            return joined.like(shape=tuple(base), mask=None)
        if name == "dot":
            a, b = args[0], args[1]
            if not (isinstance(a, AArray) and isinstance(b, AArray)):
                raise Unsupported("dot of non-arrays")
            if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
                self.finding("K100", f"dot shape mismatch {a.shape} x "
                                     f"{b.shape}")
            pet = kwargs.get("preferred_element_type")
            if pet is None or _tag_of(pet) != "f32":
                self.finding("K103", "jnp.dot without "
                             "preferred_element_type=float32 — the MXU "
                             "accumulator dtype is unpinned")
            for op in (a, b):
                if op.dt not in ("f32", "weak"):
                    self.finding("K103", f"jnp.dot operand has dtype "
                                 f"{op.dt!r} — operands must be upcast "
                                 "to fp32 before accumulation")
            # join metadata directly — contraction shapes don't broadcast
            return AArray((a.shape[0], b.shape[1] if b.ndim == 2 else 1),
                          "f32", a.downcasts + b.downcasts,
                          a.tainted or b.tainted or a.downcasts > 0
                          or b.downcasts > 0,
                          a.from_out or b.from_out)
        if name == "where":
            cond, x, y = args[0], args[1], args[2]
            if isinstance(cond, RowRange):
                zero = (isinstance(y, (int, float)) and y == 0)
                if isinstance(x, AArray) and zero:
                    return x.like(mask=cond.key())
                raise Unsupported("row-masked where with nonzero filler")
            return _arr_binop(x if isinstance(x, AArray) else y,
                              y if isinstance(y, AArray) else x, self)
        if name in ("exp", "tanh"):
            v = args[0]
            if isinstance(v, AArray):
                return _arr_binop(v, v, self)
            raise Unsupported(f"jnp.{name} of non-array")
        raise Unsupported(f"jnp.{name}")

    def call_lax(self, name, args, kwargs):
        if name == "slice":
            x, starts, limits = args[0], tuple(args[1]), tuple(args[2])
            strides = tuple(args[3]) if len(args) > 3 else \
                kwargs.get("strides")
            if not isinstance(x, AArray):
                raise Unsupported("lax.slice of non-array")
            if strides is None:
                strides = (1,) * x.ndim
            shape = []
            for axis, (s, l, st, dim) in enumerate(
                    zip(starts, limits, strides, x.shape)):
                if not all(isinstance(v, int) for v in (s, l, st)):
                    raise Unsupported("non-concrete lax.slice bound")
                if s < 0 or l > dim or s >= l:
                    self.finding(
                        "K101",
                        f"lax.slice axis {axis}: [{s}, {l}) outside the "
                        f"staged array extent [0, {dim})")
                    s, l = 0, dim
                shape.append((l - s + st - 1) // st)
            return x.like(shape=tuple(shape), mask=None)
        if name == "slice_in_dim":
            x, start, stop = args[0], args[1], args[2]
            axis = kwargs.get("axis", args[3] if len(args) > 3 else 0)
            if not isinstance(x, AArray):
                raise Unsupported("slice_in_dim of non-array")
            dim = x.shape[axis]
            if start < 0 or stop > dim or start >= stop:
                self.finding(
                    "K101",
                    f"lax.slice_in_dim axis {axis}: [{start}, {stop}) "
                    f"outside [0, {dim})")
                start, stop = 0, dim
            shape = list(x.shape)
            shape[axis] = stop - start
            out = x.like(shape=tuple(shape), mask=None)
            if axis == 0:
                # contiguous row-slice provenance for the K106 proof
                out.row_slice = (start, stop, dim)
            return out
        if name == "broadcasted_iota":
            shape = tuple(args[1])
            return IotaV(shape, args[2])
        raise Unsupported(f"lax.{name}")

    # -- dispatch analysis -------------------------------------------------

    def eval_index_map(self, index_map, n_syms):
        """Run a BlockSpec index map once, with grid indices as symbols."""
        syms = [Aff({s: 1}, 0) for s in range(n_syms)]
        out = self.call(index_map, syms, {})
        if not isinstance(out, tuple):
            out = (out,)
        return out

    def _squeeze(self, block_shape):
        return tuple(d for d in block_shape if d is not None)

    def analyze_dispatch(self, pv, operands):
        """The heart of Phase B: prove one ``pallas_call`` dispatch."""
        self.stores = []
        self.scratch_events = []
        self.band_conv_masks = []
        self.guards = []
        grid = pv.grid
        if not all(isinstance(g, int) and g > 0 for g in grid):
            raise Unsupported(f"non-concrete grid {grid!r}")
        self.sym_ranges = {s: g for s, g in enumerate(grid)}
        if len(pv.in_specs) != len(operands):
            raise Unsupported("in_specs / operand count mismatch")

        # K101 — spec-level: every block an index map can select must sit
        # inside the (padded) operand it loads from.
        for spec_i, (spec, op) in enumerate(zip(pv.in_specs, operands)):
            if not isinstance(op, AArray):
                raise Unsupported(f"operand {spec_i} is not an array")
            idx = self.eval_index_map(spec.index_map, len(grid))
            if len(idx) != len(spec.block_shape) or \
                    len(idx) != len(op.shape):
                self.finding("K100", f"in_spec {spec_i}: index map rank "
                             f"{len(idx)} vs block rank "
                             f"{len(spec.block_shape)} vs operand rank "
                             f"{len(op.shape)}")
                continue
            for axis, (bd, comp) in enumerate(zip(spec.block_shape, idx)):
                bsize = 1 if bd is None else bd
                aff = Aff.lift(comp)
                start = aff if spec.unblocked else aff * bsize
                lo, hi = start.bounds(self.sym_ranges)
                if lo < 0 or hi + bsize > op.shape[axis]:
                    self.finding(
                        "K101",
                        f"in_spec {spec_i} axis {axis}: blocks span "
                        f"[{lo}, {hi + bsize}) but the operand extent is "
                        f"[0, {op.shape[axis]})")

        # K102 — out-spec: the store lattice must tile the output exactly.
        out_sds = pv.out_shape
        if not isinstance(out_sds, ShapeDtypeV):
            raise Unsupported("out_shape is not a ShapeDtypeStruct")
        ospec = pv.out_specs
        if ospec.unblocked:
            raise Unsupported("unblocked output spec")
        oidx = self.eval_index_map(ospec.index_map, len(grid))
        used_syms = set()
        if len(oidx) != len(ospec.block_shape) or \
                len(oidx) != len(out_sds.shape):
            self.finding("K100", "out_spec rank mismatch")
            return AArray(out_sds.shape, out_sds.dt)
        for axis, (bd, comp) in enumerate(zip(ospec.block_shape, oidx)):
            bsize = 1 if bd is None else bd
            aff = Aff.lift(comp)
            dim = out_sds.shape[axis]
            if aff.coeffs:
                if len(aff.coeffs) > 1 or aff.const != 0:
                    self.finding("K102", f"out axis {axis}: index map is "
                                 f"not a single grid index ({aff!r})")
                    continue
                (s, coef), = aff.coeffs.items()
                if coef != 1:
                    self.finding("K102", f"out axis {axis}: strided index "
                                 f"map ({aff!r}) leaves gaps or overlaps")
                    continue
                if s in used_syms:
                    self.finding("K102", f"out axis {axis}: grid index "
                                 f"g{s} reused across output axes")
                used_syms.add(s)
                if grid[s] * bsize != dim:
                    self.finding(
                        "K102",
                        f"out axis {axis}: {grid[s]} blocks x {bsize} "
                        f"rows cover [0, {grid[s] * bsize}) but the "
                        f"output extent is [0, {dim})")
            else:
                if aff.const != 0:
                    self.finding("K102", f"out axis {axis}: constant "
                                 f"block index {aff.const} != 0")
                if bsize != dim:
                    self.finding(
                        "K102",
                        f"out axis {axis}: single block of {bsize} rows "
                        f"covers [0, {bsize}) of [0, {dim})")
        acc_syms = [s for s in range(len(grid)) if s not in used_syms
                    and grid[s] > 1]

        # interpret the kernel body with the grid indices symbolic
        kernel, preset_args, preset_kw = pv.kernel, [], {}
        while isinstance(kernel, PartialV):
            preset_args = list(kernel.args) + preset_args
            preset_kw = {**kernel.kwargs, **preset_kw}
            kernel = kernel.fn
        if not isinstance(kernel, Closure):
            raise Unsupported("kernel is not an interpretable function")
        refs = []
        for spec_i, (spec, op) in enumerate(zip(pv.in_specs, operands)):
            refs.append(Ref(f"in_ref{spec_i}",
                            self._squeeze(spec.block_shape), op.dt, False))
        o_ref = Ref("o_ref", self._squeeze(ospec.block_shape),
                    out_sds.dt, True)
        scratch_refs = [Ref(f"scratch{i}", sv.shape, sv.dt, False, True)
                        for i, sv in enumerate(pv.scratch_shapes)]
        self._name_refs(kernel, preset_args, refs, o_ref, scratch_refs)
        self.call(PartialV(kernel, preset_args, preset_kw),
                  refs + [o_ref] + scratch_refs, {})

        self._check_store_discipline(o_ref, grid, acc_syms,
                                     pv.dimension_semantics)
        if scratch_refs:
            self._check_carry_discipline(scratch_refs, grid,
                                         pv.dimension_semantics)
        stages = preset_kw.get("stages")
        if stages is not None:
            self._check_chain_masks(stages, grid)
        return AArray(out_sds.shape, out_sds.dt)

    def _name_refs(self, kernel, preset_args, refs, o_ref, scratch_refs):
        """Give refs their kernel-parameter names for findings."""
        params = [p.arg for p in kernel.node.args.args]
        params = params[len(preset_args):]
        bound = refs + [o_ref] + scratch_refs
        for name, ref in zip(params, bound):
            ref.name = name
        if len(bound) > len(params) and not scratch_refs:
            # *refs vararg: last one is o_ref
            for i, ref in enumerate(bound[len(params):-1]):
                ref.name = f"refs[{i}]"

    def _normalize_guards(self, store, grid):
        """Guards as {sym: value}; None if the store can never execute."""
        gv = {}
        for pred in store.guards:
            s, v = pred.sym_eq()
            if v < 0 or v >= grid[s]:
                return None  # dead store
            if grid[s] == 1:
                continue  # trivially true
            if s in gv and gv[s] != v:
                return None
            gv[s] = v
        return gv

    def _check_store_discipline(self, o_ref, grid, acc_syms, dim_sem):
        live = []
        for st in self.stores:
            gv = self._normalize_guards(st, grid)
            if gv is not None:
                live.append((st, gv))
        if not live:
            self.finding("K102", "kernel body never stores to the output "
                         "ref — every element stays uninitialized")
            return
        first_st, first_gv = live[0]
        if first_st.value.from_out:
            self.finding("K102", "first output store is a read-modify-"
                         "write — it reads uninitialized VMEM")
        for st, gv in live:
            if not st.full_block:
                self.finding("K102", f"partial output store at line "
                             f"{st.line} — stores must cover the whole "
                             "block")
            for s in gv:
                if s not in acc_syms:
                    self.finding("K102", f"store at line {st.line} is "
                                 f"guarded on covered grid axis g{s} — "
                                 "some blocks are never written")
            if st.value.from_out:
                continue  # RMW accumulation step
            for s in acc_syms:
                if gv.get(s) != 0:
                    self.finding(
                        "K102",
                        f"overwrite store at line {st.line} re-executes "
                        f"for every value of accumulation axis g{s} — "
                        "earlier partial sums are discarded")
        for s in acc_syms:
            sem = (dim_sem[s] if dim_sem is not None and s < len(dim_sem)
                   else None)
            if sem != "arbitrary":
                self.finding(
                    "K102",
                    f"accumulation axis g{s} has dimension_semantics "
                    f"{sem!r} — revisiting an output block requires "
                    "'arbitrary'")
            if not any(gv.get(s) == 0 and not st.value.from_out
                       for st, gv in live):
                self.finding(
                    "K102",
                    f"no initializing overwrite store guarded to "
                    f"g{s} == 0 — the first visit accumulates into "
                    "uninitialized VMEM")
        # K103: per-store precision flow
        for st, _ in live:
            v = st.value
            if o_ref.dt == "io":
                if v.dt != "io" or v.downcasts != 1:
                    self.finding(
                        "K103",
                        f"store at line {st.line}: value has dtype tag "
                        f"{v.dt!r} after {v.downcasts} downcast(s) — "
                        "expected exactly one astype(o_ref.dtype) at "
                        "the store")
                elif v.tainted:
                    self.finding(
                        "K103",
                        f"store at line {st.line}: arithmetic happened "
                        "after the downcast — the cast must be the "
                        "final operation")
            else:  # fp32 output: no downcast at all
                if v.dt not in ("f32", "weak") or v.downcasts != 0:
                    self.finding(
                        "K103",
                        f"store at line {st.line}: value dtype tag "
                        f"{v.dt!r} with {v.downcasts} downcast(s) — "
                        "fp32 outputs must be stored undowncast")

    def _check_carry_discipline(self, scratch_refs, grid, dim_sem):
        """K106: a VMEM scratch carry must be consumed before overwrite,
        and the overwrite must keep the TAIL rows of the fresh band.

        The carried axis is the innermost grid axis (scratch persists
        across its steps), so it needs 'arbitrary' semantics: a parallel
        or reordered axis would let a step read a carry its predecessor
        has not produced yet.  Each step must (a) read the scratch
        before writing it — the carried rows are this step's data, the
        store is the NEXT step's — and (b) store exactly the last
        ``scratch_rows`` rows of the fresh band (a provable tail
        row-slice): a head slice or recomputed value would hand the next
        band stale rows."""
        ca = len(grid) - 1
        sem = (dim_sem[ca] if dim_sem is not None and ca < len(dim_sem)
               else None)
        if sem != "arbitrary":
            self.finding(
                "K106",
                f"carried grid axis g{ca} has dimension_semantics "
                f"{sem!r} — scratch carry across steps requires "
                "'arbitrary'")
        for ref in scratch_refs:
            events = [e for e in self.scratch_events if e[1] is ref]
            if not events:
                self.finding("K106", f"scratch ref {ref.name} is never "
                             "accessed — dead carry allocation")
                continue
            if events[0][0] != "load":
                self.finding(
                    "K106",
                    f"scratch ref {ref.name} is written before its "
                    "carried rows are consumed — the carry from the "
                    "previous band step is lost")
            stores = [e for e in events if e[0] == "store"]
            if not stores:
                self.finding(
                    "K106",
                    f"scratch ref {ref.name} is read but never "
                    "refreshed — every step after the first consumes "
                    "the same stale carry")
            for _, _, value, guards, line in stores:
                if guards:
                    self.finding(
                        "K106",
                        f"scratch store at line {line} is guarded — a "
                        "skipped step would hand the next band a stale "
                        "carry")
                rs = value.row_slice
                if rs is None:
                    self.finding(
                        "K106",
                        f"scratch store at line {line} is not a "
                        "provable contiguous row-slice of the fresh "
                        "band — cannot prove the carry holds the "
                        "band's boundary rows")
                    continue
                start, stop, dim = rs
                rows = ref.shape[0]
                if stop != dim or stop - start != rows:
                    self.finding(
                        "K106",
                        f"scratch store at line {line} keeps rows "
                        f"[{start}, {stop}) of a {dim}-row band — the "
                        f"carry must be the TAIL {rows} rows "
                        f"[{dim - rows}, {dim}); the next band step "
                        "would consume stale rows")

    def _check_chain_masks(self, stages, grid):
        """K104: a stage band with possibly-garbage rows must be masked."""
        n_tiles = grid[1] if len(grid) > 1 else 1
        for si, mask in enumerate(self.band_conv_masks):
            if si == 0:
                continue  # stage 0 consumes the host-padded input band
            prev = stages[si - 1]
            m_prev, oh_valid, a, b0 = prev[5], prev[8], prev[9], prev[10]
            garbage = b0 < 0 or a * (n_tiles - 1) + b0 + m_prev > oh_valid
            if not garbage:
                continue
            expected = (((1, a),), b0, 0, oh_valid)
            if mask is None:
                self.finding(
                    "K104",
                    f"stage {si} consumes stage {si - 1}'s band without "
                    "a row mask, but that band provably contains rows "
                    f"outside [0, {oh_valid}) — conv-of-pad garbage "
                    "flows into the next stage")
            elif mask != expected:
                self.finding(
                    "K104",
                    f"stage {si}: row mask {mask!r} does not match the "
                    f"required zero range (rows {a}*t + {b0} clipped to "
                    f"[0, {oh_valid}))")


# ---------------------------------------------------------------------------
# module loading + Phase-A interception
# ---------------------------------------------------------------------------

#: module key -> kernel source path relative to ``src/repro/kernels``
KERNEL_SOURCES = {"conv2d": "conv2d/kernels.py",
                  "pool2d": "pool2d/kernels.py",
                  "matmul": "matmul_fused/kernel.py"}

_PALLAS_ALIASES = {"pallas": "pl", "tpu": "pltpu"}


def _i_plan_oh_tiles(xp, oh, kh, kw, sy, oh_block, ow, oc_block,
                     im2col=True):
    """Phase-A answer for the un-fused band planner (pads abstractly)."""
    n, hp, wp, c = xp.shape
    ohb = _a_resolve_oh(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                        im2col=im2col)
    n_tiles = _ceil_div(oh, ohb)
    band = _a_band(ohb, kh, sy)
    hp_need = (n_tiles - 1) * ohb * sy + band
    if hp_need > hp:
        xp = xp.like(shape=(n, hp_need, wp, c))
    return xp, ohb, n_tiles, band


def _i_plan_pool_tiles(xp, oh, ow, kh, kw, sy, oh_block, oc_block, pool,
                       im2col=True, oc_halo=0):
    """Phase-A answer for the fused conv+pool band planner."""
    pkh, pkw, psy, psx = pool
    n, hp, wp, c = xp.shape
    ph, pw = (oh - pkh) // psy + 1, (ow - pkw) // psx + 1
    if ph < 1 or pw < 1:
        raise KernelRaise(
            f"pool window ({pkh},{pkw}) larger than conv output "
            f"({oh},{ow})")
    phb, n_tiles = _a_resolve_ph(ph, oh, ow, wp, c, kh, kw, sy, oc_block,
                                 pool, oh_block, im2col=im2col,
                                 oc_halo=oc_halo)
    cband = _a_band(phb, pkh, psy)
    band = _a_band(cband, kh, sy)
    row_step = phb * psy * sy
    hp_need = (n_tiles - 1) * row_step + band
    if hp_need > hp:
        xp = xp.like(shape=(n, hp_need, wp, c))
    return xp, phb, n_tiles, band, cband, ph, pw, row_step


def _i_pool_out_size(size, k, stride):
    return (size - k) // stride + 1


#: trusted resolver names, answered by Phase A instead of interpretation
_INTERCEPTS = {
    "conv2d": {
        "_out_size": _a_out,
        "_band_rows": _a_band,
        "band_intervals": _a_intervals,
        "auto_oh_block": _a_auto_oh,
        "resolve_oh_block": _a_resolve_oh,
        "auto_ph_block": _a_auto_ph,
        "resolve_ph_block": _a_resolve_ph,
        "resolve_lrn_ocb": _a_resolve_lrn_ocb,
        "resolve_pool_carry": _a_resolve_pool_carry,
        "_equalize_bands": _a_equalize,
        "_plan_oh_tiles": _i_plan_oh_tiles,
        "_plan_pool_tiles": _i_plan_pool_tiles,
        "chain_stage_dims": _a_chain_dims,
        "chain_band_geometry": _a_chain_geom,
        "auto_chain_block": _a_auto_chain,
        "resolve_chain_block": _a_resolve_chain,
    },
    "pool2d": {
        "_out_size": _i_pool_out_size,
        "auto_oh_block_pool": _a_auto_oh_pool,
    },
    "matmul": {},
}

_ENV_CACHE: Dict[str, Env] = {}


def _kernel_source(name: str, sources) -> str:
    if sources is not None and name in sources:
        return sources[name]
    root = Path(__file__).resolve().parent.parent / "kernels"
    return (root / KERNEL_SOURCES[name]).read_text()


def load_kernel_modules(sources=None) -> Dict[str, Env]:
    """Parse the kernel sources into abstract module environments.

    ``sources`` maps a ``KERNEL_SOURCES`` key to replacement source text
    (the mutation tests inject seeded defects this way).  The sources are
    parsed with ``ast`` — never imported or executed.
    """
    if sources is None and _ENV_CACHE:
        return dict(_ENV_CACHE)
    envs: Dict[str, Env] = {}
    interp = Interp(envs, "<module>", [])
    for name in ("conv2d", "pool2d", "matmul"):
        env = Env()
        tree = ast.parse(_kernel_source(name, sources))
        for st in tree.body:
            interp.line = getattr(st, "lineno", 0)
            if isinstance(st, ast.Expr) and isinstance(st.value,
                                                       ast.Constant):
                continue  # module docstring
            if isinstance(st, ast.ImportFrom):
                mod = st.module or ""
                if mod == "__future__":
                    continue
                if mod in ("jax.experimental", "jax.experimental.pallas"):
                    for a in st.names:
                        handle = _PALLAS_ALIASES.get(a.name)
                        if handle is None:
                            raise Unsupported(f"from {mod} import "
                                              f"{a.name}")
                        env.set(a.asname or a.name, ModuleHandle(handle))
                    continue
                interp.import_from(st, env)
                continue
            if isinstance(st, ast.Import):
                for a in st.names:
                    tgt = a.asname or a.name.split(".", 1)[0]
                    if a.name == "jax.numpy":
                        env.set(tgt, ModuleHandle("jnp"))
                    elif a.name in ("jax", "functools"):
                        env.set(tgt, ModuleHandle(a.name))
                    else:
                        raise Unsupported(f"import {a.name}")
                continue
            if isinstance(st, ast.Assign):
                value = interp.eval(st.value, env)
                for t in st.targets:
                    interp.assign(t, value, env)
                continue
            if isinstance(st, ast.FunctionDef):
                env.set(st.name, Closure(st, env, st.name, name))
                continue
            raise Unsupported(
                f"module-level {type(st).__name__} in {name}")
        for iname, fn in _INTERCEPTS[name].items():
            if iname in env.vars:
                env.vars[iname] = PyFn(fn, iname)
        envs[name] = env
    if sources is None:
        _ENV_CACHE.update(envs)
    return envs


# ---------------------------------------------------------------------------
# public API — one sanitize_* per dispatch family
# ---------------------------------------------------------------------------


def _run_entry(module, entry, args, kwargs, label, sources,
               expected_shape):
    findings: List[Finding] = []
    try:
        envs = load_kernel_modules(sources)
        interp = Interp(envs, label, findings)
        fn = envs[module].get(entry)
        out = interp.call(fn, args, kwargs)
        if isinstance(out, AArray) and expected_shape is not None \
                and out.shape != tuple(expected_shape):
            findings.append(Finding(
                "error", label, "K100",
                f"entry returned shape {out.shape}, the dispatch config "
                f"implies {tuple(expected_shape)}"))
    except KernelRaise as e:
        findings.append(Finding("error", label, "K100",
                                f"entry raised: {e}"))
    except Unsupported as e:
        findings.append(Finding("error", label, "K100",
                                f"unsupported construct: {e}"))
    except RecursionError:
        findings.append(Finding("error", label, "K100",
                                "interpreter recursion limit"))
    except Exception as e:  # internal inconsistency -> unproven, loudly
        findings.append(Finding(
            "error", label, "K100",
            f"sanitizer internal error ({type(e).__name__}: {e})"))
    return findings


def sanitize_conv2d(x_shape, w_shape, *, stride=(1, 1), padding=(0, 0),
                    relu=False, im2col=True, oc_block=128, oh_block=None,
                    pool_kernel=None, pool_stride=None, pool_kind="max",
                    pool_relu=False, lrn=None, pool_carry=None,
                    lrn_oc_block=None, sources=None, label=None):
    """Prove one (possibly pool/LRN-fused) SIMD conv dispatch.

    ``x_shape`` NHWC, ``w_shape`` HWIO — pass the PADDED operand shapes
    the engine actually dispatches.  ``pool_carry``/``lrn_oc_block``
    mirror the dispatch knobs (None = the resolvers' auto rule, re-
    derived here by Phase A).  Returns ``(findings, geom)`` where
    ``geom`` is the Phase-A band geometry for the K105 cross-check —
    ``carry`` is the input rows the sliding-window accumulator carries
    between bands (0 for classic cells) and ``steps`` the physical grid
    steps on the band axis (``n_tiles + 1`` with carry: step 0 is the
    sacrificial seed band).
    """
    n, h, wd, c = x_shape
    kh, kw, _, oc = w_shape
    sy, sx = stride
    py, px = padding
    entry = "conv2d_advanced_simd" if im2col else "conv2d_basic_simd"
    label = label or f"{entry}[{'x'.join(map(str, x_shape))}]"
    oh, ow = _a_out(h, kh, sy, py), _a_out(wd, kw, sx, px)
    wp = wd + 2 * px
    if pool_kernel is not None:
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        pool = (pkh, pkw, psy, psx)
    else:
        pool = None
    if not im2col:
        ocb, oc_halo = oc, 0
    elif lrn is not None and pool is not None:
        ocb, oc_halo = _a_resolve_lrn_ocb(oc, oc_block, lrn, lrn_oc_block,
                                          ow, wp, c, kh, kw, sy, pool)
    elif lrn is not None:
        ocb, oc_halo = oc, 0  # the entry raises (LRN needs a pool tail)
    else:
        ocb, oc_halo = min(oc_block, oc), 0
    kwargs = dict(stride=stride, padding=padding, relu=relu,
                  oh_block=oh_block, pool_kernel=pool_kernel,
                  pool_stride=pool_stride, pool_kind=pool_kind,
                  pool_relu=pool_relu, lrn=lrn)
    if im2col:
        kwargs["oc_block"] = oc_block
        kwargs["pool_carry"] = pool_carry
        kwargs["lrn_oc_block"] = lrn_oc_block
    if pool_kernel is not None:
        ph, pw = (oh - pkh) // psy + 1, (ow - pkw) // psx + 1
        if ph < 1 or pw < 1:
            return [Finding("error", label, "K100",
                            "pool window larger than conv output")], None
        blk, n_tiles = _a_resolve_ph(ph, oh, ow, wp, c, kh, kw, sy, ocb,
                                     pool, oh_block, im2col=im2col,
                                     oc_halo=oc_halo)
        carry_on = _a_resolve_pool_carry(pool_carry if im2col else False,
                                         im2col, lrn, pool, blk, n_tiles)
        carry = (pkh - psy) * sy if carry_on else 0
        geom = {"kind": "fused", "blk": blk, "n_tiles": n_tiles,
                "total": ph,
                "band": _a_band(_a_band(blk, pkh, psy), kh, sy) - carry,
                "row_step": blk * psy * sy, "in_base": 0,
                "carry": carry,
                "steps": n_tiles + (1 if carry_on else 0)}
        expected = (n, ph, pw, oc)
    else:
        blk = _a_resolve_oh(oh, ow, wp, c, kh, kw, sy, ocb, oh_block,
                            im2col=im2col)
        geom = {"kind": "conv", "blk": blk,
                "n_tiles": _ceil_div(oh, blk), "total": oh,
                "band": _a_band(blk, kh, sy), "row_step": blk * sy,
                "in_base": 0, "carry": 0, "steps": _ceil_div(oh, blk)}
        expected = (n, oh, ow, oc)
    x = AArray(x_shape, "io")
    w = AArray(w_shape, "io")
    b = AArray((oc,), "io")
    findings = _run_entry("conv2d", entry, [x, w, b], kwargs, label,
                          sources, expected)
    return findings, geom


def sanitize_pool2d(x_shape, *, kernel=(2, 2), stride=(2, 2), kind="max",
                    relu=False, oh_block=None, sources=None, label=None):
    """Prove one standalone Pallas pooling dispatch."""
    n, h, wd, c = x_shape
    kh, kw = kernel
    sy, sx = stride
    label = label or f"pool2d_nhwc[{'x'.join(map(str, x_shape))}]"
    oh, ow = _i_pool_out_size(h, kh, sy), _i_pool_out_size(wd, kw, sx)
    if oh < 1 or ow < 1:
        return [Finding("error", label, "K100",
                        "pool window larger than input")], None
    if oh_block is None:
        blk = _a_auto_oh_pool(oh, ow, wd, c, kh, sy)
    else:
        blk = max(1, min(oh_block, oh))
    geom = {"kind": "pool", "blk": blk, "n_tiles": _ceil_div(oh, blk),
            "total": oh, "band": _a_band(blk, kh, sy),
            "row_step": blk * sy, "in_base": 0, "carry": 0,
            "steps": _ceil_div(oh, blk)}
    x = AArray(x_shape, "io")
    findings = _run_entry(
        "pool2d", "pool2d_nhwc", [x],
        dict(kernel=kernel, stride=stride, kind=kind, relu=relu,
             oh_block=oh_block), label, sources, (n, oh, ow, c))
    return findings, geom


def sanitize_chain(x_shape, w_shapes, *, strides, paddings, relus,
                   im2col=True, oh_block=None, pool_kernel=None,
                   pool_stride=None, pool_kind="max", pool_relu=False,
                   lrn=None, oc_block_final=None, sources=None,
                   label=None):
    """Prove one fused conv→conv(→pool→LRN) chain dispatch.

    ``oc_block_final`` mirrors the dispatch knob: the final stage's oc
    grid is blocked (its channels nothing inside the cell consumes) and
    the Phase-A block walk re-derives the band under the shrunken
    resident-weights model.
    """
    n, h, wd, c = x_shape
    label = label or f"conv2d_chain_simd[{len(w_shapes)} stages]"
    chain = tuple((ws[0], ws[1], st[0], st[1], pd[0], pd[1])
                  for ws, st, pd in zip(w_shapes, strides, paddings))
    ocs = tuple(ws[3] for ws in w_shapes)
    if pool_kernel is not None:
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        pool = (pkh, pkw, psy, psx)
    else:
        pool = None
    obf = oc_block_final
    if obf is not None and (lrn is not None or obf >= ocs[-1]):
        obf = None  # the dispatch normalizes/rejects identically
    try:
        dims = _a_chain_dims(h, wd, c, chain, ocs)
        oh_f, ow_f, _, oc_f = dims[-1]
        if pool is not None:
            target = (oh_f - pool[0]) // pool[2] + 1
            out_cols = (ow_f - pool[1]) // pool[3] + 1
        else:
            target, out_cols = oh_f, ow_f
        blk, n_tiles = _a_resolve_chain(h, wd, c, chain, ocs, pool,
                                        oh_block, im2col=im2col,
                                        oc_block_final=obf)
        _, _, band, in_step, in_base = _a_chain_geom(blk, chain, pool)
    except KernelRaise as e:
        return [Finding("error", label, "K100",
                        f"chain geometry failed: {e}")], None
    geom = {"kind": "chain", "blk": blk, "n_tiles": n_tiles,
            "total": target, "band": band, "row_step": in_step,
            "in_base": in_base, "carry": 0, "steps": n_tiles}
    x = AArray(x_shape, "io")
    ws = [AArray(s, "io") for s in w_shapes]
    bs = [AArray((s[3],), "io") for s in w_shapes]
    findings = _run_entry(
        "conv2d", "conv2d_chain_simd", [x, ws, bs, strides, paddings,
                                        relus],
        dict(im2col=im2col, oh_block=oh_block, pool_kernel=pool_kernel,
             pool_stride=pool_stride, pool_kind=pool_kind,
             pool_relu=pool_relu, lrn=lrn, oc_block_final=oc_block_final),
        label, sources, (n, target, out_cols, oc_f))
    return findings, geom


def sanitize_matmul(x_shape, w_shape, *, has_bias=True, act="none",
                    sources=None, label=None):
    """Prove one fused bias+activation matmul dispatch."""
    m_dim, k_dim = x_shape
    _, n_dim = w_shape
    label = label or f"matmul_fused_pallas[{m_dim}x{k_dim}x{n_dim}]"
    x = AArray(x_shape, "io")
    w = AArray(w_shape, "io")
    b = AArray((n_dim,), "io") if has_bias else None
    findings = _run_entry("matmul", "matmul_fused_pallas", [x, w, b],
                          dict(act=act), label, sources, (m_dim, n_dim))
    return findings, None
