from repro.serving.cnn import (CNNServer, FailedResult, ImageRequest,
                               ImageResult, NonFiniteInputError,
                               ServerWedgedError, ShedResult,
                               SupervisorConfig)
from repro.serving.degrade import DegradeController, Rung, default_ladder
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import (EngineFault, FaultInjector, FaultScript,
                                  PersistentEngineFault,
                                  TransientEngineFault)

__all__ = ["CNNServer", "DegradeController", "EngineFault", "FailedResult",
           "FaultInjector", "FaultScript", "ImageRequest", "ImageResult",
           "NonFiniteInputError", "PersistentEngineFault", "Request",
           "Rung", "ServerWedgedError", "ServingEngine", "ShedResult",
           "SupervisorConfig", "TransientEngineFault", "default_ladder"]
