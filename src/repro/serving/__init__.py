from repro.serving.cnn import CNNServer, ImageRequest, ImageResult
from repro.serving.engine import Request, ServingEngine

__all__ = ["CNNServer", "ImageRequest", "ImageResult", "Request",
           "ServingEngine"]
