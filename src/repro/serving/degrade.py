"""Graceful method-degradation for the CNN serving path.

The paper's method ladder (``seq_ref → … → advanced_simd_8``) is a
latency/throughput trade the server can walk at runtime: under
sustained overload it is better to serve every request on a cheaper
rung than to miss every deadline on the fastest one (the
resource-modeling argument of arxiv 1709.09503, and the AI-Benchmark
router's load-shedding/downgrade fallback, arxiv 1810.01109).

* ``Rung`` — one candidate configuration: an execution ``Method`` plus
  the ``fuse`` flag.  ``default_ladder`` derives the conventional walk
  (``advanced_simd_8 → advanced_simd_4 → basic_simd``, then
  fused→unfused as the floor).
* ``DegradeController`` — pure-state hysteresis logic, no engine
  coupling: ``observe(queue_depth, p95_s)`` classifies each serving
  step as pressured (queue above ``queue_high`` or p95 above the
  ``p95_slo_s`` target) or calm, and recommends ``"down"`` only after
  ``degrade_after`` *consecutive* pressured observations, ``"up"`` only
  after ``recover_after`` consecutive calm ones, with a ``cooldown``
  dead-band after every committed move so the controller cannot flap
  between adjacent rungs on oscillating load.

The controller never touches the engine.  ``CNNServer`` owns the
application: each candidate rung is pre-validated through
``CNNEngine.switch_verified`` (the static plan verifier runs before the
knobs stick — an unverifiable rung is skipped, never served), and the
knob setters' cache invalidation (PR 5) guarantees the next batch runs
the new plan, not a stale one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.methods import Method

#: SIMD rungs in descending-performance order (the degradation walk);
#: seq_ref/basic_parallel stay off the ladder — they are reference
#: implementations, not serving configurations.
_DESCENT = (Method.ADVANCED_SIMD_8, Method.ADVANCED_SIMD_4,
            Method.BASIC_SIMD)


@dataclasses.dataclass(frozen=True)
class Rung:
    """One serving configuration on the degradation ladder."""
    method: Method
    fuse: bool = True

    @property
    def label(self) -> str:
        return f"{self.method.value}/{'fused' if self.fuse else 'unfused'}"


def default_ladder(method: Method = Method.ADVANCED_SIMD_8,
                   fuse: bool = True) -> Tuple[Rung, ...]:
    """The conventional walk from ``method`` down: each remaining SIMD
    rung at the caller's fuse setting, then an unfused ``basic_simd``
    floor (the cheapest configuration that still serves)."""
    start = _DESCENT.index(method) if method in _DESCENT else 0
    rungs = [Rung(m, fuse) for m in _DESCENT[start:]]
    floor = Rung(Method.BASIC_SIMD, False)
    if rungs[-1] != floor:
        rungs.append(floor)
    return tuple(rungs)


class DegradeController:
    """Hysteresis state machine over a degradation ladder.

    ``rung`` indexes the *currently committed* ladder entry (0 = the
    configured, fastest rung).  The server calls ``observe`` once per
    serving step and, when a move is recommended, tries
    ``candidates(direction)`` in order until one rung verifies, then
    ``commit``\\ s it.
    """

    def __init__(self, ladder: Sequence[Rung], *, queue_high: int = 32,
                 p95_slo_s: Optional[float] = None, degrade_after: int = 3,
                 recover_after: int = 8, cooldown: int = 4):
        if not ladder:
            raise ValueError("degradation ladder must have >= 1 rung")
        if degrade_after < 1 or recover_after < 1:
            raise ValueError("degrade_after/recover_after must be >= 1")
        if queue_high < 0 or cooldown < 0:
            raise ValueError("queue_high/cooldown must be >= 0")
        self.ladder: Tuple[Rung, ...] = tuple(ladder)
        self.queue_high = queue_high
        self.p95_slo_s = p95_slo_s
        self.degrade_after = degrade_after
        self.recover_after = recover_after
        self.cooldown = cooldown
        self.rung = 0
        self.moves: List[int] = []  # committed rung indices, in order
        self._hot = 0    # consecutive pressured observations
        self._calm = 0   # consecutive calm observations
        self._hold = 0   # cooldown observations left before the next move

    def pressured(self, queue_depth: int,
                  p95_s: Optional[float] = None) -> bool:
        """One observation's verdict: queue pressure OR p95-vs-SLO
        drift (either alone is overload)."""
        if queue_depth > self.queue_high:
            return True
        return (self.p95_slo_s is not None and p95_s is not None
                and p95_s > self.p95_slo_s)

    def observe(self, *, queue_depth: int,
                p95_s: Optional[float] = None) -> Optional[str]:
        """Classify one serving step; return ``"down"``/``"up"`` when
        the hysteresis thresholds are met (and a move is possible), else
        ``None``.  The streak counters keep accumulating through the
        cooldown dead-band — pressure during cooldown is not forgotten,
        it just cannot trigger a move yet."""
        if self.pressured(queue_depth, p95_s):
            self._hot += 1
            self._calm = 0
        else:
            self._calm += 1
            self._hot = 0
        if self._hold > 0:
            self._hold -= 1
            return None
        if self._hot >= self.degrade_after and self.rung < len(self.ladder) - 1:
            return "down"
        if self._calm >= self.recover_after and self.rung > 0:
            return "up"
        return None

    def candidates(self, direction: str) -> List[int]:
        """Rung indices to try for a move, nearest first — the server
        walks these until one statically verifies (an unverifiable rung
        is skipped, not served)."""
        if direction == "down":
            return list(range(self.rung + 1, len(self.ladder)))
        if direction == "up":
            return list(range(self.rung - 1, -1, -1))
        raise ValueError(f"unknown direction {direction!r}")

    def commit(self, idx: int) -> None:
        """Record a verified switch to ``ladder[idx]`` and arm the
        cooldown dead-band (the hysteresis half that stops flapping)."""
        if not 0 <= idx < len(self.ladder):
            raise ValueError(f"rung index {idx} out of range")
        self.rung = idx
        self.moves.append(idx)
        self._hot = 0
        self._calm = 0
        self._hold = self.cooldown

    def snapshot(self) -> dict:
        """Introspection for ``CNNServer.health()``."""
        return {"rung": self.rung, "label": self.ladder[self.rung].label,
                "ladder": [r.label for r in self.ladder],
                "hot": self._hot, "calm": self._calm, "cooldown": self._hold,
                "moves": list(self.moves)}
