"""Batched CNN serving front-end — the paper's actual deployment
scenario (forward-only classification of incoming frames, §6.2 runs
batches of 16), complementing the token-shaped ``ServingEngine``.

``CNNServer`` queues per-image classification requests and serves them
in **dynamic batches**:

* ``submit`` enqueues an ``ImageRequest`` (one ``[C, H, W]`` frame) with
  its arrival timestamp;
* ``step`` forms at most one batch: it flushes when ``max_batch``
  requests are waiting OR the oldest request has aged past
  ``max_delay_s`` (the deadline — a lone request never waits forever),
  taking the oldest ``max_batch`` requests FIFO;
* the batch runs through the engine's **batch-bucketed jit cache**
  (``CNNEngine.forward_batched``: pad up to the power-of-two bucket,
  run the memoized jitted plan, slice the real rows back out), so a
  ragged flush of 5 frames reuses the bucket-8 compilation instead of
  paying a fresh trace;
* each request resolves to an ``ImageResult`` with its top-k classes
  and probabilities plus the submit→complete latency and the dynamic
  batch it rode in.

``stats()`` reports the serving-scale numbers the benchmarks record:
requests served, batches formed, mean batch size, p50/p95 latency, and
throughput over the server's busy time.  The clock is injectable so
deadline behaviour is testable without real sleeps.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import CNNEngine


@dataclasses.dataclass
class ImageRequest:
    """One classification request: a single ``[C, H, W]`` frame."""
    rid: int
    image: "np.ndarray"
    top_k: int = 5


@dataclasses.dataclass
class ImageResult:
    """Top-k classes (descending probability) plus serving metadata."""
    rid: int
    top_indices: List[int]
    top_probs: List[float]
    latency_s: float      # submit -> result available
    batch_size: int       # real requests in the dynamic batch it rode in
    bucket: int           # the padded power-of-two bucket that executed


class CNNServer:
    """Dynamic-batching front-end over a ``CNNEngine``.

    The server is step-driven (no background threads): callers submit
    requests, then drive ``step()`` — each call serves at most one
    dynamic batch — or ``run_until_drained()``.  Batches never mix
    configurations: the engine's plan and the ``fuse`` flag are fixed
    per server.
    """

    def __init__(self, engine: CNNEngine, params, *, max_batch: int = 16,
                 max_delay_s: float = 2e-3, fuse: Optional[bool] = None,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        self.engine = engine
        self.params = params
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.fuse = fuse
        self.clock = clock
        self._input_shape = tuple(engine.net.input_shape)
        self._pending: Deque[Tuple[ImageRequest, float]] = deque()
        self.done: Dict[int, ImageResult] = {}
        self.reset_stats()

    # -- client API -----------------------------------------------------------
    def submit(self, req: ImageRequest) -> None:
        """Enqueue one request (validated against the net's input shape);
        it is served by a later ``step()``."""
        img = np.asarray(req.image)
        if tuple(img.shape) != self._input_shape:
            raise ValueError(
                f"request {req.rid}: image shape {tuple(img.shape)} does not "
                f"match the network input {self._input_shape}")
        self._pending.append((req, self.clock()))

    def pending(self) -> int:
        return len(self._pending)

    def pop_result(self, rid: int) -> Optional[ImageResult]:
        """Retrieve-and-remove a finished request's result (None when not
        done yet).  Long-lived servers should drain ``done`` through this
        — results otherwise accumulate for the server's lifetime."""
        return self.done.pop(rid, None)

    # -- serving loop -----------------------------------------------------------
    def _should_flush(self, force: bool) -> bool:
        if not self._pending:
            return False
        if force or len(self._pending) >= self.max_batch:
            return True
        oldest_t = self._pending[0][1]
        return (self.clock() - oldest_t) >= self.max_delay_s

    def step(self, force: bool = False) -> List[ImageResult]:
        """Serve at most one dynamic batch.  Flushes when a full
        ``max_batch`` is waiting, the oldest request has exceeded the
        ``max_delay_s`` deadline, or ``force`` is set; otherwise returns
        ``[]`` and keeps queueing."""
        if not self._should_flush(force):
            return []
        take = min(len(self._pending), self.max_batch)
        batch = [self._pending.popleft() for _ in range(take)]
        x = jnp.asarray(np.stack([np.asarray(r.image, np.float32)
                                  for r, _ in batch]))
        t0 = self.clock()
        probs = self.engine.forward_batched(self.params, x, fuse=self.fuse)
        probs = np.asarray(probs)  # blocks until the batch is done
        t1 = self.clock()
        self._busy_s += t1 - t0
        self._batch_sizes.append(take)
        bucket = CNNEngine.batch_bucket(take)
        results = []
        for i, (req, t_sub) in enumerate(batch):
            p = probs[i]
            k = max(1, min(req.top_k, p.shape[-1]))
            top = np.argsort(-p, kind="stable")[:k]
            res = ImageResult(
                rid=req.rid, top_indices=[int(j) for j in top],
                top_probs=[float(p[j]) for j in top],
                latency_s=t1 - t_sub, batch_size=take, bucket=bucket)
            self.done[req.rid] = res
            self._latencies_s.append(res.latency_s)
            results.append(res)
        return results

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, ImageResult]:
        """Serve everything queued (forcing ragged final batches rather
        than waiting out the deadline) and return ``{rid: result}``."""
        steps = 0
        while self._pending and steps < max_steps:
            self.step(force=True)
            steps += 1
        return self.done

    # -- stats -----------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the latency/throughput accumulators (results in ``done``
        are kept; benches call this after warm-up so compile time never
        pollutes the serving numbers)."""
        self._latencies_s: List[float] = []
        self._batch_sizes: List[int] = []
        self._busy_s = 0.0

    def stats(self) -> dict:
        """Serving-scale numbers since the last ``reset_stats()``:
        requests/batches served, mean batch size, p50/p95 submit→done
        latency (us), and throughput (requests per second of server busy
        time — queue idle time between steps is not charged)."""
        served = len(self._latencies_s)
        out = {
            "served": served,
            "batches": len(self._batch_sizes),
            "mean_batch": (float(np.mean(self._batch_sizes))
                           if self._batch_sizes else 0.0),
            "busy_s": self._busy_s,
            "buckets": self.engine.bucket_stats()["buckets"],
        }
        if served:
            lat = np.asarray(self._latencies_s)
            out["p50_latency_us"] = float(np.percentile(lat, 50) * 1e6)
            out["p95_latency_us"] = float(np.percentile(lat, 95) * 1e6)
            out["throughput_rps"] = (served / self._busy_s
                                     if self._busy_s > 0 else float("inf"))
        return out
