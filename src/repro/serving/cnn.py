"""Batched CNN serving front-end — the paper's actual deployment
scenario (forward-only classification of incoming frames, §6.2 runs
batches of 16), complementing the token-shaped ``ServingEngine``.

``CNNServer`` queues per-image classification requests and serves them
in **dynamic batches**:

* ``submit`` enqueues an ``ImageRequest`` (one ``[C, H, W]`` frame)
  after admission control: the frame is validated (shape AND
  finiteness — a NaN frame would poison every batchmate's softmax),
  the bounded queue rejects on full, and a request whose deadline is
  already unmeetable is shed up front.  A shed request resolves to a
  typed ``ShedResult`` (returned AND recorded in ``done``) — never a
  silent drop;
* ``step`` first expires queued requests whose deadline has passed,
  then forms at most one batch: it flushes when ``max_batch`` requests
  are waiting OR the oldest request has aged past ``max_delay_s``,
  taking the oldest ``max_batch`` requests FIFO;
* the batch runs under a **supervised executor**: transient engine
  faults retry with capped exponential backoff, a repeatedly-failing
  batch bisects to isolate the poison request (the bad frame fails
  alone with a typed ``FailedResult``; its batchmates still get
  answers — bisection sub-batches keep the parent batch's pow2 bucket,
  so surviving rows are byte-identical to a fault-free run), and
  non-finite output rows become per-request failures instead of
  garbage top-k;
* a **circuit breaker** trips the server into an unhealthy state after
  repeated supervisor-level failures (admission sheds while open,
  half-open probe after ``breaker_reset_s``), and an optional
  **degradation controller** (``serving.degrade``) walks the method
  ladder under sustained queue pressure or p95-vs-SLO drift — every
  candidate rung pre-validated through ``CNNEngine.switch_verified``
  before it is served;
* each served request resolves to an ``ImageResult`` with its top-k
  classes and probabilities plus the submit→complete latency and the
  dynamic batch it rode in.

``stats()`` reports the serving-scale numbers the benchmarks record
(requests served, batches, p50/p95 latency, throughput over busy time)
plus the robustness counters (shed/rejected/expired/retried/failed/
degraded/breaker trips); ``health()`` snapshots the live state.  The
clock, the backoff sleep, and the engine-fault schedule
(``serving.faults``) are all injectable, so every recovery path is
deterministic under test — no real sleeps anywhere.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax.numpy as jnp
import numpy as np

from repro.core.engine import CNNEngine
from repro.serving.degrade import DegradeController
from repro.serving.faults import FaultInjector, TransientEngineFault


class NonFiniteInputError(ValueError):
    """A submitted frame contains NaN/Inf — rejected at admission (one
    non-finite frame would otherwise poison every batchmate's softmax)."""


class ServerWedgedError(RuntimeError):
    """``run_until_drained`` exhausted its step budget with requests
    still pending — the queue is wedged (e.g. breaker open), and the
    caller must not mistake that for a drained server.  Carries the
    undrained ``report``."""

    def __init__(self, report: dict):
        self.report = report
        super().__init__(
            f"server not drained after {report['steps']} steps: "
            f"{report['pending']} request(s) still pending "
            f"(rids {report['pending_rids']}); health={report['health']}")


@dataclasses.dataclass
class ImageRequest:
    """One classification request: a single ``[C, H, W]`` frame.

    ``deadline_s`` is the per-request SLO, relative to submit time
    (``None`` falls back to the server's ``default_deadline_s``; both
    ``None`` means no deadline).  A request that cannot make its
    deadline is shed at admission or expired from the queue — never
    served late into a consumer that already gave up on it.
    """
    rid: int
    image: "np.ndarray"
    top_k: int = 5
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class ImageResult:
    """Top-k classes (descending probability) plus serving metadata."""
    rid: int
    top_indices: List[int]
    top_probs: List[float]
    latency_s: float      # submit -> result available
    batch_size: int       # real requests in the dynamic batch it rode in
    bucket: int           # the padded power-of-two bucket that executed
    ok: bool = True


@dataclasses.dataclass
class ShedResult:
    """A request the server declined to serve — typed, never silent.

    ``reason`` is one of ``queue_full`` (bounded admission queue),
    ``admission_deadline`` (the deadline is unmeetable even if served
    immediately), ``deadline_expired`` (aged out while queued), or
    ``breaker_open`` (the circuit breaker is shedding load).
    """
    rid: int
    reason: str
    detail: str = ""
    waited_s: float = 0.0
    ok: bool = False


@dataclasses.dataclass
class FailedResult:
    """A request the supervised executor could not serve — typed.

    ``error`` is ``engine_fault`` (the request fails alone after
    retry + bisection) or ``non_finite_output`` (its output row was
    NaN/Inf — detected, not served as garbage top-k).
    """
    rid: int
    error: str
    detail: str
    latency_s: float
    batch_size: int
    bucket: int
    ok: bool = False


#: everything a request can terminally resolve to
Result = Union[ImageResult, ShedResult, FailedResult]


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Retry/backoff + circuit-breaker policy for the supervised
    executor.  Backoff for attempt ``i`` is
    ``min(backoff_cap_s, backoff_base_s * 2**i)`` through the
    injectable ``sleep``; the breaker opens after
    ``breaker_threshold`` *consecutive* steps that produced at least
    one terminal failure, and half-opens ``breaker_reset_s`` after it
    tripped (one probe batch: success closes, failure re-opens)."""
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.25
    breaker_threshold: int = 3
    breaker_reset_s: float = 1.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff times must be >= 0")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if self.breaker_reset_s < 0:
            raise ValueError("breaker_reset_s must be >= 0")


#: queued entry: (request, submit time, absolute deadline or None)
_Pending = Tuple[ImageRequest, float, Optional[float]]

_COUNTERS = ("shed", "rejected", "expired", "retried", "failed",
             "degraded", "recovered", "breaker_trips", "bisections")


class CNNServer:
    """Dynamic-batching, fault-tolerant front-end over a ``CNNEngine``.

    The server is step-driven (no background threads): callers submit
    requests, then drive ``step()`` — each call serves at most one
    dynamic batch — or ``run_until_drained()``.  Batches never mix
    configurations: a degradation move lands between steps (the knob
    setters invalidate the plan/jit caches, so the next batch runs the
    newly-verified plan).
    """

    def __init__(self, engine: CNNEngine, params, *, max_batch: int = 16,
                 max_delay_s: float = 2e-3, fuse: Optional[bool] = None,
                 clock: Callable[[], float] = time.monotonic,
                 max_queue: int = 1024,
                 default_deadline_s: Optional[float] = None,
                 supervisor: Optional[SupervisorConfig] = None,
                 degrade: Optional[DegradeController] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 sleep: Callable[[float], None] = time.sleep):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {max_delay_s}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.engine = engine
        self.params = params
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.fuse = fuse
        self.clock = clock
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        self.supervisor = supervisor or SupervisorConfig()
        self.degrade = degrade
        self.fault_injector = fault_injector
        self.sleep = sleep
        self._input_shape = tuple(engine.net.input_shape)
        self._pending: Deque[_Pending] = deque()
        self.done: Dict[int, Result] = {}
        # circuit breaker: closed -> (threshold consecutive failing
        # steps) -> open -> (reset_s) -> half_open -> closed/open
        self._breaker = "closed"
        self._breaker_opened_t = 0.0
        self._consec_failures = 0
        # EWMA of measured batch service time (admission's "can this
        # deadline possibly be met" floor); 0.0 until the first batch
        self._service_ewma_s = 0.0
        self.events: Deque[dict] = deque(maxlen=256)
        # sliding window feeding the degradation controller's p95 —
        # distinct from _latencies_s so reset_stats keeps pressure
        # detection alive across bench warm-up resets
        self._recent_lat_s: Deque[float] = deque(maxlen=128)
        self.reset_stats()

    # -- client API -----------------------------------------------------------
    def submit(self, req: ImageRequest) -> Optional[ShedResult]:
        """Admission control + enqueue.  Returns ``None`` when the
        request was admitted (it is served by a later ``step()``), or
        the typed ``ShedResult`` (also recorded in ``done``) when it
        was shed at admission.  Invalid frames (wrong shape, NaN/Inf)
        raise — they are caller bugs, not load."""
        img = np.asarray(req.image)
        if tuple(img.shape) != self._input_shape:
            raise ValueError(
                f"request {req.rid}: image shape {tuple(img.shape)} does not "
                f"match the network input {self._input_shape}")
        if not np.all(np.isfinite(img)):
            raise NonFiniteInputError(
                f"request {req.rid}: image contains non-finite values "
                f"(NaN/Inf frames are rejected at admission — one would "
                f"poison every batchmate's softmax)")
        now = self.clock()
        if self._breaker == "open" and not self._breaker_ready(now):
            return self._shed(req, "breaker_open",
                              "circuit breaker is open", waited_s=0.0)
        deadline_s = (req.deadline_s if req.deadline_s is not None
                      else self.default_deadline_s)
        if deadline_s is not None and (
                deadline_s <= 0.0 or deadline_s < self._service_ewma_s):
            return self._shed(
                req, "admission_deadline",
                f"deadline {deadline_s:g}s cannot be met (estimated "
                f"service time {self._service_ewma_s:g}s)", waited_s=0.0)
        if len(self._pending) >= self.max_queue:
            self._counters["rejected"] += 1
            return self._shed(req, "queue_full",
                              f"admission queue at max_queue={self.max_queue}",
                              waited_s=0.0)
        deadline_t = None if deadline_s is None else now + deadline_s
        self._pending.append((req, now, deadline_t))
        return None

    def pending(self) -> int:
        return len(self._pending)

    def pop_result(self, rid: int) -> Optional[Result]:
        """Retrieve-and-remove a finished request's result (None when not
        done yet).  Long-lived servers should drain ``done`` through this
        — results otherwise accumulate for the server's lifetime."""
        return self.done.pop(rid, None)

    # -- shedding ---------------------------------------------------------------
    def _shed(self, req: ImageRequest, reason: str, detail: str,
              waited_s: float) -> ShedResult:
        res = ShedResult(rid=req.rid, reason=reason, detail=detail,
                         waited_s=waited_s)
        self.done[req.rid] = res
        self._counters["shed"] += 1
        self.events.append({"kind": "shed", "rid": req.rid, "reason": reason})
        return res

    def _expire_deadlines(self, now: float) -> List[ShedResult]:
        """Shed every queued request whose absolute deadline has passed
        (FIFO order preserved among the survivors)."""
        if not any(d is not None for _, _, d in self._pending):
            return []
        out: List[ShedResult] = []
        keep: Deque[_Pending] = deque()
        for req, t_sub, deadline_t in self._pending:
            if deadline_t is not None and now >= deadline_t:
                self._counters["expired"] += 1
                out.append(self._shed(
                    req, "deadline_expired",
                    f"deadline passed after {now - t_sub:g}s in queue",
                    waited_s=now - t_sub))
            else:
                keep.append((req, t_sub, deadline_t))
        self._pending = keep
        return out

    # -- breaker ----------------------------------------------------------------
    def _breaker_ready(self, now: float) -> bool:
        return (now - self._breaker_opened_t) >= self.supervisor.breaker_reset_s

    def _trip_breaker(self, now: float) -> None:
        self._breaker = "open"
        self._breaker_opened_t = now
        self._counters["breaker_trips"] += 1
        self.events.append({"kind": "breaker_open", "t": now})

    def _breaker_after_step(self, now: float, any_failed: bool) -> None:
        if any_failed:
            self._consec_failures += 1
            if self._breaker == "half_open":
                self._trip_breaker(now)  # the probe failed: re-open
            elif (self._breaker == "closed" and self._consec_failures
                    >= self.supervisor.breaker_threshold):
                self._trip_breaker(now)
        else:
            self._consec_failures = 0
            if self._breaker == "half_open":
                self._breaker = "closed"
                self.events.append({"kind": "breaker_closed", "t": now})

    # -- supervised execution ---------------------------------------------------
    def _invoke(self, xs: np.ndarray, rids: Sequence[int],
                bucket: int) -> np.ndarray:
        """One engine invocation, padded to ``bucket`` — bisection
        sub-batches keep the PARENT batch's bucket, so they reuse the
        same compiled executable and surviving rows stay byte-identical
        to a fault-free run (zero-pad rows are inert batchmates)."""
        x = jnp.asarray(xs)
        if x.shape[0] < bucket:
            pad = jnp.zeros((bucket - x.shape[0], *x.shape[1:]), x.dtype)
            x = jnp.concatenate([x, pad], axis=0)

        def call(arr):
            return self.engine.forward_batched(self.params, arr,
                                               fuse=self.fuse)

        if self.fault_injector is not None:
            probs = self.fault_injector(call, x, rids)
        else:
            probs = call(x)
        return np.asarray(probs)[:len(rids)]

    def _supervise(self, xs: np.ndarray, rids: List[int],
                   bucket: int) -> List[Tuple[str, object]]:
        """Run one (sub-)batch with retry/backoff, bisecting on
        unrecoverable failure.  Returns one ``("ok", probs_row)`` or
        ``("fail", detail)`` per request, in request order."""
        sup = self.supervisor
        attempt = 0
        last_err: Optional[BaseException] = None
        while True:
            try:
                probs = self._invoke(xs, rids, bucket)
                return [("ok", probs[i]) for i in range(len(rids))]
            except TransientEngineFault as e:
                last_err = e  # typed transient: retry with backoff
                if attempt >= sup.max_retries:
                    break
                self._counters["retried"] += 1
                self.sleep(min(sup.backoff_cap_s,
                               sup.backoff_base_s * (2 ** attempt)))
                attempt += 1
            except Exception as e:  # noqa: BLE001 — recorded, then bisected
                last_err = e  # persistent/unknown: retrying cannot help
                break
        detail = f"{type(last_err).__name__}: {last_err}"
        if len(rids) == 1:
            self.events.append({"kind": "request_failed", "rid": rids[0],
                                "detail": detail})
            return [("fail", detail)]
        # bisect to isolate the poison request: each half re-enters the
        # supervisor with a fresh retry budget and the parent's bucket
        self._counters["bisections"] += 1
        self.events.append({"kind": "bisect", "rids": list(rids),
                            "detail": detail})
        mid = (len(rids) + 1) // 2
        return (self._supervise(xs[:mid], rids[:mid], bucket)
                + self._supervise(xs[mid:], rids[mid:], bucket))

    # -- serving loop -----------------------------------------------------------
    def _should_flush(self, force: bool) -> bool:
        if not self._pending:
            return False
        if force or len(self._pending) >= self.max_batch:
            return True
        oldest_t = self._pending[0][1]
        return (self.clock() - oldest_t) >= self.max_delay_s

    def step(self, force: bool = False) -> List[Result]:
        """Serve at most one dynamic batch.  Returns every request that
        reached a terminal result during this step — ``ImageResult``\\ s
        for the served batch, plus any ``ShedResult``\\ s expired from
        the queue and ``FailedResult``\\ s the supervisor isolated.
        Flushes when a full ``max_batch`` is waiting, the oldest request
        has exceeded the ``max_delay_s`` deadline, or ``force`` is set;
        otherwise serves nothing and keeps queueing."""
        now = self.clock()
        if self._breaker == "open":
            if not self._breaker_ready(now):
                self._observe_degrade()
                return []
            self._breaker = "half_open"  # one probe batch allowed
            self.events.append({"kind": "breaker_half_open", "t": now})
        results: List[Result] = list(self._expire_deadlines(now))
        if not self._should_flush(force):
            self._observe_degrade()
            return results
        take = min(len(self._pending), self.max_batch)
        batch = [self._pending.popleft() for _ in range(take)]
        xs = np.stack([np.asarray(r.image, np.float32)
                       for r, _, _ in batch])
        rids = [r.rid for r, _, _ in batch]
        bucket = CNNEngine.batch_bucket(take)
        t0 = self.clock()
        rows = self._supervise(xs, rids, bucket)
        t1 = self.clock()
        self._busy_s += t1 - t0
        self._batch_sizes.append(take)
        dt = t1 - t0
        self._service_ewma_s = (dt if self._service_ewma_s == 0.0
                                else 0.5 * self._service_ewma_s + 0.5 * dt)
        any_failed = False
        for (req, t_sub, _), (status, payload) in zip(batch, rows):
            res: Result
            if status == "fail":
                res = FailedResult(
                    rid=req.rid, error="engine_fault", detail=str(payload),
                    latency_s=t1 - t_sub, batch_size=take, bucket=bucket)
            else:
                p = np.asarray(payload)
                if not np.all(np.isfinite(p)):
                    res = FailedResult(
                        rid=req.rid, error="non_finite_output",
                        detail="output row contains NaN/Inf",
                        latency_s=t1 - t_sub, batch_size=take, bucket=bucket)
                else:
                    k = max(1, min(req.top_k, p.shape[-1]))
                    top = np.argsort(-p, kind="stable")[:k]
                    res = ImageResult(
                        rid=req.rid, top_indices=[int(j) for j in top],
                        top_probs=[float(p[j]) for j in top],
                        latency_s=t1 - t_sub, batch_size=take, bucket=bucket)
                    self._latencies_s.append(res.latency_s)
                    self._recent_lat_s.append(res.latency_s)
            if not res.ok:
                any_failed = True
                self._counters["failed"] += 1
            self.done[req.rid] = res
            results.append(res)
        self._breaker_after_step(t1, any_failed)
        self._observe_degrade()
        return results

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Result]:
        """Serve everything queued (forcing ragged final batches rather
        than waiting out the deadline) and return ``{rid: result}``.
        Raises ``ServerWedgedError`` when ``max_steps`` is exhausted with
        requests still pending — a wedged queue (e.g. the breaker is
        open) must never be mistaken for a drained one."""
        steps = 0
        while self._pending and steps < max_steps:
            self.step(force=True)
            steps += 1
        if self._pending:
            raise ServerWedgedError({
                "steps": steps,
                "pending": len(self._pending),
                "pending_rids": [r.rid for r, _, _ in self._pending],
                "health": self.health(),
            })
        return self.done

    # -- degradation ------------------------------------------------------------
    def _recent_p95_s(self) -> Optional[float]:
        if not self._recent_lat_s:
            return None
        return float(np.percentile(np.asarray(self._recent_lat_s), 95))

    def _observe_degrade(self) -> None:
        if self.degrade is None:
            return
        action = self.degrade.observe(queue_depth=len(self._pending),
                                      p95_s=self._recent_p95_s())
        if action is not None:
            self._apply_rung(action)

    def _apply_rung(self, direction: str) -> None:
        """Walk the ladder in ``direction``, committing the first rung
        whose plan ``CNNEngine.switch_verified`` statically blesses —
        an unverifiable rung is skipped (recorded), never served."""
        ctl = self.degrade
        for idx in ctl.candidates(direction):
            rung = ctl.ladder[idx]
            ok, findings = self.engine.switch_verified(
                method=rung.method, fuse_pool=rung.fuse)
            if ok:
                self.fuse = None  # serve on the engine's verified fuse_pool
                ctl.commit(idx)
                key = "degraded" if direction == "down" else "recovered"
                self._counters[key] += 1
                self.events.append({"kind": key, "rung": rung.label,
                                    "index": idx})
                return
            self.events.append({
                "kind": "rung_rejected", "rung": rung.label, "index": idx,
                "findings": [str(f) for f in findings
                             if f.severity == "error"]})

    # -- stats / health ---------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the latency/throughput accumulators and robustness
        counters (results in ``done`` and live state — breaker,
        degradation rung — are kept; benches call this after warm-up so
        compile time never pollutes the serving numbers)."""
        self._latencies_s: List[float] = []
        self._batch_sizes: List[int] = []
        self._busy_s = 0.0
        self._counters: Dict[str, int] = {k: 0 for k in _COUNTERS}

    def stats(self) -> dict:
        """Serving-scale numbers since the last ``reset_stats()``:
        requests/batches served, mean batch size, p50/p95 submit→done
        latency (us), throughput (requests per second of server busy
        time — ``0.0`` when no busy time was accrued, never ``inf``),
        and the robustness counters."""
        served = len(self._latencies_s)
        out = {
            "served": served,
            "batches": len(self._batch_sizes),
            "mean_batch": (float(np.mean(self._batch_sizes))
                           if self._batch_sizes else 0.0),
            "busy_s": self._busy_s,
            "buckets": self.engine.bucket_stats()["buckets"],
            **self._counters,
        }
        if served:
            lat = np.asarray(self._latencies_s)
            out["p50_latency_us"] = float(np.percentile(lat, 50) * 1e6)
            out["p95_latency_us"] = float(np.percentile(lat, 95) * 1e6)
            out["throughput_rps"] = (served / self._busy_s
                                     if self._busy_s > 0 else 0.0)
        return out

    def health(self) -> dict:
        """Live robustness snapshot: overall ``state`` (``healthy`` /
        ``degraded`` — running below the top rung or probing half-open
        — / ``unhealthy`` — breaker open), breaker detail, queue depth,
        and the committed degradation rung."""
        if self._breaker == "open":
            state = "unhealthy"
        elif (self._breaker == "half_open"
                or (self.degrade is not None and self.degrade.rung > 0)):
            state = "degraded"
        else:
            state = "healthy"
        return {
            "state": state,
            "breaker": self._breaker,
            "consecutive_failures": self._consec_failures,
            "pending": len(self._pending),
            "method": self.engine.method.value,
            "service_estimate_s": self._service_ewma_s,
            "degrade": (None if self.degrade is None
                        else self.degrade.snapshot()),
        }
