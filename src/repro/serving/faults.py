"""Deterministic fault-injection harness for the CNN serving path.

Every recovery path in ``CNNServer`` (retry/backoff, poison-batch
bisection, non-finite-row detection, circuit breaker, degradation under
latency drift) is exercised in tier-1 tests through this module — no
real sleeps, no flaky timing: faults fire on a **scripted schedule**
keyed by the engine-invocation index and by request id.

* ``FaultScript`` — the schedule.  ``transient_calls`` /
  ``persistent_calls`` name the 0-based invocation indices that raise
  ``TransientEngineFault`` / ``PersistentEngineFault``;
  ``latency_spikes`` maps an invocation index to seconds added through
  the injectable clock-advance hook (so p95-vs-SLO drift is scriptable
  under a fake clock); ``poison_rids`` fail every invocation whose
  sub-batch contains one of those request ids (the bisection target: a
  poison frame fails any batch it rides in, alone included);
  ``corrupt_rids`` overwrite those requests' output rows with NaN (the
  garbage-top-k class the server must convert into typed per-request
  failures).
* ``FaultInjector`` — wraps the engine call.  ``CNNServer`` passes every
  supervised invocation (initial attempt, each retry, each bisection
  half) through ``injector(call, x, rids)``; the injector consults the
  script, records an event, and either raises, delays, or corrupts.

The invocation counter deliberately counts *attempts*, not batches:
``transient_calls={0, 1}`` scripts "fail twice, then succeed", which is
exactly the shape the retry/backoff tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, List, Mapping, Optional, Sequence

import numpy as np


class EngineFault(RuntimeError):
    """Base of the injected engine-fault taxonomy (supervised by
    ``CNNServer``: transients retry, everything else bisects)."""


class TransientEngineFault(EngineFault):
    """A fault worth retrying (the injected analogue of a transient
    allocator/transfer hiccup)."""


class PersistentEngineFault(EngineFault):
    """A fault retrying cannot fix (the injected analogue of a poison
    input or a broken compiled artifact)."""


def _as_frozenset(value) -> FrozenSet[int]:
    return frozenset(value or ())


@dataclasses.dataclass(frozen=True)
class FaultScript:
    """A deterministic schedule of engine faults (see module docstring).

    All fields default empty — an empty script injects nothing, so a
    server wired with one behaves identically to an un-instrumented
    server (asserted in tests).
    """

    transient_calls: FrozenSet[int] = frozenset()
    persistent_calls: FrozenSet[int] = frozenset()
    latency_spikes: Mapping[int, float] = dataclasses.field(
        default_factory=dict)
    poison_rids: FrozenSet[int] = frozenset()
    corrupt_rids: FrozenSet[int] = frozenset()

    def __post_init__(self):
        object.__setattr__(self, "transient_calls",
                           _as_frozenset(self.transient_calls))
        object.__setattr__(self, "persistent_calls",
                           _as_frozenset(self.persistent_calls))
        object.__setattr__(self, "latency_spikes",
                           dict(self.latency_spikes or {}))
        object.__setattr__(self, "poison_rids",
                           _as_frozenset(self.poison_rids))
        object.__setattr__(self, "corrupt_rids",
                           _as_frozenset(self.corrupt_rids))


class FaultInjector:
    """Scripted hook on the engine call.

    ``advance`` is the latency-spike hook: under test it is the fake
    clock's ``advance`` method, in a live soak it could be
    ``time.sleep``.  ``None`` (default) records the spike event without
    consuming time — an un-wired injector never slows a real server.
    """

    def __init__(self, script: FaultScript,
                 advance: Optional[Callable[[float], None]] = None):
        self.script = script
        self.advance = advance
        self.calls = 0
        self.events: List[Dict] = []

    def _record(self, kind: str, idx: int, rids: Sequence[int], **extra):
        self.events.append({"call": idx, "kind": kind,
                            "rids": list(rids), **extra})

    def __call__(self, call: Callable[[np.ndarray], "np.ndarray"],
                 x, rids: Sequence[int]) -> np.ndarray:
        """One supervised engine invocation: ``call(x)`` under the
        script.  ``x`` is the already-bucket-padded batch; ``rids`` are
        the real request ids riding rows ``0..len(rids)-1``."""
        idx = self.calls
        self.calls += 1
        spike = self.script.latency_spikes.get(idx)
        if spike is not None:
            self._record("latency", idx, rids, seconds=spike)
            if self.advance is not None:
                self.advance(spike)
        if idx in self.script.transient_calls:
            self._record("transient", idx, rids)
            raise TransientEngineFault(
                f"injected transient fault at call {idx}")
        poisoned = sorted(self.script.poison_rids.intersection(rids))
        if idx in self.script.persistent_calls or poisoned:
            self._record("persistent", idx, rids, poisoned=poisoned)
            raise PersistentEngineFault(
                f"injected persistent fault at call {idx}"
                + (f" (poison rids {poisoned})" if poisoned else ""))
        out = np.asarray(call(x))
        if self.script.corrupt_rids:
            hit = [i for i, r in enumerate(rids)
                   if r in self.script.corrupt_rids]
            if hit:
                out = out.copy()
                out[hit] = np.nan
                self._record("corrupt", idx, rids,
                             corrupted=[rids[i] for i in hit])
        return out
