"""Batched serving engine — slot-based continuous batching.

The paper's deployment scenario is forward-only inference on batches of
inputs (batches of 16 frames in §6.2).  For the assigned autoregressive
architectures the analogue is a slot-based decode loop:

* a fixed pool of ``max_batch`` slots shares one KV cache;
* prefill inserts a request's prompt into a free slot (its K/V written into
  the slot's cache rows);
* one ``decode_step`` advances *all* active slots by one token per call —
  requests join and leave the batch independently (continuous batching);
* finished slots (EOS / max_new_tokens) are freed and immediately reusable.

The double-buffered host/device overlap of Fig. 5 maps to JAX async
dispatch: the host prepares slot bookkeeping for step t+1 while the device
executes step t; nothing here blocks except the final token fetch.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.sampling import sample


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int = -1  # -1: never stop early


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0
    generated: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 max_len: int = 512, window: int = 0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.window = window
        self.cache = model.init_cache(max_batch, max_len, window)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.pending: "queue.SimpleQueue[Request]" = queue.SimpleQueue()
        self.done: Dict[int, List[int]] = {}
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c,
                                                   window=window)
        )

    # -- client API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._check_prompt(req)
        self.pending.put(req)

    def _check_prompt(self, req: Request) -> None:
        """A slot's KV cache holds ``max_len`` rows and decoding needs at
        least one free row past the prompt — an oversized prompt would
        overflow the slot's cache rows at prefill (and ``_decode_step``
        would then write past ``max_len``)."""
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of request {req.rid} has {len(req.prompt)} tokens; "
                f"the engine's slots hold max_len={self.max_len} KV rows "
                f"and decoding needs at least one free row — prompts must "
                f"be shorter than max_len")

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (not self.pending.empty() or self._any_active()) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    # -- engine loop ------------------------------------------------------------
    def _any_active(self) -> bool:
        return any(s.request is not None for s in self.slots)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.request is None:
                return i
        return None

    def step(self) -> None:
        # 1) admit pending requests into free slots (prefill)
        while not self.pending.empty():
            i = self._free_slot()
            if i is None:
                break
            req = self.pending.get()
            self._prefill_into_slot(i, req)
        # 2) advance all active slots one token
        if self._any_active():
            self._decode_step()

    # -- internals -----------------------------------------------------------------
    def _prefill_into_slot(self, i: int, req: Request) -> None:
        self._check_prompt(req)  # guard direct callers too
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache1 = self.model.init_cache(1, self.max_len, self.window)
        batch = {"tokens": prompt}
        logits, cache1, _ = self.model.forward(
            self.params, batch, mode="prefill", cache=cache1,
            window_override=self.window)
        # write the single-request cache into slot i of the shared cache
        def insert(c, c1):
            # batch axis position differs per leaf; find the axis whose size
            # is max_batch and c1 has 1 there
            for ax in range(c.ndim):
                if c.shape[ax] == self.max_batch and c1.shape[ax] == 1:
                    idx = [slice(None)] * c.ndim
                    idx[ax] = slice(i, i + 1)
                    return c.at[tuple(idx)].set(c1.astype(c.dtype))
            return c
        self.cache = jax.tree_util.tree_map(insert, self.cache, cache1)
        if req.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            first = int(sample(logits[:, -1], sub,
                               temperature=req.temperature)[0])
        else:
            first = int(jnp.argmax(logits[0, -1]))
        slot = self.slots[i]
        slot.request = req
        slot.pos = prompt.shape[1]  # position of the next (generated) token
        slot.generated = [first]

    def _decode_step(self) -> None:
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch,), np.int32)
        active = []
        for i, s in enumerate(self.slots):
            if s.request is not None:
                tokens[i, 0] = s.generated[-1]
                positions[i] = s.pos
                active.append(i)
            else:
                positions[i] = 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            self.cache)
        # one fresh key per slot per step: slot i at step t never shares a
        # key with slot j≠i or with its own other steps
        self.key, step_key = jax.random.split(self.key)
        keys = jax.random.split(step_key, self.max_batch)
        greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))  # one dispatch
        for i in active:
            s = self.slots[i]
            temp = s.request.temperature
            if temp > 0:
                tok = int(sample(logits[i:i + 1, 0], keys[i],
                                 temperature=temp)[0])
            else:
                tok = int(greedy[i])
            s.generated.append(tok)
            s.pos += 1
            req = s.request
            n_new = len(s.generated)
            if (tok == req.eos_id or n_new >= req.max_new_tokens
                    or s.pos >= self.max_len - 1):
                self.done[req.rid] = s.generated
                self.slots[i] = _Slot()
