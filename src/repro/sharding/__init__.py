from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    spec_tree,
    batch_spec,
    kv_cache_spec,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "spec_tree",
    "batch_spec",
    "kv_cache_spec",
]
