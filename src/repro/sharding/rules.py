"""Logical-axis sharding rules.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "ff", "experts", ...).  A single table maps logical names
to physical mesh axes, so changing the distribution strategy is a one-line
edit here — never a model-code edit.  This is the same design used by
production JAX frameworks (MaxText/T5X "logical axis rules").

Physical axes: "pod" (slow inter-pod ICI), "data", "model".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P


# Logical axis -> physical mesh axis (or tuple of axes, or None=replicated).
_DEFAULT_TABLE: Dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": None,  # residual-stream sequence axis (Megatron-SP variant
    #                   maps it to "model"; attention regions keep "seq")
    "kv_seq": None,  # switched to ("pod","data") for tiny-batch long context
    "embed_act": None,
    # params
    "embed": None,  # d_model rows of projections
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",  # MoE shard_mode="expert"
    "expert_ff": "model",  # MoE shard_mode="tensor"
    "ssm_inner": "model",  # mamba/rwkv expanded inner dim
    "media": None,
    "layers": None,  # scan-stacked leading layer axis
    "zero": ("pod", "data"),  # ZeRO-1 optimizer-state sharding axis
    "fsdp": None,  # flipped to ("pod","data") for very large models
    "none": None,
}


@dataclass(frozen=True)
class AxisRules:
    table: Tuple[Tuple[str, object], ...] = tuple(sorted(_DEFAULT_TABLE.items()))

    def lookup(self, name: Optional[str]) -> object:
        if name is None:
            return None
        d = dict(self.table)
        if name not in d:
            raise KeyError(f"unknown logical axis {name!r}")
        return d[name]

    def replace(self, **kv) -> "AxisRules":
        d = dict(self.table)
        d.update(kv)
        return AxisRules(tuple(sorted(d.items())))


DEFAULT_RULES = AxisRules()


def _filter_axes(entry: object, mesh_axes: Sequence[str]) -> object:
    """Drop physical axes not present in the current mesh (e.g. 'pod' on the
    single-pod mesh)."""
    if entry is None:
        return None
    if isinstance(entry, tuple):
        kept = tuple(a for a in entry if a in mesh_axes)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return entry if entry in mesh_axes else None


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    mesh_axes: Sequence[str],
    rules: AxisRules = DEFAULT_RULES,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for this mesh."""
    return P(*[_filter_axes(rules.lookup(n), mesh_axes) for n in logical_axes])


def spec_tree(logical_tree, mesh_axes, rules: AxisRules = DEFAULT_RULES):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda ax: logical_to_spec(ax, mesh_axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def batch_spec(mesh_axes: Sequence[str], rules: AxisRules = DEFAULT_RULES) -> P:
    """Sharding of a [batch, seq, ...] activation."""
    return logical_to_spec(("batch", "seq"), mesh_axes, rules)


def kv_cache_spec(
    batch: int,
    num_kv_heads: int,
    dp_size: int,
    model_size: int,
    mesh_axes: Sequence[str],
    rules: AxisRules = DEFAULT_RULES,
) -> P:
    """Choose KV-cache sharding: [batch, seq, kv_heads, head_dim].

    - batch >= dp  : shard batch over dp; heads over model if divisible,
                     else shard the sequence over model.
    - batch <  dp  : (long_500k b=1) shard the *sequence* over dp, heads over
                     model if divisible.
    """
    dp = _filter_axes(rules.lookup("batch"), mesh_axes)
    model = _filter_axes(rules.lookup("heads"), mesh_axes)
    heads_ok = model is None or (num_kv_heads % max(model_size, 1) == 0)
    if batch >= dp_size and batch % max(dp_size, 1) == 0:
        if heads_ok:
            return P(dp, None, model, None)
        return P(dp, model, None, None)
    # tiny batch: shard sequence over dp
    if heads_ok:
        return P(None, dp, model, None)
    return P(None, (dp, model) if model is not None and dp is not None else dp, None, None)
