"""Activation-sharding context.

GSPMD propagation alone is ambiguous when FSDP weight sharding and batch
sharding share the data axis (an einsum whose operands are both sharded on
'data' can be resolved by replicating either side — for qwen1.5 it chose to
replicate *activations*, cascading into a fully-replicated 640 GB KV cache;
EXPERIMENTS.md §Dry-run).  Production JAX frameworks pin activations with
``with_sharding_constraint`` at block boundaries; this module provides the
plumbing without threading mesh/rules through every model signature.

``activation_sharding(mesh_axes, rules)`` installs a context; model code
calls ``shard_act(x, logical_axes)`` which is a no-op when no context is
installed (plain CPU tests) and a sharding constraint under the dry-run /
launcher.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple

import jax

from repro.sharding.rules import AxisRules, logical_to_spec

_CTX: contextvars.ContextVar[Optional[Tuple[Tuple[str, ...], AxisRules]]] = (
    contextvars.ContextVar("activation_sharding", default=None)
)


@contextlib.contextmanager
def activation_sharding(mesh_axes: Sequence[str], rules: AxisRules):
    token = _CTX.set((tuple(mesh_axes), rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def shard_act(x, logical_axes: Sequence[Optional[str]]):
    """Constrain activation `x` to the logical axes under the active rules;
    identity when no context is installed."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh_axes, rules = ctx
    if len(logical_axes) != x.ndim:
        return x
    spec = logical_to_spec(logical_axes, mesh_axes, rules)
    return jax.lax.with_sharding_constraint(x, spec)
