"""Per-(arch, mesh, shape) sharding-rule adaptation.

Starting from the default logical-axis table, drop shardings that do not
divide (e.g. qwen1.5's 40 heads on a 16-way model axis) and move the batch
sharding to the KV sequence for tiny-batch long-context decode.  All
decisions are recorded in the returned ``notes`` for EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.config import MeshConfig, ModelConfig, ShapeConfig
from repro.sharding.rules import AxisRules, DEFAULT_RULES


def rules_for(
    cfg: ModelConfig,
    mesh: MeshConfig,
    shape: Optional[ShapeConfig] = None,
) -> Tuple[AxisRules, list]:
    M = mesh.model_size
    dp = mesh.dp_size
    over = {}
    notes = []

    def drop(axis: str, size: int, what: str):
        if size % M:
            over[axis] = None
            notes.append(f"{what} ({size}) not divisible by model={M}: replicated")

    if cfg.num_heads:
        drop("heads", cfg.num_heads, "q heads")
    if cfg.num_kv_heads:
        drop("kv_heads", cfg.num_kv_heads, "kv heads")
    drop("ff", cfg.d_ff, "d_ff")
    drop("vocab", cfg.padded_vocab, "padded vocab")
    if cfg.moe is not None:
        # expert and tensor sharding are mutually exclusive (both map to the
        # model axis; one PartitionSpec may use it only once)
        if cfg.moe.shard_mode == "expert":
            drop("experts", cfg.moe.num_experts, "experts")
            over["expert_ff"] = None
        else:
            drop("expert_ff", cfg.moe.d_ff_expert, "expert d_ff")
            over["experts"] = None
    if cfg.ssm is not None:
        drop("ssm_inner", cfg.ssm.expand * cfg.d_model, "ssm inner dim")
    if cfg.rwkv is not None:
        drop("ssm_inner", cfg.d_model, "rwkv inner dim")

    # FSDP: when model-axis sharding alone leaves > ~2 GB of parameters per
    # device, additionally shard the "embed" parameter axis over the data
    # axes (ZeRO-3 style weight gathering).  This is what makes grok-1-314b
    # (628 GB of bf16 weights) fit 16 GB/chip.
    from repro.models.registry import analytic_param_count

    per_dev_param_bytes = 2 * analytic_param_count(cfg) / max(M, 1)
    if per_dev_param_bytes > 1.5 * 2**30 and cfg.d_model % dp == 0:
        over["embed"] = tuple(a for a in mesh.axes if a in ("pod", "data"))
        notes.append(
            f"FSDP: params would be {per_dev_param_bytes/2**30:.1f} GiB/device "
            f"under model-only sharding; 'embed' param axis sharded over dp"
        )

    kv_seq_axes = []
    if shape is not None and shape.global_batch % dp:
        over["batch"] = None
        kv_seq_axes += [a for a in mesh.axes if a in ("pod", "data")]
        notes.append(
            f"batch ({shape.global_batch}) not divisible by dp={dp}: "
            "replicated; KV sequence sharded over dp instead"
        )
    if cfg.num_kv_heads and cfg.num_kv_heads % M:
        # kv heads replicated -> shard the cache/context sequence over model
        kv_seq_axes.append("model")
        over["media"] = "model"
        notes.append("kv-seq (and media/context) sharded over model "
                     "(kv heads replicated)")
    if kv_seq_axes:
        over["kv_seq"] = tuple(kv_seq_axes)
    return DEFAULT_RULES.replace(**over), notes
