"""Llama-3.2-Vision style VLM decoder: self-attn layers with gated
cross-attention layers every ``cross_attn.interval`` layers.

The vision frontend (ViT + tiling) is a STUB per the assignment carve-out:
``batch["media_embeds"]`` carries precomputed patch embeddings
[b, n_media, media_dim]; only the projector and the language decoder are
implemented.  Cross K/V are computed once (prefill) and cached for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.attention import cross_kv, cross_attention_cached
from repro.nn.embedding import embedding_spec, embed_tokens, lm_logits
from repro.nn.linear import linear_spec, dense
from repro.nn.param import Param, stack_spec
from repro.models.common import (
    BaseModel,
    block_spec,
    block_apply,
    kv_cache_param,
    norm_spec,
    norm_apply,
    scan_layers,
)


class VisionLM(BaseModel):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        interval = cfg.cross_attn.interval
        assert interval > 1 and cfg.num_layers % interval == 0
        self.n_groups = cfg.num_layers // interval  # groups of (interval-1
        self.n_self = interval - 1  # self layers per group) + 1 cross layer

    def param_spec(self) -> dict:
        cfg = self.cfg
        unit = {
            "self": stack_spec(block_spec(cfg), self.n_self, axis_name=None),
            "cross": block_spec(cfg, cross=True, d_in=cfg.d_model),
        }
        return {
            "embed": embedding_spec(cfg),
            "projector": linear_spec(cfg.cross_attn.media_dim, cfg.d_model,
                                     "media", "embed", bias=True),
            "layers": stack_spec(unit, self.n_groups),
            "ln_f": norm_spec(cfg),
        }

    # -- forward ----------------------------------------------------------------
    def forward(self, params, batch, mode: str = "train", *, dp_size: int = 1,
                window_override: int = 0, cache=None, use_pallas: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None, :]
        media = dense(params["projector"], batch["media_embeds"])  # [b,t,d]
        x = embed_tokens(params["embed"], tokens, cfg)
        window = cfg.sliding_window or window_override
        remat = "full" if mode == "train" else "none"

        def body(xc, p_i, c_i):
            has_cache = isinstance(c_i, dict)

            def self_body(xs, p_s, c_s):
                cc = c_s if isinstance(c_s, dict) else None
                xs, nc, _ = block_apply(
                    p_s, xs, cfg, window=window, positions=positions,
                    mode="full", cache=cc, use_pallas=use_pallas)
                return xs, (nc if cc is not None else c_s), {}

            c_self = c_i["self"] if has_cache else None
            xc, nc_self, _ = scan_layers(self_body, xc, p_i["self"],
                                         stacked_cache=c_self, remat="none")
            xc, _, _ = block_apply(
                p_i["cross"], xc, cfg, positions=positions, mode="full",
                context=media, use_pallas=use_pallas)
            ncache = c_i
            if has_cache:
                ck, cv = cross_kv(p_i["cross"]["attn"], media, cfg)
                ncache = {"self": nc_self,
                          "cross": {"k": ck.astype(jnp.bfloat16),
                                    "v": cv.astype(jnp.bfloat16)}}
            return xc, ncache, {}

        x, new_cache, aux = scan_layers(body, x, params["layers"],
                                        stacked_cache=cache, remat=remat)
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        if cache is not None:
            return logits, new_cache, aux
        return logits, aux

    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        cfg = self.cfg
        S = min(cache_len, window) if window > 0 else cache_len
        t = cfg.cross_attn.num_media_tokens
        unit = {
            "self": kv_cache_param(cfg, batch, S, stacked=self.n_self),
            "cross": {
                "k": Param((batch, t, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "media", "kv_heads", None),
                           init="zeros", dtype="bfloat16"),
                "v": Param((batch, t, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "media", "kv_heads", None),
                           init="zeros", dtype="bfloat16"),
            },
        }
        return stack_cache(unit, self.n_groups)

    def decode_step(self, params, tokens, positions, cache, *, window: int = 0,
                    dp_size: int = 1):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        w = cfg.sliding_window or window

        def body(xc, p_i, c_i):
            def self_body(xs, p_s, c_s):
                xs, nc, _ = block_apply(
                    p_s, xs, cfg, window=w, positions=positions,
                    mode="decode", cache=c_s)
                return xs, nc, {}

            xc, nc_self, _ = scan_layers(self_body, xc, p_i["self"],
                                         stacked_cache=c_i["self"],
                                         remat="none")
            # gated cross-attn + mlp against the cached media K/V
            h = norm_apply(p_i["cross"]["ln_attn"], xc, cfg)
            a = cross_attention_cached(p_i["cross"]["attn"], h,
                                       c_i["cross"]["k"], c_i["cross"]["v"],
                                       cfg)
            a = a * jnp.tanh(p_i["cross"]["gate_attn"]).astype(a.dtype)
            xc = xc + a
            h = norm_apply(p_i["cross"]["ln_mlp"], xc, cfg)
            from repro.nn.mlp import mlp_apply

            m = mlp_apply(p_i["cross"]["mlp"], h, cfg)
            m = m * jnp.tanh(p_i["cross"]["gate_mlp"]).astype(m.dtype)
            xc = xc + m
            return xc, {"self": nc_self, "cross": c_i["cross"]}, {}

        x, new_cache, _ = scan_layers(body, x, params["layers"],
                                      stacked_cache=cache, remat="none")
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache


def stack_cache(unit, n):
    """Prepend the group dimension to a cache-spec pytree."""
    from repro.nn.param import Param as _P

    def f(p):
        return _P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale, p.dtype)

    return jax.tree_util.tree_map(f, unit, is_leaf=lambda x: isinstance(x, _P))
