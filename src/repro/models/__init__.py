from repro.models.registry import get_model, analytic_param_count

__all__ = ["get_model", "analytic_param_count"]
