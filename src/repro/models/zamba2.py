"""Zamba2 hybrid: Mamba2 backbone + one *shared* attention block.

38 Mamba2 blocks; after every 6th block the shared transformer block runs on
``concat(hidden, original_embedding)`` at width 2·d_model and its output is
projected back to d_model and added residually (arXiv:2411.15242; LoRA
per-invocation adapters simplified to a per-invocation layerscale —
DESIGN.md §7).  Weight sharing is the paper's "load once, reuse many times"
argument at whole-block scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.embedding import embedding_spec, embed_tokens, lm_logits
from repro.nn.linear import linear_spec, dense
from repro.nn.param import Param, stack_spec
from repro.nn.ssm import ssm_spec, ssm_apply, ssm_dims
from repro.models.common import (
    BaseModel,
    block_spec,
    block_apply,
    kv_cache_param,
    norm_spec,
    norm_apply,
    scan_layers,
)


class Zamba2LM(BaseModel):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        every = cfg.shared_attn_every
        assert every > 0
        self.n_groups = cfg.num_layers // every  # shared-block invocations
        self.group = every
        self.n_tail = cfg.num_layers - self.n_groups * every
        # the shared block operates at width 2*d_model
        self.wide_cfg = dataclasses.replace(
            cfg, d_model=2 * cfg.d_model, moe=None, ssm=None, shared_attn_every=0
        )

    def _mamba_unit(self):
        return {"ln": norm_spec(self.cfg), "ssm": ssm_spec(self.cfg)}

    def param_spec(self) -> dict:
        cfg = self.cfg
        spec = {
            "embed": embedding_spec(cfg),
            "mamba": stack_spec(self._mamba_unit(), self.n_groups * self.group),
            "shared": block_spec(self.wide_cfg),
            "shared_out": linear_spec(2 * cfg.d_model, cfg.d_model,
                                      "ff", "embed"),
            "layerscale": Param((self.n_groups, cfg.d_model),
                                (None, "embed"), init="ones", dtype="float32"),
            "ln_f": norm_spec(cfg),
        }
        if self.n_tail:
            spec["mamba_tail"] = stack_spec(self._mamba_unit(), self.n_tail)
        return spec

    # -- helpers ---------------------------------------------------------------
    def _mamba_body(self, mode):
        cfg = self.cfg

        def body(xc, p_i, c_i):
            has_cache = isinstance(c_i, dict)
            h = norm_apply(p_i["ln"], xc, cfg)
            y, ncache = ssm_apply(p_i["ssm"], h, cfg, mode=mode,
                                  cache=c_i if has_cache else None)
            return xc + y, (ncache if has_cache else c_i), {}

        return body

    def _shared_apply(self, params, x, embeds, gi, *, window, positions, mode,
                      cache):
        """One invocation of the shared wide block."""
        cfg = self.cfg
        wide = jnp.concatenate([x, embeds], axis=-1)
        y, ncache, _ = block_apply(
            params["shared"], wide, self.wide_cfg, window=window,
            positions=positions, mode=mode, cache=cache)
        out = dense(params["shared_out"], y)
        scale = params["layerscale"][gi].astype(out.dtype)
        return x + out * scale, ncache

    def _mamba_cache_unit(self, batch: int, stacked: int):
        cfg = self.cfg
        d_inner, h = ssm_dims(cfg)
        n, K = cfg.ssm.d_state, cfg.ssm.d_conv
        c = d_inner + 2 * n
        return {
            "conv": Param((stacked, batch, K - 1, c),
                          ("layers", "batch", None, "ssm_inner"),
                          init="zeros", dtype="float32"),
            "state": Param((stacked, batch, h, cfg.ssm.head_dim, n),
                           ("layers", "batch", "heads", None, None),
                           init="zeros", dtype="float32"),
        }

    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        cfg = self.cfg
        S = min(cache_len, window) if window > 0 else cache_len
        spec = {
            "mamba": self._mamba_cache_unit(batch, self.n_groups * self.group),
            "shared_kv": kv_cache_param(self.wide_cfg, batch, S,
                                        stacked=self.n_groups),
        }
        if self.n_tail:
            spec["mamba_tail"] = self._mamba_cache_unit(batch, self.n_tail)
        return spec

    # -- forward ----------------------------------------------------------------
    def _run(self, params, x, embeds, *, mode, positions, window, cache,
             remat=False):
        g, gg = self.group, self.n_groups
        mamba_params = jax.tree_util.tree_map(
            lambda a: a.reshape((gg, g) + a.shape[1:]), params["mamba"])
        mamba_cache = None
        if cache is not None:
            mamba_cache = jax.tree_util.tree_map(
                lambda a: a.reshape((gg, g) + a.shape[1:]), cache["mamba"])
        body = self._mamba_body(mode)

        new_mamba_caches = []
        new_shared_caches = []
        for gi in range(gg):
            p_g = jax.tree_util.tree_map(lambda a: a[gi], mamba_params)
            c_g = (jax.tree_util.tree_map(lambda a: a[gi], mamba_cache)
                   if mamba_cache is not None else None)
            x, nc, _ = scan_layers(body, x, p_g, stacked_cache=c_g,
                                   remat="full" if remat else "none")
            if cache is not None:
                new_mamba_caches.append(nc)
            sc = (jax.tree_util.tree_map(lambda a: a[gi], cache["shared_kv"])
                  if cache is not None else None)
            shared_fn = self._shared_apply
            if remat:
                shared_fn = jax.checkpoint(
                    lambda p, xx, ee: self._shared_apply(
                        p, xx, ee, gi, window=window, positions=positions,
                        mode=mode, cache=sc),
                    prevent_cse=False)
                x, nsc = shared_fn(params, x, embeds)
            else:
                x, nsc = shared_fn(params, x, embeds, gi, window=window,
                                   positions=positions, mode=mode, cache=sc)
            if cache is not None:
                new_shared_caches.append(nsc)
        if self.n_tail:
            c_t = cache["mamba_tail"] if cache is not None else None
            x, nct, _ = scan_layers(body, x, params["mamba_tail"],
                                    stacked_cache=c_t,
                                    remat="full" if remat else "none")
        new_cache = None
        if cache is not None:
            stack = lambda trees: jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *trees)
            merged = jax.tree_util.tree_map(
                lambda a: a.reshape((gg * g,) + a.shape[2:]),
                stack(new_mamba_caches))
            new_cache = {"mamba": merged, "shared_kv": stack(new_shared_caches)}
            if self.n_tail:
                new_cache["mamba_tail"] = nct
        return x, new_cache

    def forward(self, params, batch, mode: str = "train", *, dp_size: int = 1,
                window_override: int = 0, cache=None, use_pallas: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None, :]
        embeds = embed_tokens(params["embed"], tokens, cfg)
        x = embeds
        window = cfg.sliding_window or window_override
        x, new_cache = self._run(params, x, embeds, mode="full",
                                 positions=positions, window=window,
                                 cache=cache, remat=(mode == "train"))
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        from repro.models.common import _zero_aux
        if cache is not None:
            return logits, new_cache, _zero_aux()
        return logits, _zero_aux()

    def decode_step(self, params, tokens, positions, cache, *, window: int = 0,
                    dp_size: int = 1):
        cfg = self.cfg
        embeds = embed_tokens(params["embed"], tokens, cfg)
        w = cfg.sliding_window or window
        x, new_cache = self._run(params, embeds, embeds, mode="decode",
                                 positions=positions, window=w, cache=cache)
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache
