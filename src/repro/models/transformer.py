"""Decoder-only transformer LM: dense GQA and MoE variants.

Covers starcoder2 / internlm2 / qwen1.5 (dense), gemma2 (alternating
local/global attention, softcaps, post-block norms), grok-1 and qwen3-moe
(MoE FFNs).  Layers run under one ``lax.scan`` over stacked parameters; for
gemma2-style alternation the scan unit is a (local, global) *pair*.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.embedding import embedding_spec, embed_tokens, lm_logits
from repro.nn.param import Param, stack_spec
from repro.models.common import (
    BaseModel,
    block_spec,
    block_apply,
    kv_cache_param,
    norm_spec,
    norm_apply,
    scan_layers,
)


class TransformerLM(BaseModel):
    """Dense or MoE decoder-only LM."""

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        self.use_moe = cfg.moe is not None
        self.pair = cfg.local_global_interval == 2
        assert cfg.local_global_interval in (0, 2), "only k=2 alternation"
        if self.pair:
            assert cfg.num_layers % 2 == 0
        self.n_scan = cfg.num_layers // (2 if self.pair else 1)

    # -- params ---------------------------------------------------------------
    def param_spec(self) -> dict:
        cfg = self.cfg
        if self.pair:
            unit = {
                "local": block_spec(cfg, use_moe=self.use_moe),
                "global": block_spec(cfg, use_moe=self.use_moe),
            }
        else:
            unit = block_spec(cfg, use_moe=self.use_moe)
        return {
            "embed": embedding_spec(cfg),
            "layers": stack_spec(unit, self.n_scan),
            "ln_f": norm_spec(cfg),
        }

    # -- windows --------------------------------------------------------------
    def _windows(self, window_override: int) -> Tuple[int, int]:
        """(local_window, global_window) per scan unit."""
        cfg = self.cfg
        if self.pair:
            return cfg.sliding_window, window_override
        return cfg.sliding_window or window_override, 0

    # -- forward (train / prefill) ---------------------------------------------
    def forward(self, params, batch, mode: str = "train", *, dp_size: int = 1,
                window_override: int = 0, cache=None, use_pallas: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.arange(s)[None, :]
        x = embed_tokens(params["embed"], tokens, cfg,
                         scale_by_dim=cfg.rms_plus_one)
        lw, gw = self._windows(window_override)
        remat = "full" if mode == "train" else "none"

        def body(xc, p_i, c_i):
            if self.pair:
                c_loc = c_i["local"] if isinstance(c_i, dict) else None
                c_glb = c_i["global"] if isinstance(c_i, dict) else None
                xc, nc_l, aux1 = block_apply(
                    p_i["local"], xc, cfg, window=lw, positions=positions,
                    mode="full", cache=c_loc, use_moe=self.use_moe,
                    dp_size=dp_size, moe_mode=mode, use_pallas=use_pallas)
                xc, nc_g, aux2 = block_apply(
                    p_i["global"], xc, cfg, window=gw, positions=positions,
                    mode="full", cache=c_glb, use_moe=self.use_moe,
                    dp_size=dp_size, moe_mode=mode, use_pallas=use_pallas)
                aux = {k: aux1.get(k, 0.0) + aux2.get(k, 0.0)
                       for k in set(aux1) | set(aux2)
                       if k.endswith("loss")}
                ncache = ({"local": nc_l, "global": nc_g}
                          if isinstance(c_i, dict) else c_i)
            else:
                cache_i = c_i if isinstance(c_i, dict) else None
                xc, ncache, aux = block_apply(
                    p_i, xc, cfg, window=lw, positions=positions, mode="full",
                    cache=cache_i, use_moe=self.use_moe, dp_size=dp_size,
                    moe_mode=mode, use_pallas=use_pallas)
                if not isinstance(c_i, dict):
                    ncache = c_i
            return xc, ncache, aux

        x, new_cache, aux = scan_layers(
            body, x, params["layers"], stacked_cache=cache, remat=remat)
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        if cache is not None:
            return logits, new_cache, aux
        return logits, aux

    # -- caches ----------------------------------------------------------------
    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        cfg = self.cfg
        lw, gw = self._windows(window)

        def clen(w):
            return min(cache_len, w) if w > 0 else cache_len

        if self.pair:
            return {
                "local": kv_cache_param(cfg, batch, clen(lw), stacked=self.n_scan),
                "global": kv_cache_param(cfg, batch, clen(gw), stacked=self.n_scan),
            }
        return kv_cache_param(cfg, batch, clen(lw), stacked=self.n_scan)

    # -- decode ------------------------------------------------------------------
    def decode_step(self, params, tokens, positions, cache, *, window: int = 0,
                    dp_size: int = 1):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg,
                         scale_by_dim=cfg.rms_plus_one)
        lw, gw = self._windows(window)

        def body(xc, p_i, c_i):
            if self.pair:
                xc, nc_l, _ = block_apply(
                    p_i["local"], xc, cfg, window=lw, positions=positions,
                    mode="decode", cache=c_i["local"], use_moe=self.use_moe,
                    dp_size=dp_size)
                xc, nc_g, _ = block_apply(
                    p_i["global"], xc, cfg, window=gw, positions=positions,
                    mode="decode", cache=c_i["global"], use_moe=self.use_moe,
                    dp_size=dp_size)
                return xc, {"local": nc_l, "global": nc_g}, {}
            xc, nc, _ = block_apply(
                p_i, xc, cfg, window=lw, positions=positions, mode="decode",
                cache=c_i, use_moe=self.use_moe, dp_size=dp_size)
            return xc, nc, {}

        x, new_cache, _ = scan_layers(body, x, params["layers"],
                                      stacked_cache=cache, remat="none")
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache
