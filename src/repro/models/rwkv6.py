"""RWKV6 (Finch) language model — attention-free, O(1)-state decode.

Per DESIGN.md §Arch-applicability the paper's conv/attention ladder is
inapplicable here; the layout + fused-epilogue techniques apply to the
projections, and the WKV6 time-mixing uses chunked temporal blocking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.embedding import embedding_spec, embed_tokens, lm_logits
from repro.nn.param import stack_spec
from repro.nn.rwkv import (
    rwkv_time_spec,
    rwkv_channel_spec,
    rwkv_time_apply,
    rwkv_channel_apply,
    rwkv_dims,
)
from repro.models.common import BaseModel, norm_spec, norm_apply, scan_layers
from repro.nn.param import Param


class RWKV6LM(BaseModel):
    def param_spec(self) -> dict:
        cfg = self.cfg
        unit = {
            "ln1": norm_spec(cfg),
            "time": rwkv_time_spec(cfg),
            "ln2": norm_spec(cfg),
            "chan": rwkv_channel_spec(cfg),
        }
        return {
            "embed": embedding_spec(cfg),
            "ln0": norm_spec(cfg),
            "layers": stack_spec(unit, cfg.num_layers),
            "ln_f": norm_spec(cfg),
        }

    def _body(self, mode):
        cfg = self.cfg

        def body(xc, p_i, c_i):
            has_cache = isinstance(c_i, dict)
            tc = c_i["time"] if has_cache else None
            cc = c_i["chan"] if has_cache else None
            h = norm_apply(p_i["ln1"], xc, cfg)
            t_out, new_tc = rwkv_time_apply(p_i["time"], h, cfg, cache=tc,
                                            mode=mode)
            xc = xc + t_out
            h = norm_apply(p_i["ln2"], xc, cfg)
            c_out, new_cc = rwkv_channel_apply(p_i["chan"], h, cfg, cache=cc)
            xc = xc + c_out
            ncache = {"time": new_tc, "chan": new_cc} if has_cache else c_i
            return xc, ncache, {}

        return body

    def forward(self, params, batch, mode: str = "train", *, dp_size: int = 1,
                window_override: int = 0, cache=None, use_pallas: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, cfg)
        x = norm_apply(params["ln0"], x, cfg)
        remat = "full" if mode == "train" else "none"
        x, new_cache, aux = scan_layers(self._body("full"), x, params["layers"],
                                        stacked_cache=cache, remat=remat)
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        if cache is not None:
            return logits, new_cache, aux
        return logits, aux

    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        cfg = self.cfg
        d, h = rwkv_dims(cfg)
        e = cfg.rwkv.head_dim
        L = cfg.num_layers
        unit = {
            "time": {
                "last": Param((L, batch, d), ("layers", "batch", None),
                              init="zeros", dtype="float32"),
                "state": Param((L, batch, h, e, e),
                               ("layers", "batch", "heads", None, None),
                               init="zeros", dtype="float32"),
            },
            "chan": {
                "last": Param((L, batch, d), ("layers", "batch", None),
                              init="zeros", dtype="float32"),
            },
        }
        return unit

    def decode_step(self, params, tokens, positions, cache, *, window: int = 0,
                    dp_size: int = 1):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        x = norm_apply(params["ln0"], x, cfg)
        x, new_cache, _ = scan_layers(self._body("decode"), x, params["layers"],
                                      stacked_cache=cache, remat="none")
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache
