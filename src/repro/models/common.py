"""Shared model plumbing: blocks, layer scans, cache specs, the Model API."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, ShapeConfig
from repro.nn.attention import attention_spec, attention_apply
from repro.nn.mlp import mlp_spec, mlp_apply
from repro.nn.moe import moe_spec, moe_apply
from repro.nn.norm import (
    rmsnorm_spec,
    rmsnorm_apply,
    layernorm_spec,
    layernorm_apply,
)
from repro.nn.param import Param, init_tree, axes_tree, stack_spec
from repro.sharding.ctx import shard_act


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig, dim: int = 0) -> dict:
    dim = dim or cfg.d_model
    if cfg.norm_kind == "layernorm":
        return layernorm_spec(dim)
    return rmsnorm_spec(dim)


def norm_apply(params, x, cfg: ModelConfig):
    if cfg.norm_kind == "layernorm":
        return layernorm_apply(params, x, cfg.norm_eps)
    return rmsnorm_apply(params, x, cfg.norm_eps, plus_one=cfg.rms_plus_one)


# ---------------------------------------------------------------------------
# Standard pre-norm transformer block (dense or MoE)
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, use_moe: bool = False, cross: bool = False,
               d_in: int = 0) -> dict:
    spec = {
        "ln_attn": norm_spec(cfg),
        "attn": attention_spec(cfg, cross=cross, kv_dim=d_in or None),
        "ln_mlp": norm_spec(cfg),
        "mlp": moe_spec(cfg) if use_moe else mlp_spec(cfg),
    }
    if cfg.post_block_norms:
        spec["ln_attn_post"] = norm_spec(cfg)
        spec["ln_mlp_post"] = norm_spec(cfg)
    if cross:
        # gating for cross-attn residual (llama-3.2-vision style tanh gates)
        spec["gate_attn"] = Param((1,), (None,), init="zeros", dtype="float32")
        spec["gate_mlp"] = Param((1,), (None,), init="zeros", dtype="float32")
    return spec


def block_apply(
    params,
    x,
    cfg: ModelConfig,
    *,
    window: int = 0,
    positions=None,
    mode: str = "full",
    cache: Optional[dict] = None,
    context=None,
    use_moe: bool = False,
    dp_size: int = 1,
    moe_mode: str = "train",
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict], dict]:
    """Returns (x, new_cache, aux)."""
    aux: Dict[str, Any] = {}
    h = norm_apply(params["ln_attn"], x, cfg)
    a, new_cache = attention_apply(
        params["attn"], h, cfg, window=window, positions=positions, mode=mode,
        cache=cache, context=context, use_rope=(context is None),
        use_pallas=use_pallas,
    )
    if cfg.post_block_norms:
        a = norm_apply(params["ln_attn_post"], a, cfg)
    if context is not None and "gate_attn" in params:
        a = a * jnp.tanh(params["gate_attn"]).astype(a.dtype)
    x = shard_act(x + a, ("batch", "seq_res", "embed_act"))

    h = norm_apply(params["ln_mlp"], x, cfg)
    if use_moe:
        m, aux = moe_apply(params["mlp"], h, cfg, dp_size=dp_size,
                           mode=("decode" if mode == "decode" else moe_mode))
    else:
        m = mlp_apply(params["mlp"], h, cfg, use_pallas=use_pallas)
    if cfg.post_block_norms:
        m = norm_apply(params["ln_mlp_post"], m, cfg)
    if context is not None and "gate_mlp" in params:
        m = m * jnp.tanh(params["gate_mlp"]).astype(m.dtype)
    x = shard_act(x + m, ("batch", "seq_res", "embed_act"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Layer scan with optional remat
# ---------------------------------------------------------------------------


def scan_layers(
    body: Callable,  # (x, layer_params, layer_cache) -> (x, new_cache, aux)
    x,
    stacked_params,
    stacked_cache=None,
    remat: str = "none",
):
    """Scan `body` over the leading (layer) axis of params/cache.

    The cache travels in the scan CARRY and is updated in place with
    ``dynamic_update_index_in_dim`` — passing it as scan xs/ys would keep
    the input and output stacks alive simultaneously (2× the KV cache;
    measured +10.9 GiB/device on qwen1.5 decode_32k, EXPERIMENTS.md §Perf).

    aux outputs are summed over layers.  Returns (x, new_stacked_cache, aux).
    """
    n_layers = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    has_cache = stacked_cache is not None

    def step(carry, xs):
        xc, aux_acc, cache = carry
        p_i, i = xs
        c_i = None
        if has_cache:
            c_i = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False),
                cache)
        x_new, cache_new, aux = body(xc, p_i, c_i)
        if has_cache:
            cache = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(
                    a, u.astype(a.dtype), i, 0),
                cache, cache_new)
        aux_acc = _accumulate_aux(aux_acc, aux)
        return (x_new, aux_acc, cache), None

    fn = step
    if remat == "full":
        fn = jax.checkpoint(step, prevent_cse=False)
    elif remat == "dots":
        fn = jax.checkpoint(
            step,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    cache0 = stacked_cache if has_cache else _none_like(n_layers)
    (x, aux, new_cache), _ = jax.lax.scan(
        fn, (x, _zero_aux(), cache0),
        (stacked_params, jnp.arange(n_layers)))
    return x, (new_cache if has_cache else None), aux


def _zero_aux():
    return {
        "load_balance_loss": jnp.zeros((), jnp.float32),
        "router_z_loss": jnp.zeros((), jnp.float32),
    }


def _accumulate_aux(acc, aux):
    out = dict(acc)
    for k in ("load_balance_loss", "router_z_loss"):
        if aux and k in aux:
            out[k] = acc[k] + aux[k]
    return out


def _none_like(n):
    return jnp.zeros((n, 0), jnp.float32)  # zero-size per-layer placeholder


# ---------------------------------------------------------------------------
# KV-cache specs (as Param trees so init/axes machinery is reused)
# ---------------------------------------------------------------------------


def kv_cache_param(
    cfg: ModelConfig, batch: int, cache_len: int, stacked: int = 0,
    dtype: str = "bfloat16",
) -> dict:
    shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    axes = ("batch", "kv_seq", "kv_heads", None)
    s_shape, s_axes = shape[:-1], axes[:-1]
    if stacked:
        shape = (stacked,) + shape
        axes = ("layers",) + axes
        s_shape = (stacked,) + s_shape
        s_axes = ("layers",) + s_axes
    if cfg.kv_quant:
        return {
            "k": Param(shape, axes, init="zeros", dtype="int8"),
            "k_scale": Param(s_shape, s_axes, init="zeros", dtype="float16"),
            "v": Param(shape, axes, init="zeros", dtype="int8"),
            "v_scale": Param(s_shape, s_axes, init="zeros", dtype="float16"),
        }
    return {
        "k": Param(shape, axes, init="zeros", dtype=dtype),
        "v": Param(shape, axes, init="zeros", dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


class BaseModel:
    """Functional model wrapper: param specs + forward/prefill/decode."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ----------------------------------------------------------
    def param_spec(self) -> dict:
        raise NotImplementedError

    def init(self, key) -> dict:
        return init_tree(self.param_spec(), key, self.cfg.param_dtype)

    def param_axes(self) -> dict:
        return axes_tree(self.param_spec())

    # -- compute -------------------------------------------------------------
    def forward(self, params, batch: dict, mode: str = "train"):
        """batch: {"tokens": [b,s], ...} -> (logits fp32 [b,s,V], aux dict)."""
        raise NotImplementedError

    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        raise NotImplementedError

    def init_cache(self, batch: int, cache_len: int, window: int = 0,
                   key=None) -> dict:
        return init_tree(
            self.cache_spec(batch, cache_len, window), key or jax.random.PRNGKey(0),
            "bfloat16",
        )

    def cache_axes(self, batch: int, cache_len: int, window: int = 0) -> dict:
        return axes_tree(self.cache_spec(batch, cache_len, window))

    def decode_step(self, params, tokens, positions, cache, window: int = 0):
        """tokens [b,1], positions [b] -> (logits [b,1,V], new_cache)."""
        raise NotImplementedError

    # -- bookkeeping -----------------------------------------------------------
    @property
    def name(self) -> str:
        return self.cfg.name

    def effective_window(self, shape: ShapeConfig) -> int:
        """Window to use for a given input shape (long-context fallback —
        DESIGN.md §Arch-applicability)."""
        cfg = self.cfg
        if shape.seq_len > 131_072 and not cfg.is_attention_free:
            return cfg.sliding_window or cfg.long_context_window
        return cfg.sliding_window
