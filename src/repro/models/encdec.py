"""Encoder-decoder transformer (SeamlessM4T-v2 text/speech backbone).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB
per the assignment carve-out: ``batch["frames"]`` carries precomputed frame
embeddings [b, n_frames, media_dim].  The encoder is bidirectional; the
decoder interleaves causal self-attention, cross-attention to the encoder
output, and an MLP.  Cross K/V are computed once per sequence and cached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.nn.attention import (
    attention_spec,
    attention_apply,
    cross_kv,
    cross_attention_cached,
)
from repro.nn.embedding import embedding_spec, embed_tokens, lm_logits
from repro.nn.linear import linear_spec, dense
from repro.nn.mlp import mlp_spec, mlp_apply
from repro.nn.param import Param, stack_spec
from repro.models.common import (
    BaseModel,
    block_spec,
    block_apply,
    kv_cache_param,
    norm_spec,
    norm_apply,
    scan_layers,
)
from repro.models.vision_lm import stack_cache


class EncDecLM(BaseModel):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        assert cfg.num_encoder_layers > 0

    def _dec_unit(self) -> dict:
        cfg = self.cfg
        return {
            "ln_self": norm_spec(cfg),
            "self": attention_spec(cfg),
            "ln_cross": norm_spec(cfg),
            "cross": attention_spec(cfg, cross=True),
            "ln_mlp": norm_spec(cfg),
            "mlp": mlp_spec(cfg),
        }

    def param_spec(self) -> dict:
        cfg = self.cfg
        return {
            "embed": embedding_spec(cfg),
            "frontend": linear_spec(cfg.cross_attn.media_dim, cfg.d_model,
                                    "media", "embed", bias=True),
            "encoder": stack_spec(block_spec(cfg), cfg.num_encoder_layers),
            "ln_enc": norm_spec(cfg),
            "decoder": stack_spec(self._dec_unit(), cfg.num_layers),
            "ln_f": norm_spec(cfg),
        }

    # -- encoder -----------------------------------------------------------------
    def encode(self, params, frames, mode: str = "train"):
        cfg = self.cfg
        x = dense(params["frontend"], frames)
        positions = jnp.arange(x.shape[1])[None, :]

        def body(xc, p_i, c_i):
            xc, _, _ = block_apply(p_i, xc, cfg, positions=positions,
                                   mode="full", cache=None)
            return xc, c_i, {}

        # bidirectional: causal=False is routed via window=0 + causal flag
        def body_bi(xc, p_i, c_i):
            h = norm_apply(p_i["ln_attn"], xc, cfg)
            a, _ = attention_apply(p_i["attn"], h, cfg, causal=False,
                                   positions=positions, mode="full")
            xc = xc + a
            h = norm_apply(p_i["ln_mlp"], xc, cfg)
            xc = xc + mlp_apply(p_i["mlp"], h, cfg)
            return xc, c_i, {}

        remat = "full" if mode == "train" else "none"
        x, _, _ = scan_layers(body_bi, x, params["encoder"], remat=remat)
        return norm_apply(params["ln_enc"], x, cfg)

    # -- decoder -----------------------------------------------------------------
    def _dec_body(self, enc_out, positions, window, mode, use_cross_cache):
        cfg = self.cfg

        def body(xc, p_i, c_i):
            has_cache = isinstance(c_i, dict)
            h = norm_apply(p_i["ln_self"], xc, cfg)
            a, nc_self = attention_apply(
                p_i["self"], h, cfg, window=window, positions=positions,
                mode=mode, cache=c_i["self"] if has_cache else None)
            xc = xc + a
            h = norm_apply(p_i["ln_cross"], xc, cfg)
            if use_cross_cache:
                a = cross_attention_cached(p_i["cross"], h, c_i["cross"]["k"],
                                           c_i["cross"]["v"], cfg)
                nc_cross = c_i["cross"]
            else:
                a, _ = attention_apply(p_i["cross"], h, cfg, context=enc_out,
                                       mode="full")
                nc_cross = None
                if has_cache:
                    ck, cv = cross_kv(p_i["cross"], enc_out, cfg)
                    nc_cross = {"k": ck.astype(jnp.bfloat16),
                                "v": cv.astype(jnp.bfloat16)}
            xc = xc + a
            h = norm_apply(p_i["ln_mlp"], xc, cfg)
            xc = xc + mlp_apply(p_i["mlp"], h, cfg)
            ncache = {"self": nc_self, "cross": nc_cross} if has_cache else c_i
            return xc, ncache, {}

        return body

    # -- public API -----------------------------------------------------------------
    def forward(self, params, batch, mode: str = "train", *, dp_size: int = 1,
                window_override: int = 0, cache=None, use_pallas: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None, :]
        enc_out = self.encode(params, batch["frames"], mode)
        x = embed_tokens(params["embed"], tokens, cfg)
        window = cfg.sliding_window or window_override
        body = self._dec_body(enc_out, positions, window, "full", False)
        remat = "full" if mode == "train" else "none"
        x, new_cache, aux = scan_layers(body, x, params["decoder"],
                                        stacked_cache=cache, remat=remat)
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        if cache is not None:
            return logits, new_cache, aux
        return logits, aux

    def cache_spec(self, batch: int, cache_len: int, window: int = 0) -> dict:
        cfg = self.cfg
        S = min(cache_len, window) if window > 0 else cache_len
        t = cfg.cross_attn.num_media_tokens
        unit = {
            "self": kv_cache_param(cfg, batch, S),
            "cross": {
                "k": Param((batch, t, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "media", "kv_heads", None),
                           init="zeros", dtype="bfloat16"),
                "v": Param((batch, t, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "media", "kv_heads", None),
                           init="zeros", dtype="bfloat16"),
            },
        }
        return stack_cache(unit, cfg.num_layers)

    def decode_step(self, params, tokens, positions, cache, *, window: int = 0,
                    dp_size: int = 1):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        w = cfg.sliding_window or window
        body = self._dec_body(None, positions, w, "decode", True)
        x, new_cache, _ = scan_layers(body, x, params["decoder"],
                                      stacked_cache=cache, remat="none")
        x = norm_apply(params["ln_f"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_cache
