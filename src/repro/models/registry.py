"""Model registry: family -> class dispatch and analytic parameter counts."""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax

from repro.core.config import ModelConfig
from repro.nn.param import Param, is_param


def get_model(cfg: ModelConfig):
    from repro.models.transformer import TransformerLM
    from repro.models.rwkv6 import RWKV6LM
    from repro.models.zamba2 import Zamba2LM
    from repro.models.vision_lm import VisionLM
    from repro.models.encdec import EncDecLM

    if cfg.family == "ssm":
        return RWKV6LM(cfg)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg)
    if cfg.family == "vlm":
        return VisionLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    # dense + moe
    return TransformerLM(cfg)


def _spec_counts(spec):
    """(total, expert, embed) parameter counts from a Param spec tree."""
    total = expert = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=is_param
    )[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(str(k).startswith("we_") for k in keys):
            expert += n
        if "embed" in [str(k) for k in keys] or any(
            str(k) in ("tok", "head") for k in keys
        ):
            embed += n
    return total, expert, embed


def analytic_param_count(
    cfg: ModelConfig, active_only: bool = False, non_embedding: bool = False
) -> int:
    model = get_model(cfg)
    total, expert, embed = _spec_counts(model.param_spec())
    n = total
    if active_only and cfg.moe is not None:
        k, E = cfg.moe.num_experts_per_token, cfg.moe.num_experts
        n = total - expert + expert * k / E
    if non_embedding:
        n -= embed
    return int(n)
