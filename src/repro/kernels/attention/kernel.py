"""Flash attention Pallas TPU kernel (forward).

Beyond-paper kernel required by the 32k prefill shapes: online-softmax
attention with O(block²) VMEM.  Structure:

  grid = (batch·heads, q_tiles, kv_tiles)   kv innermost, sequential
  q block   [bq, hd]      (VMEM, reused across all kv steps — the paper's
                           "load once, reuse" argument applied to queries)
  k/v block [bk, hd]
  scratch   m [bq], l [bq], acc [bq, hd] fp32 — persists across kv steps

Causal/sliding-window masking is applied per block from iota; blocks that
are entirely masked are skipped with ``pl.when`` so the FLOPs match the
true masked cost.  The jnp twin (``repro.nn.attention.chunked_attention``)
is the oracle and the autodiff path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ACC_DTYPE

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, cap, causal, window, bq, bk, nk, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_hi)
    if window > 0:
        visible = jnp.logical_and(visible, k_hi > q_lo - window)

    @pl.when(visible if not isinstance(visible, bool) else True)
    def _step():
        q = q_ref[...].astype(ACC_DTYPE)  # [bq, hd]
        k = k_ref[...].astype(ACC_DTYPE)  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        s = s * scale
        if cap and cap > 0.0:
            s = cap * jnp.tanh(s / cap)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < skv  # kv padding
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        row_ok = m_new > NEG_INF / 2
        p = jnp.exp(s - m_new[:, None]) * row_ok[:, None]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[...].astype(ACC_DTYPE), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *, causal=True, window=0, attn_softcap=0.0, scale=None,
    bq: int = 512, bk: int = 512, interpret: bool = False,
):
    """q: [b, sq, h, hd]; k/v: [b, skv, h, hd] (kv heads pre-expanded).

    Returns [b, sq, h, hd]."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(bq, sq)
    bk = min(bk, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    # layout: fold (b, h) into the leading grid axis
    qt = jnp.pad(q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd),
                 ((0, 0), (0, pq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3).reshape(b * h, skv, hd),
                 ((0, 0), (0, pk), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3).reshape(b * h, skv, hd),
                 ((0, 0), (0, pk), (0, 0)))
    nq, nk = (sq + pq) // bq, (skv + pk) // bk

    kern = functools.partial(
        _kernel, scale=scale, cap=attn_softcap, causal=causal,
        window=window, bq=bq, bk=bk, nk=nk, skv=skv,
    )
    out = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((None, bk, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((None, bk, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :sq].reshape(b, h, sq, hd).transpose(0, 2, 1, 3)