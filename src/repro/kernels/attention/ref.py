"""Oracle for the flash-attention kernel: materialized softmax attention
(small shapes) — shared semantics with ``repro.nn.attention``."""
from __future__ import annotations

from repro.nn.attention import reference_attention

flash_attention_ref = reference_attention
