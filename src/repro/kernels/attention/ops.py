"""jit'd wrapper for the flash-attention kernel (GQA expansion included)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "attn_softcap",
                                   "scale", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, attn_softcap=0.0,
                    scale=None, interpret: bool = None):
    """q: [b, sq, h, hd]; k/v: [b, skv, kvh, hd] (kv heads auto-expanded)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    group = q.shape[2] // k.shape[2]
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, attn_softcap=attn_softcap,
        scale=scale, interpret=interp,
    )
