"""Kernel-wide named constants.

``ACC_DTYPE`` is the accumulation dtype every Pallas kernel body
computes in: operands are upcast to it on load, partial sums live in
it, and exactly one downcast to ``o_ref.dtype`` happens at the final
store.  Naming the constant (instead of writing ``jnp.float32`` inline)
is what lets two static passes enforce the contract cheaply:

* the repo lint's R007 rule accepts only ``ACC_DTYPE`` or a ref's
  ``.dtype`` as an ``astype`` target inside kernel bodies, and
* the kernel sanitizer's K103 precision-flow lattice resolves the name
  to fp32 when it symbolically executes the bodies.

When the quantized int8/fp16 path lands, its kernels get their own
named accumulation constants here and both passes extend by table
entry, not by new pattern-matching.
"""
from __future__ import annotations

import jax.numpy as jnp

ACC_DTYPE = jnp.float32
