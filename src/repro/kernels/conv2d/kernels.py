"""The CNNdroid conv ladder as Pallas TPU kernels.

Three kernels, one per paper method (§4.2–§4.4), sharing the grid-over-
frames structure (the paper launches one RenderScript kernel per frame
batch; we launch one grid cell per frame × tile):

* ``basic_parallel``  (§4.2) — NCHW, whole frame per grid cell, reduction
  loops over (c, kh, kw) with the *spatial* map vectorized — channels are
  NOT on the lane axis, mirroring the paper's un-swapped layout.  The MXU
  stays idle; only the VPU spatial lanes are used.
* ``basic_simd``      (§4.3) — NHWC after dimension swapping: channels on
  the 128-lane minor axis; grid cell (frame, oh-tile); per kernel position
  a [rows, C] × [C, OC] dot — the vectorized channel dot product — over
  one output-row band at a time.
* ``advanced_simd``   (§4.4) — NHWC + output-channel blocking: grid cell
  (frame, oh-tile, oc-tile); an im2col patch matrix [rows, KH·KW·C] built
  once per spatial tile in VMEM is reused for the whole 128-wide oc tile
  (the paper's 4/8-outputs-per-thread reuse at MXU width), with bias+ReLU
  fused in the epilogue.

Spatial tiling (the ``oh_block`` knob): both SIMD kernels split the output
height into bands of ``oh_block`` rows.  Each grid cell loads only the
input-row band its output band needs — ``(oh_block-1)*stride + KH`` rows
including the halo, addressed stride-aware with an element-offset
(``pl.Unblocked``) BlockSpec so neighbouring bands may overlap by the
``KH - stride`` halo rows.  ``oh_block=None`` picks the largest band whose
working set (input band + im2col patches + weights + output block) fits
``VMEM_BUDGET_BYTES`` — so frames far larger than VMEM (e.g. 512×512×64)
run on the same ladder instead of trying to stage the whole padded frame.

VMEM budget: block shapes keep the minor dimension lane-aligned when the
channel count allows (ops.py pads channels — the paper's divisible-by-4
observation at lane width 128/8); the heuristic targets half of the ~16 MB
per-core VMEM to leave room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Target working set per grid cell — half the ~16 MB/core VMEM, leaving the
# other half for the pipeline's double buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _out_size(size, k, stride, pad):
    return (size + 2 * pad - k) // stride + 1


def _band_rows(oh_block, kh, sy):
    """Input rows one output band needs: oh_block strided rows + halo."""
    return (oh_block - 1) * sy + kh


def auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                  budget: int = VMEM_BUDGET_BYTES, itemsize: int = 4,
                  im2col: bool = True) -> int:
    """Largest output-row band whose per-cell working set fits ``budget``.

    Working set (fp32 staging): the input row band, the patch staging, one
    weight block, and the output block.  ``im2col=True`` (advanced kernel)
    charges the full [rows, KH*KW*C] patch matrix; ``im2col=False`` (basic
    kernel) charges only the single [rows, C] slice it holds at a time.
    Candidates walk down from the whole frame through powers of two; the
    floor is a single output row.
    """
    patch_c = kh * kw * c if im2col else c
    candidates = [oh] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)
                         if b < oh]
    for ohb in candidates:
        band = _band_rows(ohb, kh, sy)
        need = (band * wp * c          # input row band (incl. halo)
                + ohb * ow * patch_c       # patch staging
                + kh * kw * c * oc_block   # weight block
                + ohb * ow * oc_block      # output block / accumulator
                ) * itemsize
        if need <= budget:
            return ohb
    return 1


def resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                     im2col: bool = True) -> int:
    """The output-row band a SIMD kernel will actually run with: the auto
    heuristic when ``oh_block`` is None, else the clamped explicit value.
    Public so benches/tools can report the executed geometry."""
    if oh_block is None:
        return auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                             im2col=im2col)
    return max(1, min(oh_block, oh))


# ---------------------------------------------------------------------------
# §4.2 basic parallel — NCHW, no channel vectorization
# ---------------------------------------------------------------------------


def _basic_parallel_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                           relu):
    # x_ref: [C, H, W]; w_ref: [OC, C, KH, KW]; o_ref: [OC, OH, OW]
    oc, ohh, oww = o_ref.shape
    c = x_ref.shape[0]
    acc = jnp.zeros((oc, ohh, oww), jnp.float32)
    for ci in range(c):  # channels OUTER (un-swapped layout: no lane reuse)
        plane = x_ref[ci]  # [H, W]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    plane, (i, j),
                    (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1),
                    (sy, sx),
                )  # [OH, OW] — spatial lanes only
                acc = acc + (patch.astype(jnp.float32)[None] *
                             w_ref[:, ci, i, j].astype(jnp.float32)
                             [:, None, None])
    acc = acc + b_ref[...].astype(jnp.float32)[:, None, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def conv2d_basic_parallel(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                          interpret: bool = False):
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[2], xp.shape[3]
    kern = functools.partial(_basic_parallel_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, c, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((oc, c, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, oc, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oc, oh, ow), x.dtype),
        interpret=interpret,
    )(xp, w, b)


# ---------------------------------------------------------------------------
# shared oh-band plumbing for the SIMD kernels
# ---------------------------------------------------------------------------


def _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block, ow, oc_block,
                   im2col=True):
    """Resolve the band size and pad the input so every band is full.

    Returns ``(xp, ohb, n_tiles, band)`` where ``xp`` has enough bottom
    zero-rows that the last band — starting at ``(n_tiles-1)*ohb*sy`` and
    spanning ``band`` rows — stays in bounds; the surplus output rows are
    sliced off by the caller.
    """
    n, hp, wp, c = xp.shape
    ohb = resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                           im2col=im2col)
    n_tiles = -(-oh // ohb)
    ohp = n_tiles * ohb
    band = _band_rows(ohb, kh, sy)
    hp_need = (ohp - 1) * sy + kh
    if hp_need > hp:
        xp = jnp.pad(xp, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
    return xp, ohb, n_tiles, band


# ---------------------------------------------------------------------------
# §4.3 basic SIMD — NHWC, vectorized channel dot per kernel position
# ---------------------------------------------------------------------------


def _basic_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx, relu):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH, KW, C, OC];
    # o_ref: [OH_BLK, OW, OC]
    ohh, oww, oc = o_ref.shape
    x = x_ref[0]
    acc = jnp.zeros((ohh * oww, oc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1)  # [rows, C] — C on the lane axis
            acc = acc + jnp.dot(
                patch.astype(jnp.float32),
                w_ref[i, j].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )  # vectorized dot over channels (the paper's 4-wide, here 128)
    acc = acc + b_ref[...].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, oc).astype(o_ref.dtype)


def conv2d_basic_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                      relu=False, oh_block=None, interpret: bool = False):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                            ow, oc, im2col=False)
    wp = xp.shape[2]
    row_step = ohb * sy
    kern = functools.partial(_basic_simd_kernel, kh=kh, kw=kw, sy=sy, sx=sx,
                             relu=relu)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh, kw, c, oc), lambda i, t: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((None, ohb, ow, oc),
                               lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * ohb, ow, oc),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, w_hwio, b)
    return out[:, :oh]


# ---------------------------------------------------------------------------
# §4.4 advanced SIMD — im2col in VMEM + output-channel blocking + epilogue
# ---------------------------------------------------------------------------


def _advanced_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                          relu):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH*KW*C, OC_BLK];
    # o_ref: [OH_BLK, OW, OC_BLK]
    ohh, oww, ocb = o_ref.shape
    x = x_ref[0]
    cols = []
    for i in range(kh):  # im2col built once per spatial tile, reused for
        for j in range(kw):  # the whole 128-wide output-channel block (§4.4)
            cols.append(jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1))
    patches = jnp.concatenate(cols, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(patches.astype(jnp.float32), w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)  # one MXU matmul
    acc = acc + b_ref[...].astype(jnp.float32)
    if relu:  # fused epilogue in VMEM — zero-cost ReLU (Fig. 5)
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, ocb).astype(o_ref.dtype)


def conv2d_advanced_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                         relu=False, oc_block: int = 128, oh_block=None,
                         interpret: bool = False):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    ocb = min(oc_block, oc)
    pad_oc = (-oc) % ocb
    wmat = w_hwio.reshape(kh * kw * c, oc)
    if pad_oc:
        wmat = jnp.pad(wmat, ((0, 0), (0, pad_oc)))
        b = jnp.pad(b, (0, pad_oc))
    ocp = oc + pad_oc
    xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                            ow, ocb)
    wp = xp.shape[2]
    row_step = ohb * sy
    kern = functools.partial(_advanced_simd_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles, ocp // ocb),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t, o: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh * kw * c, ocb), lambda i, t, o: (0, o)),
            pl.BlockSpec((ocb,), lambda i, t, o: (o,)),
        ],
        out_specs=pl.BlockSpec((None, ohb, ow, ocb),
                               lambda i, t, o: (i, t, 0, o)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * ohb, ow, ocp),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, wmat, b)
    return out[:, :oh, :, :oc]
