"""The CNNdroid conv ladder as Pallas TPU kernels.

Three kernels, one per paper method (§4.2–§4.4), sharing the grid-over-
frames structure (the paper launches one RenderScript kernel per frame
batch; we launch one grid cell per frame × tile):

* ``basic_parallel``  (§4.2) — NCHW, whole frame per grid cell, reduction
  loops over (c, kh, kw) with the *spatial* map vectorized — channels are
  NOT on the lane axis, mirroring the paper's un-swapped layout.  The MXU
  stays idle; only the VPU spatial lanes are used.
* ``basic_simd``      (§4.3) — NHWC after dimension swapping: channels on
  the 128-lane minor axis; grid cell (frame, oh-tile); per kernel position
  a [rows, C] × [C, OC] dot — the vectorized channel dot product — over
  one output-row band at a time.
* ``advanced_simd``   (§4.4) — NHWC + output-channel blocking: grid cell
  (frame, oh-tile, oc-tile); an im2col patch matrix [rows, KH·KW·C] built
  once per spatial tile in VMEM is reused for the whole 128-wide oc tile
  (the paper's 4/8-outputs-per-thread reuse at MXU width), with bias+ReLU
  fused in the epilogue.

Spatial tiling (the ``oh_block`` knob): both SIMD kernels split the output
height into bands of ``oh_block`` rows.  Each grid cell loads only the
input-row band its output band needs — ``(oh_block-1)*stride + KH`` rows
including the halo, addressed stride-aware with an element-offset
(``pl.Unblocked``) BlockSpec so neighbouring bands may overlap by the
``KH - stride`` halo rows.  ``oh_block=None`` picks the largest band whose
working set (input band + im2col patches + weights + output block) fits
``VMEM_BUDGET_BYTES`` — so frames far larger than VMEM (e.g. 512×512×64)
run on the same ladder instead of trying to stage the whole padded frame.

VMEM budget: block shapes keep the minor dimension lane-aligned when the
channel count allows (ops.py pads channels — the paper's divisible-by-4
observation at lane width 128/8); the heuristic targets half of the ~16 MB
per-core VMEM to leave room for double buffering.

Fused pooling epilogue (super-layers): both SIMD kernels accept an
optional ``pool=(pkh, pkw, psy, psx, kind, pool_relu)``.  A grid cell then
computes the conv-output band that feeds ``ph_block`` *pooled* rows — the
conv band is ``(ph_block-1)*psy + pkh`` rows, i.e. the oh-band snapped to
the pool stride and widened by the pool-window halo — applies bias+ReLU,
pools it in VMEM (``pool2d.kernels.pool_band``), and writes only the
pooled band.  The intermediate conv activation never touches HBM: one
dispatch, one HBM write, for what the per-layer ladder did in two passes.

Fused LRN epilogue (one stage further): an optional
``lrn=(n, alpha, beta, k)`` extends the fused cell to
conv→bias→ReLU→pool→LRN.  The channel-axis sum-of-squares runs over the
in-VMEM pooled band (fp32, ``lrn_band``) with the same asymmetric window
padding as ``engine._lrn`` — window ``[c - n//2, c + (n-1)//2]``, so even
``n`` stays C-channels-in/C-channels-out — and only the *normalized* band
is written.  AlexNet's two ``conv→relu→pool→norm`` runs become single
dispatches.  LRN needs every output channel of a pooled row in one cell,
so the advanced kernel drops its oc-grid blocking to one full-width tile
when ``lrn`` is set (the working-set model below charges for it).

``fused_cell_bytes`` is the shared VMEM working-set model for one fused
grid cell (halo-widened input band + patch staging + weights + conv band
+ pooled band); ``auto_ph_block`` walks it to pick the largest pooled
band that fits the budget, and the fusion planner
(``repro.core.fusion``) evaluates the same model at the one-pool-window
floor to decline fusion for shapes whose smallest possible cell would
still bust the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Target working set per grid cell — half the ~16 MB/core VMEM, leaving the
# other half for the pipeline's double buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _out_size(size, k, stride, pad):
    return (size + 2 * pad - k) // stride + 1


def _band_rows(oh_block, kh, sy):
    """Input rows one output band needs: oh_block strided rows + halo."""
    return (oh_block - 1) * sy + kh


def auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                  budget: int = VMEM_BUDGET_BYTES, itemsize: int = 4,
                  im2col: bool = True) -> int:
    """Largest output-row band whose per-cell working set fits ``budget``.

    Working set (fp32 staging): the input row band, the patch staging, one
    weight block, and the output block.  ``im2col=True`` (advanced kernel)
    charges the full [rows, KH*KW*C] patch matrix; ``im2col=False`` (basic
    kernel) charges only the single [rows, C] slice it holds at a time.
    Candidates walk down from the whole frame through powers of two; the
    floor is a single output row.
    """
    patch_c = kh * kw * c if im2col else c
    candidates = [oh] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)
                         if b < oh]
    for ohb in candidates:
        band = _band_rows(ohb, kh, sy)
        need = (band * wp * c          # input row band (incl. halo)
                + ohb * ow * patch_c       # patch staging
                + kh * kw * c * oc_block   # weight block
                + ohb * ow * oc_block      # output block / accumulator
                ) * itemsize
        if need <= budget:
            return ohb
    return 1


def resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                     im2col: bool = True) -> int:
    """The output-row band a SIMD kernel will actually run with: the auto
    heuristic when ``oh_block`` is None, else the clamped explicit value.
    Public so benches/tools can report the executed geometry."""
    if oh_block is None:
        return auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                             im2col=im2col)
    return max(1, min(oh_block, oh))


def fused_cell_bytes(phb, ow, wp, c, kh, kw, sy, oc_block, pool,
                     im2col: bool = True, itemsize: int = 4) -> int:
    """Modelled VMEM working set of ONE fused conv→pool(→LRN) grid cell.

    ``phb`` pooled rows ⇒ ``(phb-1)*psy + pkh`` conv rows ⇒
    ``(cband-1)*sy + kh`` input rows (halo included).  Charged terms, all
    fp32 staging: the halo-widened input band, the patch staging (full
    im2col matrix for the advanced kernel, one [rows, C] slice for the
    basic kernel), one weight block, the conv-band accumulator, and the
    pooled output band.  The same model backs both the kernel-side
    ``auto_ph_block`` walk and the planner's decline-to-fuse check, so
    the planner never forms a group the kernel cannot stage.
    """
    pkh, pkw, psy, psx = pool
    pw = (ow - pkw) // psx + 1
    cband = (phb - 1) * psy + pkh          # conv rows per cell
    band = (cband - 1) * sy + kh           # input rows per cell (halo incl.)
    patch_c = kh * kw * c if im2col else c
    return (band * wp * c                  # halo-widened input band
            + cband * ow * patch_c        # patch staging
            + kh * kw * c * oc_block      # weight block
            + cband * ow * oc_block       # conv band accumulator
            + phb * pw * oc_block         # pooled (normalized) output band
            ) * itemsize


def auto_ph_block(ph, ow, wp, c, kh, kw, sy, oc_block, pool,
                  budget: int = VMEM_BUDGET_BYTES,
                  im2col: bool = True) -> int:
    """Largest pooled-row band whose fused-cell working set fits
    ``budget``; floors at one pooled row (one pool window of conv rows —
    which may exceed the soft budget: the planner's job is to keep such
    shapes un-fused in the first place)."""
    candidates = [ph] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)
                         if b < ph]
    for phb in candidates:
        if fused_cell_bytes(phb, ow, wp, c, kh, kw, sy, oc_block, pool,
                            im2col=im2col) <= budget:
            return phb
    return 1


def lrn_band(x, n, alpha, beta, k):
    """AlexNet-style LRN over the channel (minor) axis of an fp32 band.

    Window ``[c - n//2, c + (n-1)//2]`` with zero padding — the same
    asymmetric split as ``engine._lrn``, so even ``n`` keeps C channels.
    Unrolled shifted-slice accumulation (``n`` is small and static):
    pure VPU work on data already in VMEM.
    """
    c = x.shape[-1]
    sq = x * x
    lo, hi = n // 2, n - 1 - n // 2
    sq_p = jnp.pad(sq, [(0, 0)] * (x.ndim - 1) + [(lo, hi)])
    acc = jnp.zeros_like(x)
    for i in range(n):
        acc = acc + jax.lax.slice_in_dim(sq_p, i, i + c, axis=x.ndim - 1)
    return x / (k + alpha * acc) ** beta


# ---------------------------------------------------------------------------
# §4.2 basic parallel — NCHW, no channel vectorization
# ---------------------------------------------------------------------------


def _basic_parallel_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                           relu):
    # x_ref: [C, H, W]; w_ref: [OC, C, KH, KW]; o_ref: [OC, OH, OW]
    oc, ohh, oww = o_ref.shape
    c = x_ref.shape[0]
    acc = jnp.zeros((oc, ohh, oww), jnp.float32)
    for ci in range(c):  # channels OUTER (un-swapped layout: no lane reuse)
        plane = x_ref[ci]  # [H, W]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    plane, (i, j),
                    (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1),
                    (sy, sx),
                )  # [OH, OW] — spatial lanes only
                acc = acc + (patch.astype(jnp.float32)[None] *
                             w_ref[:, ci, i, j].astype(jnp.float32)
                             [:, None, None])
    acc = acc + b_ref[...].astype(jnp.float32)[:, None, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def conv2d_basic_parallel(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                          interpret: bool = False):
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[2], xp.shape[3]
    kern = functools.partial(_basic_parallel_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, c, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((oc, c, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, oc, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oc, oh, ow), x.dtype),
        interpret=interpret,
    )(xp, w, b)


# ---------------------------------------------------------------------------
# shared oh-band plumbing for the SIMD kernels
# ---------------------------------------------------------------------------


def _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block, ow, oc_block,
                   im2col=True):
    """Resolve the band size and pad the input so every band is full.

    Returns ``(xp, ohb, n_tiles, band)`` where ``xp`` has enough bottom
    zero-rows that the last band — starting at ``(n_tiles-1)*ohb*sy`` and
    spanning ``band`` rows — stays in bounds; the surplus output rows are
    sliced off by the caller.
    """
    n, hp, wp, c = xp.shape
    ohb = resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                           im2col=im2col)
    n_tiles = -(-oh // ohb)
    ohp = n_tiles * ohb
    band = _band_rows(ohb, kh, sy)
    hp_need = (ohp - 1) * sy + kh
    if hp_need > hp:
        xp = jnp.pad(xp, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
    return xp, ohb, n_tiles, band


# ---------------------------------------------------------------------------
# shared pooled-band plumbing for the fused conv→ReLU→pool kernels
# ---------------------------------------------------------------------------


def _plan_pool_tiles(xp, oh, ow, kh, kw, sy, oh_block, oc_block, pool,
                     im2col=True):
    """Band geometry for a fused conv+pool cell.

    Resolves the pooled-row band directly from the fused-cell working-set
    model (``auto_ph_block``; an explicit ``oh_block`` is snapped down to
    whole pool windows: ``ph_block`` pooled rows ⇒ ``(ph_block-1)*psy +
    pkh`` conv rows per cell), then *equalizes* the bands — ``ph_block``
    is re-snapped to ``ceil(ph / n_tiles)`` so the last band covers its
    fair share instead of being a ragged remainder that still fetches a
    full band of (mostly pad) input rows.  Pads the input so every band
    stays in bounds.  Returns ``(xp, ph_block, n_tiles, band, cband, ph,
    pw, row_step)`` where ``band`` is input rows per cell, ``cband`` conv
    rows per cell, ``(ph, pw)`` the pooled output size, and ``row_step``
    the input-row stride between consecutive bands.

    Floor: a fused cell can never hold fewer than one pool window of conv
    rows, so a one-pooled-row cell may exceed the *soft*
    VMEM_BUDGET_BYTES target (half of VMEM) by up to the pool-window
    factor.  All paper shapes stay far under the hard limit; shapes whose
    floor cell busts the budget are kept un-fused by the planner's
    working-set check (``repro.core.fusion``).
    """
    pkh, pkw, psy, psx = pool
    n, hp, wp, c = xp.shape
    ph, pw = (oh - pkh) // psy + 1, (ow - pkw) // psx + 1
    if ph < 1 or pw < 1:
        raise ValueError(
            f"pool window ({pkh},{pkw}) larger than conv output ({oh},{ow})")
    if oh_block is None:
        phb = auto_ph_block(ph, ow, wp, c, kh, kw, sy, oc_block,
                            (pkh, pkw, psy, psx), im2col=im2col)
    else:
        # snap the explicit conv band to the pool stride: the largest
        # pooled-row count whose conv band fits inside the oh-band
        ohb = max(1, min(oh_block, oh))
        phb = max(1, (ohb - pkh) // psy + 1) if ohb >= pkh else 1
    phb = min(phb, ph)
    n_tiles = -(-ph // phb)
    # equalize: same tile count, smallest per-band size — the ragged last
    # band shrinks to its fair share and stops over-fetching pad rows
    phb = -(-ph // n_tiles)
    n_tiles = -(-ph // phb)
    cband = (phb - 1) * psy + pkh           # conv rows per cell
    band = (cband - 1) * sy + kh            # input rows per cell (halo incl.)
    row_step = phb * psy * sy
    hp_need = (n_tiles - 1) * row_step + band
    if hp_need > hp:
        xp = jnp.pad(xp, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
    return xp, phb, n_tiles, band, cband, ph, pw, row_step


def _pool_epilogue(acc, o_ref, pool, conv_relu, lrn=None):
    """Shared epilogue: bias-added fp32 conv rows → (ReLU) → pooled band
    → (LRN).

    ``acc``: [conv_rows * conv_ow, OC] fp32; writes o_ref [PH_BLK, PW, OC].
    ``lrn=(n, alpha, beta, k)`` normalizes the pooled band across channels
    before the (single) HBM write — the conv AND pooled activations both
    stay VMEM-resident.
    """
    from repro.kernels.pool2d.kernels import pool_band  # deferred: no cycle

    pkh, pkw, psy, psx, kind, pool_relu, conv_ow = pool
    phh, pww, oc = o_ref.shape
    if conv_relu:
        acc = jnp.maximum(acc, 0.0)
    cband = (phh - 1) * psy + pkh
    out = pool_band(acc.reshape(cband, conv_ow, oc), phh, pww,
                    pkh, pkw, psy, psx, kind)
    if pool_relu:
        out = jnp.maximum(out, 0.0)
    if lrn is not None:
        n, alpha, beta, k = lrn
        out = lrn_band(out, n, alpha, beta, k)
    o_ref[...] = out.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# §4.3 basic SIMD — NHWC, vectorized channel dot per kernel position
# ---------------------------------------------------------------------------


def _basic_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx, relu,
                       pool=None, lrn=None):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH, KW, C, OC];
    # o_ref: [OH_BLK, OW, OC] (unfused) or [PH_BLK, PW, OC] (fused pool)
    if pool is None:
        ohh, oww, oc = o_ref.shape
    else:
        pkh, _, psy, _, _, _, conv_ow = pool
        phh, _, oc = o_ref.shape
        ohh, oww = (phh - 1) * psy + pkh, conv_ow  # conv rows this cell owns
    x = x_ref[0]
    acc = jnp.zeros((ohh * oww, oc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1)  # [rows, C] — C on the lane axis
            acc = acc + jnp.dot(
                patch.astype(jnp.float32),
                w_ref[i, j].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )  # vectorized dot over channels (the paper's 4-wide, here 128)
    acc = acc + b_ref[...].astype(jnp.float32)
    if pool is not None:  # fused super-layer: pool in VMEM, write pooled band
        _pool_epilogue(acc, o_ref, pool, relu, lrn)
        return
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, oc).astype(o_ref.dtype)


def conv2d_basic_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                      relu=False, oh_block=None, interpret: bool = False,
                      pool_kernel=None, pool_stride=None,
                      pool_kind: str = "max", pool_relu: bool = False,
                      lrn=None):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    if lrn is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    if pool_kernel is not None:
        # fused super-layer: each cell writes a pooled band, the conv
        # activation stays in VMEM
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        xp, phb, n_tiles, band, _, ph, pw, row_step = _plan_pool_tiles(
            xp, oh, ow, kh, kw, sy, oh_block, oc,
            (pkh, pkw, psy, psx), im2col=False)
        pool = (pkh, pkw, psy, psx, pool_kind, pool_relu, ow)
        out_rows, out_cols = phb, pw
    else:
        xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                                ow, oc, im2col=False)
        pool = None
        row_step = ohb * sy
        out_rows, out_cols = ohb, ow
    wp = xp.shape[2]
    kern = functools.partial(_basic_simd_kernel, kh=kh, kw=kw, sy=sy, sx=sx,
                             relu=relu, pool=pool, lrn=lrn)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh, kw, c, oc), lambda i, t: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((None, out_rows, out_cols, oc),
                               lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * out_rows, out_cols, oc),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, w_hwio, b)
    return out[:, :ph] if pool_kernel is not None else out[:, :oh]


# ---------------------------------------------------------------------------
# §4.4 advanced SIMD — im2col in VMEM + output-channel blocking + epilogue
# ---------------------------------------------------------------------------


def _advanced_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                          relu, pool=None, lrn=None):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH*KW*C, OC_BLK];
    # o_ref: [OH_BLK, OW, OC_BLK] (unfused) or [PH_BLK, PW, OC_BLK] (fused)
    if pool is None:
        ohh, oww, ocb = o_ref.shape
    else:
        pkh, _, psy, _, _, _, conv_ow = pool
        phh, _, ocb = o_ref.shape
        ohh, oww = (phh - 1) * psy + pkh, conv_ow  # conv rows this cell owns
    x = x_ref[0]
    cols = []
    for i in range(kh):  # im2col built once per spatial tile, reused for
        for j in range(kw):  # the whole 128-wide output-channel block (§4.4)
            cols.append(jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1))
    patches = jnp.concatenate(cols, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(patches.astype(jnp.float32), w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)  # one MXU matmul
    acc = acc + b_ref[...].astype(jnp.float32)
    if pool is not None:  # fused super-layer: pool in VMEM, write pooled band
        _pool_epilogue(acc, o_ref, pool, relu, lrn)
        return
    if relu:  # fused epilogue in VMEM — zero-cost ReLU (Fig. 5)
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, ocb).astype(o_ref.dtype)


def conv2d_advanced_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                         relu=False, oc_block: int = 128, oh_block=None,
                         interpret: bool = False, pool_kernel=None,
                         pool_stride=None, pool_kind: str = "max",
                         pool_relu: bool = False, lrn=None):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    if lrn is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    # LRN reaches across ALL output channels of a pooled row, so the oc
    # grid collapses to one full-width tile when the epilogue is fused
    # (the planner's working-set check charges the full-width weights)
    ocb = oc if lrn is not None else min(oc_block, oc)
    pad_oc = (-oc) % ocb
    wmat = w_hwio.reshape(kh * kw * c, oc)
    if pad_oc:
        wmat = jnp.pad(wmat, ((0, 0), (0, pad_oc)))
        b = jnp.pad(b, (0, pad_oc))
    ocp = oc + pad_oc
    if pool_kernel is not None:
        # fused super-layer: each cell writes a pooled band, the conv
        # activation stays in VMEM
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        xp, phb, n_tiles, band, _, ph, pw, row_step = _plan_pool_tiles(
            xp, oh, ow, kh, kw, sy, oh_block, ocb, (pkh, pkw, psy, psx))
        pool = (pkh, pkw, psy, psx, pool_kind, pool_relu, ow)
        out_rows, out_cols = phb, pw
    else:
        xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                                ow, ocb)
        pool = None
        row_step = ohb * sy
        out_rows, out_cols = ohb, ow
    wp = xp.shape[2]
    kern = functools.partial(_advanced_simd_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu, pool=pool, lrn=lrn)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles, ocp // ocb),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t, o: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh * kw * c, ocb), lambda i, t, o: (0, o)),
            pl.BlockSpec((ocb,), lambda i, t, o: (o,)),
        ],
        out_specs=pl.BlockSpec((None, out_rows, out_cols, ocb),
                               lambda i, t, o: (i, t, 0, o)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * out_rows, out_cols, ocp),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, wmat, b)
    if pool_kernel is not None:
        return out[:, :ph, :, :oc]
    return out[:, :oh, :, :oc]
