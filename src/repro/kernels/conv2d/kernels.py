"""The CNNdroid conv ladder as Pallas TPU kernels.

Three kernels, one per paper method (§4.2–§4.4), sharing the grid-over-
frames structure (the paper launches one RenderScript kernel per frame
batch; we launch one grid cell per frame × tile):

* ``basic_parallel``  (§4.2) — NCHW, whole frame per grid cell, reduction
  loops over (c, kh, kw) with the *spatial* map vectorized — channels are
  NOT on the lane axis, mirroring the paper's un-swapped layout.  The MXU
  stays idle; only the VPU spatial lanes are used.
* ``basic_simd``      (§4.3) — NHWC after dimension swapping: channels on
  the 128-lane minor axis; grid cell (frame, oh-tile); per kernel position
  a [rows, C] × [C, OC] dot — the vectorized channel dot product — over
  one output-row band at a time.
* ``advanced_simd``   (§4.4) — NHWC + output-channel blocking: grid cell
  (frame, oh-tile, oc-tile); an im2col patch matrix [rows, KH·KW·C] built
  once per spatial tile in VMEM is reused for the whole 128-wide oc tile
  (the paper's 4/8-outputs-per-thread reuse at MXU width), with bias+ReLU
  fused in the epilogue.

Spatial tiling (the ``oh_block`` knob): both SIMD kernels split the output
height into bands of ``oh_block`` rows.  Each grid cell loads only the
input-row band its output band needs — ``(oh_block-1)*stride + KH`` rows
including the halo, addressed stride-aware with an element-offset
(``pl.Unblocked``) BlockSpec so neighbouring bands may overlap by the
``KH - stride`` halo rows.  ``oh_block=None`` picks the largest band whose
working set (input band + im2col patches + weights + output block) fits
``VMEM_BUDGET_BYTES`` — so frames far larger than VMEM (e.g. 512×512×64)
run on the same ladder instead of trying to stage the whole padded frame.

VMEM budget: block shapes keep the minor dimension lane-aligned when the
channel count allows (ops.py pads channels — the paper's divisible-by-4
observation at lane width 128/8); the heuristic targets half of the ~16 MB
per-core VMEM to leave room for double buffering.

Fused pooling epilogue (super-layers): both SIMD kernels accept an
optional ``pool=(pkh, pkw, psy, psx, kind, pool_relu)``.  A grid cell then
computes the conv-output band that feeds ``ph_block`` *pooled* rows — the
conv band is ``(ph_block-1)*psy + pkh`` rows, i.e. the oh-band snapped to
the pool stride and widened by the pool-window halo — applies bias+ReLU,
pools it in VMEM (``pool2d.kernels.pool_band``), and writes only the
pooled band.  The intermediate conv activation never touches HBM: one
dispatch, one HBM write, for what the per-layer ladder did in two passes.

Fused LRN epilogue (one stage further): an optional
``lrn=(n, alpha, beta, k)`` extends the fused cell to
conv→bias→ReLU→pool→LRN.  The channel-axis sum-of-squares runs over the
in-VMEM pooled band (fp32, ``lrn_band``) with the same asymmetric window
padding as ``engine._lrn`` — window ``[c - n//2, c + (n-1)//2]``, so even
``n`` stays C-channels-in/C-channels-out — and only the *normalized* band
is written.  AlexNet's two ``conv→relu→pool→norm`` runs become single
dispatches.  LRN needs every output channel of a pooled row in one cell,
so the advanced kernel drops its oc-grid blocking to one full-width tile
when ``lrn`` is set (the working-set model below charges for it) —
unless the *two-pass channel-halo* cell applies: ``resolve_lrn_ocb``
restores oc blocking by widening each weight tile with the LRN window's
``n - 1`` neighbour columns (zero columns past the frame edges), so a
tile computes the conv channels its own LRN windows read and
``lrn_band_halo`` keeps the ``ocb`` core at the store.

Sliding-window pool accumulator (``resolve_pool_carry``): when adjacent
pooled bands overlap (``K = pkh - psy >= 1`` conv rows), the carry cell
convolves only the ``R = ph_block*psy`` fresh rows per band step and
keeps the K boundary rows in VMEM scratch across the sequential band
axis — one extra seed step (its output block is sliced off) trades the
per-band halo re-read and re-convolution for a K-row scratch carry.

``fused_cell_bytes`` is the shared VMEM working-set model for one fused
grid cell (halo-widened input band + patch staging + weights + conv band
+ pooled band); ``auto_ph_block`` walks it to pick the largest pooled
band that fits the budget, and the fusion planner
(``repro.core.fusion``) evaluates the same model at the one-pool-window
floor to decline fusion for shapes whose smallest possible cell would
still bust the budget.

Fused conv→conv chains (VMEM-resident halo): ``conv2d_chain_simd`` runs
a whole run of consecutive convolutions as ONE grid cell per output-row
band — the cell computes a band of conv A, keeps it in VMEM, and
immediately convolves it with conv B's weights, with bias+ReLU between
stages and the pool/LRN epilogue allowed on the tail.  The band math
composes backwards across stages: a band of ``ohb`` final rows needs
``(ohb-1)*sB + kB`` rows of A's output, hence
``((ohb-1)*sB + kB - 1)*sA + kA`` input rows (``chain_band_geometry``).
Intermediate vertical padding cannot be materialized host-side (the pad
rows are *activation* zeros, not conv-of-zero-input), so each
intermediate stage zero-masks the rows of its band that fall outside its
valid output range — those rows ARE the next stage's padding.  Stage N+1
consumes every output channel of stage N, so chain cells run all stages
at full oc width (no oc-grid blocking); ``chain_cell_bytes`` /
``auto_chain_block`` generalize the working-set model to the per-stage
live sets (weights of every stage stay resident; the per-stage
band+patch temporaries are sequential, so their *maximum* is charged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ACC_DTYPE

# Target working set per grid cell — half the ~16 MB/core VMEM, leaving the
# other half for the pipeline's double buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# Chain cells keep every stage's full-width weights resident (stage N+1
# consumes every output channel of stage N, so there is no oc tile to
# shrink them).  Weights are grid-invariant — fetched once, never
# double-buffered — so the chain check runs against near-full VMEM
# capacity (16 MB minus pipeline headroom) instead of the half-capacity
# streaming budget; the streamed input/output bands are charged on top of
# the per-stage live set, standing in for their double buffers.
CHAIN_VMEM_BUDGET_BYTES = 14 * 1024 * 1024


def _out_size(size, k, stride, pad):
    return (size + 2 * pad - k) // stride + 1


def _band_rows(oh_block, kh, sy):
    """Input rows one output band needs: oh_block strided rows + halo."""
    return (oh_block - 1) * sy + kh


def band_intervals(n_tiles, blk, total, row_step, band, base: int = 0):
    """Per-grid-cell ``(start, rows)`` intervals of a banded kernel grid.

    Returns ``(out_iv, in_iv)``: ``out_iv[t]`` is cell ``t``'s output
    band in output-row coordinates with ``rows`` clipped to the ``total``
    valid rows (the surplus rows of a ragged last band are sliced off by
    the caller), and ``in_iv[t]`` the input-row band the cell stages, in
    padded-input coordinates — ``start = base + t*row_step``; a negative
    ``base`` means the caller pre-pads that many extra top zero rows (the
    chain cells' intermediate vertical padding).  ONE copy of the
    tile-planning math: ``_plan_oh_tiles`` / ``_plan_pool_tiles`` /
    ``pool2d_nhwc`` derive their bottom-padding need from ``in_iv[-1]``,
    and the static plan verifier (``repro.analysis.verifier``) proves
    band coverage over the same lists the kernels execute.
    """
    out_iv = [(t * blk, max(0, min(blk, total - t * blk)))
              for t in range(n_tiles)]
    in_iv = [(base + t * row_step, band) for t in range(n_tiles)]
    return out_iv, in_iv


def conv_cell_bytes(ohb, ow, wp, c, kh, kw, sy, oc_block,
                    im2col: bool = True, itemsize: int = 4) -> int:
    """Modelled VMEM working set of ONE un-fused conv grid cell (fp32
    staging): the halo-inclusive input row band, the patch staging (full
    im2col matrix for the advanced kernels, one [rows, C] slice for the
    basic kernel), one weight block, and the output accumulator.  Shared
    by the ``auto_oh_block`` walk and the static plan verifier's budget
    audit."""
    patch_c = kh * kw * c if im2col else c
    band = _band_rows(ohb, kh, sy)
    return (band * wp * c              # input row band (incl. halo)
            + ohb * ow * patch_c       # patch staging
            + kh * kw * c * oc_block   # weight block
            + ohb * ow * oc_block      # output block / accumulator
            ) * itemsize


def conv_macs(oh, ow, cin, kh, kw, oc) -> int:
    """Multiply-accumulates of ONE conv frame (``oh×ow×oc`` outputs, each
    reducing over ``cin×kh×kw``).  The arithmetic half of the analytic
    cost model (``repro.core.cost``) — every ladder method computes
    exactly these MACs; they differ only in achieved throughput."""
    return oh * ow * oc * cin * kh * kw


def band_overfetch_factor(n_tiles, band, padded_h) -> float:
    """HBM input-traffic multiplier of a banded dispatch: neighbouring
    bands re-fetch their halo rows, so one frame streams ``n_tiles *
    band`` input rows instead of the ``padded_h`` it holds.  ≥ 1.0 by
    construction (a single whole-frame band streams each row once).  The
    memory half of the analytic cost model — shrinking ``oh_block`` buys
    VMEM at the price of this factor."""
    if padded_h <= 0:
        return 1.0
    return max(1.0, (n_tiles * band) / padded_h)


def auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                  budget: int = VMEM_BUDGET_BYTES, itemsize: int = 4,
                  im2col: bool = True) -> int:
    """Largest output-row band whose per-cell working set
    (``conv_cell_bytes``) fits ``budget``.  Candidates walk down from the
    whole frame through powers of two; the floor is a single output row.
    """
    candidates = [oh] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)
                         if b < oh]
    for ohb in candidates:
        if conv_cell_bytes(ohb, ow, wp, c, kh, kw, sy, oc_block,
                           im2col=im2col, itemsize=itemsize) <= budget:
            return ohb
    return 1


def resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                     im2col: bool = True) -> int:
    """The output-row band a SIMD kernel will actually run with: the auto
    heuristic when ``oh_block`` is None, else the clamped explicit value.
    Public so benches/tools can report the executed geometry."""
    if oh_block is None:
        return auto_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block,
                             im2col=im2col)
    return max(1, min(oh_block, oh))


def fused_cell_bytes(phb, ow, wp, c, kh, kw, sy, oc_block, pool,
                     im2col: bool = True, itemsize: int = 4,
                     oc_halo: int = 0) -> int:
    """Modelled VMEM working set of ONE fused conv→pool(→LRN) grid cell.

    ``phb`` pooled rows ⇒ ``(phb-1)*psy + pkh`` conv rows ⇒
    ``(cband-1)*sy + kh`` input rows (halo included).  Charged terms, all
    fp32 staging: the halo-widened input band, the patch staging (full
    im2col matrix for the advanced kernel, one [rows, C] slice for the
    basic kernel), one weight block, the conv-band accumulator, and the
    pooled output band.  ``oc_halo`` widens every oc-tile term by the
    LRN window's ``n - 1`` neighbour columns for the two-pass
    channel-halo cell (0 for the classic cells).  The same model backs
    both the kernel-side ``auto_ph_block`` walk and the planner's
    decline-to-fuse check, so the planner never forms a group the kernel
    cannot stage.
    """
    pkh, pkw, psy, psx = pool
    pw = (ow - pkw) // psx + 1
    cband = (phb - 1) * psy + pkh          # conv rows per cell
    band = (cband - 1) * sy + kh           # input rows per cell (halo incl.)
    patch_c = kh * kw * c if im2col else c
    ocw = oc_block + oc_halo               # halo-widened oc tile
    return (band * wp * c                  # halo-widened input band
            + cband * ow * patch_c        # patch staging
            + kh * kw * c * ocw           # weight block
            + cband * ow * ocw            # conv band accumulator
            + phb * pw * ocw              # pooled (normalized) output band
            ) * itemsize


def auto_ph_block(ph, ow, wp, c, kh, kw, sy, oc_block, pool,
                  budget: int = VMEM_BUDGET_BYTES,
                  im2col: bool = True, oc_halo: int = 0) -> int:
    """Largest pooled-row band whose fused-cell working set fits
    ``budget``; floors at one pooled row (one pool window of conv rows —
    which may exceed the soft budget: the planner's job is to keep such
    shapes un-fused in the first place)."""
    candidates = [ph] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1)
                         if b < ph]
    for phb in candidates:
        if fused_cell_bytes(phb, ow, wp, c, kh, kw, sy, oc_block, pool,
                            im2col=im2col, oc_halo=oc_halo) <= budget:
            return phb
    return 1


def _equalize_bands(blk, target):
    """Clamp a band size to ``target`` rows, then re-snap it to
    ``ceil(target / n_tiles)`` so the ragged last band shrinks to its fair
    share instead of fetching a full band of mostly-pad input rows.
    Returns ``(blk, n_tiles)``."""
    blk = max(1, min(blk, target))
    n_tiles = -(-target // blk)
    blk = -(-target // n_tiles)
    return blk, -(-target // blk)


def resolve_ph_block(ph, oh, ow, wp, c, kh, kw, sy, oc_block, pool, oh_block,
                     im2col: bool = True, oc_halo: int = 0) -> tuple:
    """The equalized pooled-row band a fused conv+pool cell will execute
    with, as ``(ph_block, n_tiles)``: the ``auto_ph_block`` walk when
    ``oh_block`` is None, else the explicit conv band snapped down to
    whole pool windows.  Public so the engine's geometry report shares
    the exact resolution the kernels run."""
    pkh, _, psy, _ = pool
    if oh_block is None:
        phb = auto_ph_block(ph, ow, wp, c, kh, kw, sy, oc_block, pool,
                            im2col=im2col, oc_halo=oc_halo)
    else:
        # snap the explicit conv band to the pool stride: the largest
        # pooled-row count whose conv band fits inside the oh-band
        ohb = max(1, min(oh_block, oh))
        phb = max(1, (ohb - pkh) // psy + 1) if ohb >= pkh else 1
    return _equalize_bands(phb, ph)


def lrn_band(x, n, alpha, beta, k):
    """AlexNet-style LRN over the channel (minor) axis of an fp32 band.

    Window ``[c - n//2, c + (n-1)//2]`` with zero padding — the same
    asymmetric split as ``engine._lrn``, so even ``n`` keeps C channels.
    Unrolled shifted-slice accumulation (``n`` is small and static):
    pure VPU work on data already in VMEM.
    """
    c = x.shape[-1]
    sq = x * x
    lo, hi = n // 2, n - 1 - n // 2
    sq_p = jnp.pad(sq, [(0, 0)] * (x.ndim - 1) + [(lo, hi)])
    acc = jnp.zeros_like(x)
    for i in range(n):
        acc = acc + jax.lax.slice_in_dim(sq_p, i, i + c, axis=x.ndim - 1)
    return x / (k + alpha * acc) ** beta


def lrn_band_halo(x, n, alpha, beta, k):
    """LRN over a channel-halo-widened band (the two-pass oc-blocked
    cell): ``x``'s minor axis holds ``ocb + n - 1`` conv channels — the
    tile's own ``ocb`` plus the ``n//2`` / ``n-1-n//2`` neighbour columns
    the window reaches into.  Zero weight columns at the frame edges make
    the halo channels exact zeros there, reproducing ``lrn_band``'s
    zero-padded window without any in-kernel padding.  Returns the
    normalized ``ocb``-wide core.
    """
    lo = n // 2
    ocb = x.shape[-1] - (n - 1)
    sq = x * x
    acc = jax.lax.slice_in_dim(sq, 0, ocb, axis=x.ndim - 1)
    for i in range(1, n):
        acc = acc + jax.lax.slice_in_dim(sq, i, i + ocb, axis=x.ndim - 1)
    core = jax.lax.slice_in_dim(x, lo, lo + ocb, axis=x.ndim - 1)
    return core / (k + alpha * acc) ** beta


def resolve_lrn_ocb(oc, oc_block, lrn, lrn_oc_block, ow, wp, c, kh, kw, sy,
                    pool, im2col: bool = True) -> tuple:
    """Resolve ``(ocb, oc_halo)`` for a fused conv→pool→LRN cell.

    The classic cell runs LRN at one full-width oc tile (the window reads
    every channel of a pooled row).  The two-pass channel-halo cell
    restores oc blocking by widening each weight tile with the window's
    ``n - 1`` neighbour columns so a tile can normalize its own ``ocb``
    channels locally.  Auto (``lrn_oc_block=None``) keeps the historical
    full-width tile whenever even the one-pooled-row floor cell fits the
    budget — default plans stay byte-identical — and blocks otherwise;
    ``True`` forces blocking, ``False`` forces full width.  Shared by the
    kernel dispatch, the fusion planner, and the verifier; the sanitizer
    re-derives it independently (Phase A).
    """
    if lrn is None or not im2col:
        return (min(oc_block, oc) if im2col else oc), 0
    blocked = min(oc_block, oc)
    if blocked >= oc or lrn_oc_block is False:
        return oc, 0
    if lrn_oc_block is None and fused_cell_bytes(
            1, ow, wp, c, kh, kw, sy, oc, pool) <= VMEM_BUDGET_BYTES:
        return oc, 0
    return blocked, lrn[0] - 1


def resolve_pool_carry(pool_carry, im2col, lrn, pool, phb, n_tiles) -> bool:
    """Whether a fused conv→pool dispatch runs the sliding-window carry
    cell: adjacent oh-bands share ``K = pkh - psy`` boundary conv rows,
    and the carry cell keeps them in VMEM scratch between band steps
    instead of re-convolving them.  Requires the im2col kernel, no LRN
    epilogue, overlapping pool windows (``K >= 1``) that fit inside one
    band's fresh rows (``K <= phb*psy``), and more than one band.
    ``pool_carry``: None = auto (on when feasible), False = off, True =
    requested (still falls back to off when infeasible).  Shared by the
    kernel dispatch, the fusion planner, and the verifier; the sanitizer
    re-derives it independently (Phase A)."""
    if pool is None or lrn is not None or not im2col or pool_carry is False:
        return False
    pkh, _, psy, _ = pool
    k_rows = pkh - psy
    return 1 <= k_rows <= phb * psy and n_tiles > 1


# ---------------------------------------------------------------------------
# §4.2 basic parallel — NCHW, no channel vectorization
# ---------------------------------------------------------------------------


def _basic_parallel_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                           relu):
    # x_ref: [C, H, W]; w_ref: [OC, C, KH, KW]; o_ref: [OC, OH, OW]
    oc, ohh, oww = o_ref.shape
    c = x_ref.shape[0]
    acc = jnp.zeros((oc, ohh, oww), jnp.float32)
    for ci in range(c):  # channels OUTER (un-swapped layout: no lane reuse)
        plane = x_ref[ci]  # [H, W]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    plane, (i, j),
                    (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1),
                    (sy, sx),
                )  # [OH, OW] — spatial lanes only
                acc = acc + (patch.astype(ACC_DTYPE)[None] *
                             w_ref[:, ci, i, j].astype(ACC_DTYPE)
                             [:, None, None])
    acc = acc + b_ref[...].astype(ACC_DTYPE)[:, None, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def conv2d_basic_parallel(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                          interpret: bool = False):
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[2], xp.shape[3]
    kern = functools.partial(_basic_parallel_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, c, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((oc, c, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, oc, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oc, oh, ow), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        ),
        interpret=interpret,
    )(xp, w, b)


# ---------------------------------------------------------------------------
# shared oh-band plumbing for the SIMD kernels
# ---------------------------------------------------------------------------


def _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block, ow, oc_block,
                   im2col=True):
    """Resolve the band size and pad the input so every band is full.

    Returns ``(xp, ohb, n_tiles, band)`` where ``xp`` has enough bottom
    zero-rows that the last band — starting at ``(n_tiles-1)*ohb*sy`` and
    spanning ``band`` rows — stays in bounds; the surplus output rows are
    sliced off by the caller.
    """
    n, hp, wp, c = xp.shape
    ohb = resolve_oh_block(oh, ow, wp, c, kh, kw, sy, oc_block, oh_block,
                           im2col=im2col)
    n_tiles = -(-oh // ohb)
    band = _band_rows(ohb, kh, sy)
    _, in_iv = band_intervals(n_tiles, ohb, oh, ohb * sy, band)
    hp_need = in_iv[-1][0] + band
    if hp_need > hp:
        xp = jnp.pad(xp, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
    return xp, ohb, n_tiles, band


# ---------------------------------------------------------------------------
# shared pooled-band plumbing for the fused conv→ReLU→pool kernels
# ---------------------------------------------------------------------------


def _plan_pool_tiles(xp, oh, ow, kh, kw, sy, oh_block, oc_block, pool,
                     im2col=True, oc_halo=0):
    """Band geometry for a fused conv+pool cell.

    Resolves the pooled-row band directly from the fused-cell working-set
    model (``auto_ph_block``; an explicit ``oh_block`` is snapped down to
    whole pool windows: ``ph_block`` pooled rows ⇒ ``(ph_block-1)*psy +
    pkh`` conv rows per cell), then *equalizes* the bands — ``ph_block``
    is re-snapped to ``ceil(ph / n_tiles)`` so the last band covers its
    fair share instead of being a ragged remainder that still fetches a
    full band of (mostly pad) input rows.  Pads the input so every band
    stays in bounds.  Returns ``(xp, ph_block, n_tiles, band, cband, ph,
    pw, row_step)`` where ``band`` is input rows per cell, ``cband`` conv
    rows per cell, ``(ph, pw)`` the pooled output size, and ``row_step``
    the input-row stride between consecutive bands.

    Floor: a fused cell can never hold fewer than one pool window of conv
    rows, so a one-pooled-row cell may exceed the *soft*
    VMEM_BUDGET_BYTES target (half of VMEM) by up to the pool-window
    factor.  All paper shapes stay far under the hard limit; shapes whose
    floor cell busts the budget are kept un-fused by the planner's
    working-set check (``repro.core.fusion``).
    """
    pkh, pkw, psy, psx = pool
    n, hp, wp, c = xp.shape
    ph, pw = (oh - pkh) // psy + 1, (ow - pkw) // psx + 1
    if ph < 1 or pw < 1:
        raise ValueError(
            f"pool window ({pkh},{pkw}) larger than conv output ({oh},{ow})")
    phb, n_tiles = resolve_ph_block(ph, oh, ow, wp, c, kh, kw, sy, oc_block,
                                    pool, oh_block, im2col=im2col,
                                    oc_halo=oc_halo)
    cband = (phb - 1) * psy + pkh           # conv rows per cell
    band = (cband - 1) * sy + kh            # input rows per cell (halo incl.)
    row_step = phb * psy * sy
    _, in_iv = band_intervals(n_tiles, phb, ph, row_step, band)
    hp_need = in_iv[-1][0] + band
    if hp_need > hp:
        xp = jnp.pad(xp, ((0, 0), (0, hp_need - hp), (0, 0), (0, 0)))
    return xp, phb, n_tiles, band, cband, ph, pw, row_step


def _pool_epilogue(acc, o_ref, pool, conv_relu, lrn=None):
    """Shared epilogue: bias-added fp32 conv rows → (ReLU) → pooled band
    → (LRN).

    ``acc``: [conv_rows * conv_ow, OC] fp32; writes o_ref [PH_BLK, PW, OC].
    ``lrn=(n, alpha, beta, k)`` normalizes the pooled band across channels
    before the (single) HBM write — the conv AND pooled activations both
    stay VMEM-resident.
    """
    from repro.kernels.pool2d.kernels import pool_band  # deferred: no cycle

    pkh, pkw, psy, psx, kind, pool_relu, conv_ow = pool
    phh, pww, oc = o_ref.shape
    if conv_relu:
        acc = jnp.maximum(acc, 0.0)
    cband = (phh - 1) * psy + pkh
    out = pool_band(acc.reshape(cband, conv_ow, oc), phh, pww,
                    pkh, pkw, psy, psx, kind)
    if pool_relu:
        out = jnp.maximum(out, 0.0)
    if lrn is not None:
        n, alpha, beta, k = lrn
        out = lrn_band(out, n, alpha, beta, k)
    o_ref[...] = out.astype(o_ref.dtype)


def _pool_epilogue_halo(acc, o_ref, pool, conv_relu, lrn):
    """Channel-halo variant of ``_pool_epilogue`` for the oc-blocked LRN
    cell: ``acc`` holds ``ocb + n - 1`` conv channels (the tile's own
    plus the window's neighbour columns), the pooled band stays widened,
    and ``lrn_band_halo`` narrows it to the ``ocb`` core at the single
    HBM store.
    """
    from repro.kernels.pool2d.kernels import pool_band  # deferred: no cycle

    pkh, pkw, psy, psx, kind, pool_relu, conv_ow = pool
    phh, pww, ocb = o_ref.shape
    n, alpha, beta, k = lrn
    ocw = ocb + n - 1
    if conv_relu:
        acc = jnp.maximum(acc, 0.0)
    cband = (phh - 1) * psy + pkh
    wide = pool_band(acc.reshape(cband, conv_ow, ocw), phh, pww,
                     pkh, pkw, psy, psx, kind)
    if pool_relu:
        wide = jnp.maximum(wide, 0.0)
    o_ref[...] = lrn_band_halo(wide, n, alpha, beta, k).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# §4.3 basic SIMD — NHWC, vectorized channel dot per kernel position
# ---------------------------------------------------------------------------


def _basic_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx, relu,
                       pool=None, lrn=None):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH, KW, C, OC];
    # o_ref: [OH_BLK, OW, OC] (unfused) or [PH_BLK, PW, OC] (fused pool)
    if pool is None:
        ohh, oww, oc = o_ref.shape
    else:
        pkh, _, psy, _, _, _, conv_ow = pool
        phh, _, oc = o_ref.shape
        ohh, oww = (phh - 1) * psy + pkh, conv_ow  # conv rows this cell owns
    x = x_ref[0]
    acc = jnp.zeros((ohh * oww, oc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1)  # [rows, C] — C on the lane axis
            acc = acc + jnp.dot(
                patch.astype(ACC_DTYPE),
                w_ref[i, j].astype(ACC_DTYPE),
                preferred_element_type=jnp.float32,
            )  # vectorized dot over channels (the paper's 4-wide, here 128)
    acc = acc + b_ref[...].astype(ACC_DTYPE)
    if pool is not None:  # fused super-layer: pool in VMEM, write pooled band
        _pool_epilogue(acc, o_ref, pool, relu, lrn)
        return
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, oc).astype(o_ref.dtype)


def conv2d_basic_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                      relu=False, oh_block=None, interpret: bool = False,
                      pool_kernel=None, pool_stride=None,
                      pool_kind: str = "max", pool_relu: bool = False,
                      lrn=None):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    if lrn is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    if pool_kernel is not None:
        # fused super-layer: each cell writes a pooled band, the conv
        # activation stays in VMEM
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        xp, phb, n_tiles, band, _, ph, pw, row_step = _plan_pool_tiles(
            xp, oh, ow, kh, kw, sy, oh_block, oc,
            (pkh, pkw, psy, psx), im2col=False)
        pool = (pkh, pkw, psy, psx, pool_kind, pool_relu, ow)
        out_rows, out_cols = phb, pw
    else:
        xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                                ow, oc, im2col=False)
        pool = None
        row_step = ohb * sy
        out_rows, out_cols = ohb, ow
    wp = xp.shape[2]
    kern = functools.partial(_basic_simd_kernel, kh=kh, kw=kw, sy=sy, sx=sx,
                             relu=relu, pool=pool, lrn=lrn)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh, kw, c, oc), lambda i, t: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((None, out_rows, out_cols, oc),
                               lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * out_rows, out_cols, oc),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, w_hwio, b)
    return out[:, :ph] if pool_kernel is not None else out[:, :oh]


# ---------------------------------------------------------------------------
# §4.4 advanced SIMD — im2col in VMEM + output-channel blocking + epilogue
# ---------------------------------------------------------------------------


def _advanced_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                          relu, pool=None, lrn=None):
    # x_ref: [1, BAND, WP, C] (input-row band); w_ref: [KH*KW*C, OC_BLK];
    # o_ref: [OH_BLK, OW, OC_BLK] (unfused) or [PH_BLK, PW, OC_BLK] (fused)
    if pool is None:
        ohh, oww, ocb = o_ref.shape
    else:
        pkh, _, psy, _, _, _, conv_ow = pool
        phh, _, ocb = o_ref.shape
        ohh, oww = (phh - 1) * psy + pkh, conv_ow  # conv rows this cell owns
    x = x_ref[0]
    cols = []
    for i in range(kh):  # im2col built once per spatial tile, reused for
        for j in range(kw):  # the whole 128-wide output-channel block (§4.4)
            cols.append(jax.lax.slice(
                x, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1))
    patches = jnp.concatenate(cols, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(patches.astype(ACC_DTYPE), w_ref[...].astype(ACC_DTYPE),
                  preferred_element_type=jnp.float32)  # one MXU matmul
    acc = acc + b_ref[...].astype(ACC_DTYPE)
    if pool is not None:  # fused super-layer: pool in VMEM, write pooled band
        _pool_epilogue(acc, o_ref, pool, relu, lrn)
        return
    if relu:  # fused epilogue in VMEM — zero-cost ReLU (Fig. 5)
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, ocb).astype(o_ref.dtype)


def _advanced_simd_halo_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                               relu, pool, lrn):
    # two-pass channel-halo cell: w_ref/b_ref are widened to the tile's
    # ocb + n - 1 columns (its own channels plus the LRN window's
    # neighbours), so conv+pool run over the widened tile and
    # lrn_band_halo keeps only the ocb core at the store — oc blocking
    # and the LRN epilogue coexist.
    pkh, _, psy, _, _, _, conv_ow = pool
    phh = o_ref.shape[0]
    ohh, oww = (phh - 1) * psy + pkh, conv_ow  # conv rows this cell owns
    xin = x_ref[0]
    parts = []
    for i in range(kh):
        for j in range(kw):
            parts.append(jax.lax.slice(
                xin, (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 xin.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1))
    pmat = jnp.concatenate(parts, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(pmat.astype(ACC_DTYPE), w_ref[...].astype(ACC_DTYPE),
                  preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(ACC_DTYPE)
    _pool_epilogue_halo(acc, o_ref, pool, relu, lrn)


def _advanced_simd_carry_kernel(x_ref, w_ref, b_ref, o_ref, c_ref, *, kh, kw,
                                sy, sx, relu, pool, k_rows):
    # sliding-window pool accumulator: each band step convolves only its
    # R = PH_BLK*psy fresh conv rows; the K = pkh - psy boundary rows are
    # carried in VMEM scratch (c_ref) from the previous step instead of
    # being re-read and re-convolved from the input band.  Step 0 is a
    # pure seed band over the zero prepad (its output block is sliced off
    # host-side); its last K fresh rows are conv rows [0, K).
    pkh, _, psy, _, _, _, conv_ow = pool
    phh, _, ocb = o_ref.shape
    r_rows = phh * psy  # fresh conv rows this step owns
    xin = x_ref[0]
    parts = []
    for i in range(kh):
        for j in range(kw):
            parts.append(jax.lax.slice(
                xin, (i, j, 0),
                (i + (r_rows - 1) * sy + 1, j + (conv_ow - 1) * sx + 1,
                 xin.shape[2]),
                (sy, sx, 1),
            ).reshape(r_rows * conv_ow, -1))
    pmat = jnp.concatenate(parts, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(pmat.astype(ACC_DTYPE), w_ref[...].astype(ACC_DTYPE),
                  preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(ACC_DTYPE)
    fresh = acc.reshape(r_rows, conv_ow, ocb)
    whole = jnp.concatenate([c_ref[...], fresh], axis=0)
    _pool_epilogue(whole.reshape((k_rows + r_rows) * conv_ow, ocb), o_ref,
                   pool, relu)
    # slide the window: the LAST K fresh conv rows become the next band's
    # carried head (pre-ReLU fp32 — the epilogue re-applies ReLU on read,
    # so every pooled output still sees relu(conv) exactly once)
    c_ref[...] = jax.lax.slice_in_dim(fresh, r_rows - k_rows, r_rows, axis=0)


def conv2d_advanced_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                         relu=False, oc_block: int = 128, oh_block=None,
                         interpret: bool = False, pool_kernel=None,
                         pool_stride=None, pool_kind: str = "max",
                         pool_relu: bool = False, lrn=None, pool_carry=None,
                         lrn_oc_block=None):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    if lrn is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    if pool_kernel is not None:
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
    # LRN reaches across ALL output channels of a pooled row, so the oc
    # grid collapses to one full-width tile when the epilogue is fused
    # (the planner's working-set check charges the full-width weights) —
    # unless the two-pass channel-halo cell restores the blocking with
    # window-widened weight tiles (resolve_lrn_ocb decides; oc_halo > 0
    # selects the halo dispatch below)
    if lrn is not None:
        ocb, oc_halo = resolve_lrn_ocb(oc, oc_block, lrn, lrn_oc_block, ow,
                                       xp.shape[2], c, kh, kw, sy,
                                       (pkh, pkw, psy, psx))
    else:
        ocb, oc_halo = min(oc_block, oc), 0
    pad_oc = (-oc) % ocb
    wmat = w_hwio.reshape(kh * kw * c, oc)
    if pad_oc:
        wmat = jnp.pad(wmat, ((0, 0), (0, pad_oc)))
        b = jnp.pad(b, (0, pad_oc))
    ocp = oc + pad_oc
    if oc_halo:
        # widen the weight/bias columns by the LRN window reach; the halo
        # columns outside [0, ocp) are zero, so halo conv channels are
        # exact zeros at the frame edges (lrn_band's zero-pad semantics)
        halo_lo = lrn[0] // 2
        halo_hi = lrn[0] - 1 - halo_lo
        wmat = jnp.pad(wmat, ((0, 0), (halo_lo, halo_hi)))
        b = jnp.pad(b, (halo_lo, halo_hi))
    if pool_kernel is not None:
        # fused super-layer: each cell writes a pooled band, the conv
        # activation stays in VMEM
        xp, phb, n_tiles, band, _, ph, pw, row_step = _plan_pool_tiles(
            xp, oh, ow, kh, kw, sy, oh_block, ocb, (pkh, pkw, psy, psx),
            oc_halo=oc_halo)
        pool = (pkh, pkw, psy, psx, pool_kind, pool_relu, ow)
        out_rows, out_cols = phb, pw
        carry = resolve_pool_carry(pool_carry, True, lrn,
                                   (pkh, pkw, psy, psx), phb, n_tiles)
    else:
        xp, ohb, n_tiles, band = _plan_oh_tiles(xp, oh, kh, kw, sy, oh_block,
                                                ow, ocb)
        pool = None
        row_step = ohb * sy
        out_rows, out_cols = ohb, ow
        carry = False
    wp = xp.shape[2]
    if carry:
        k_rows = pkh - psy        # conv rows carried between band steps
        r_rows = phb * psy        # fresh conv rows per band step
        band = (r_rows - 1) * sy + kh
        row_step = r_rows * sy
        prepad = row_step - k_rows * sy
        # the zero prepad makes step 0 a pure seed band: its output block
        # pools prepad zeros (sliced off below) while its last K fresh
        # conv rows are conv rows [0, K) — step 1's carry.  The bottom
        # rows _plan_pool_tiles already padded are exactly what the
        # shifted bands need (the prepad algebra cancels to zero extra).
        xp = jnp.pad(xp, ((0, 0), (prepad, 0), (0, 0), (0, 0)))
        oc_tiles = ocp // ocb
        kern = functools.partial(_advanced_simd_carry_kernel, kh=kh, kw=kw,
                                 sy=sy, sx=sx, relu=relu, pool=pool,
                                 k_rows=k_rows)
        out = pl.pallas_call(
            kern,
            grid=(n, oc_tiles, n_tiles + 1),
            in_specs=[
                # element-offset indexing; the carried rows replace the
                # pool-window share of the inter-band halo
                pl.BlockSpec((1, band, wp, c),
                             lambda i, u, j: (i, j * row_step, 0, 0),
                             indexing_mode=pl.Unblocked()),
                pl.BlockSpec((kh * kw * c, ocb), lambda i, u, j: (0, u)),
                pl.BlockSpec((ocb,), lambda i, u, j: (u,)),
            ],
            out_specs=pl.BlockSpec((None, out_rows, out_cols, ocb),
                                   lambda i, u, j: (i, j, 0, u)),
            out_shape=jax.ShapeDtypeStruct(
                (n, (n_tiles + 1) * out_rows, out_cols, ocp), x_nhwc.dtype),
            scratch_shapes=[pltpu.VMEM((k_rows, ow, ocb), jnp.float32)],
            compiler_params=pltpu.TPUCompilerParams(
                # the band axis is sequential: each step consumes the
                # carry its predecessor left in scratch
                dimension_semantics=("parallel", "parallel", "arbitrary")
            ),
            interpret=interpret,
        )(xp, wmat, b)
        return out[:, out_rows:out_rows + ph, :, :oc]
    if oc_halo:
        oc_tiles = ocp // ocb
        kern = functools.partial(_advanced_simd_halo_kernel, kh=kh, kw=kw,
                                 sy=sy, sx=sx, relu=relu, pool=pool, lrn=lrn)
        out = pl.pallas_call(
            kern,
            grid=(n, n_tiles, oc_tiles),
            in_specs=[
                # element-offset indexing on rows AND weight columns:
                # adjacent weight tiles overlap by the n-1 halo columns
                pl.BlockSpec((1, band, wp, c),
                             lambda i, t, u: (i, t * row_step, 0, 0),
                             indexing_mode=pl.Unblocked()),
                pl.BlockSpec((kh * kw * c, ocb + oc_halo),
                             lambda i, t, u: (0, u * ocb),
                             indexing_mode=pl.Unblocked()),
                pl.BlockSpec((ocb + oc_halo,), lambda i, t, u: (u * ocb,),
                             indexing_mode=pl.Unblocked()),
            ],
            out_specs=pl.BlockSpec((None, out_rows, out_cols, ocb),
                                   lambda i, t, u: (i, t, 0, u)),
            out_shape=jax.ShapeDtypeStruct(
                (n, n_tiles * out_rows, out_cols, ocp), x_nhwc.dtype),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel")
            ),
            interpret=interpret,
        )(xp, wmat, b)
        return out[:, :ph, :, :oc]
    kern = functools.partial(_advanced_simd_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu, pool=pool, lrn=lrn)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles, ocp // ocb),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wp, c),
                         lambda i, t, o: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((kh * kw * c, ocb), lambda i, t, o: (0, o)),
            pl.BlockSpec((ocb,), lambda i, t, o: (o,)),
        ],
        out_specs=pl.BlockSpec((None, out_rows, out_cols, ocb),
                               lambda i, t, o: (i, t, 0, o)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * out_rows, out_cols, ocp),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, wmat, b)
    if pool_kernel is not None:
        return out[:, :ph, :, :oc]
    return out[:, :oh, :, :oc]


# ---------------------------------------------------------------------------
# fused conv→conv chains — a run of convolutions per grid cell, the
# intermediate activations (and their halos) VMEM-resident
# ---------------------------------------------------------------------------
#
# A chain is described by per-stage tuples ``(kh, kw, sy, sx, py, px)`` plus
# the per-stage output-channel counts ``ocs``.  Stage 0's padding is applied
# host-side (like the single-conv kernels); every later stage's horizontal
# padding is materialized in VMEM (``jnp.pad`` on the band's width axis) and
# its *vertical* padding is realized by the zero-masked halo rows of the
# previous stage's band.


def chain_stage_dims(h, w, c, chain, ocs):
    """Per-stage ``(oh, ow, cin, oc)`` propagated through the chain from
    the (unpadded) chain input ``(h, w, c)``."""
    dims = []
    for (kh, kw, sy, sx, py, px), oc in zip(chain, ocs):
        oh = (h + 2 * py - kh) // sy + 1
        ow = (w + 2 * px - kw) // sx + 1
        dims.append((oh, ow, c, oc))
        h, w, c = oh, ow, oc
    return dims


def chain_band_geometry(blk, chain, pool):
    """Backward halo composition for one chain cell producing ``blk``
    final rows (pooled rows when ``pool`` is set).

    Returns ``(m, offs, band, in_step, in_base)``: ``m[i]`` is the rows of
    stage i's output band the cell materializes (``m[i-1] = (m[i]-1)*sy_i
    + kh_i`` — stage i's halo-widened input demand), ``offs[i] = (A, B)``
    the affine map from band index ``t`` to stage i's global starting row
    (``A*t + B``; B goes negative where intermediate vertical padding is
    consumed), ``band`` the input rows per cell, and ``(in_step,
    in_base)`` the affine input-row offset in stage-0 *padded-input*
    coordinates (``in_base`` ≤ 0: the caller pre-pads that many extra
    zero rows on top).
    """
    s = len(chain)
    m = [0] * s
    offs = [(0, 0)] * s
    if pool is not None:
        pkh, _, psy, _ = pool
        m[-1] = (blk - 1) * psy + pkh
        offs[-1] = (blk * psy, 0)
    else:
        m[-1] = blk
        offs[-1] = (blk, 0)
    for i in range(s - 1, 0, -1):
        kh, _, sy, _, py, _ = chain[i]
        a, b = offs[i]
        m[i - 1] = (m[i] - 1) * sy + kh
        offs[i - 1] = (a * sy, b * sy - py)
    kh0, _, sy0, _, _, _ = chain[0]
    band = (m[0] - 1) * sy0 + kh0
    a0, b0 = offs[0]
    return m, offs, band, a0 * sy0, b0 * sy0


def chain_tile_intervals(blk, n_tiles, target, chain, pool):
    """Per-grid-cell ``(start, rows)`` intervals of a chain dispatch —
    final-stage output bands (clipped to the ``target`` valid rows) and
    the composed halo-inclusive input bands in stage-0 *pre-padded*
    coordinates (a negative start means the kernel pre-pads that many
    extra genuine-zero top rows).  Shares ``chain_band_geometry`` with
    the kernel and ``band_intervals`` with the single-conv planners, so
    the verifier proves coverage over exactly what the cell executes."""
    _, _, band, in_step, in_base = chain_band_geometry(blk, chain, pool)
    return band_intervals(n_tiles, blk, target, in_step, band, base=in_base)


def chain_cell_bytes(blk, h, w, c, chain, ocs, pool,
                     im2col: bool = True, itemsize: int = 4,
                     oc_block_final=None) -> int:
    """Modelled VMEM live set of ONE chain grid cell producing ``blk``
    final rows (pooled rows when ``pool`` is set).

    Chains hold every *intermediate* stage's full-width weights resident
    (stage N+1 consumes every channel of stage N, so there is no oc tile
    to shrink them); the per-stage temporaries — incoming band, patch
    staging, outgoing band — are sequential, only one stage's set is live
    at a time, so their *maximum* is charged rather than their sum.  The
    streamed input band and final output band are charged once more on
    top, standing in for their pipeline double buffers.  Nothing consumes
    the FINAL stage's channels inside the cell, so ``oc_block_final``
    restores oc-grid blocking there: the final weights, outgoing band,
    and output stream shrink to one oc tile (the dominant resident-
    weights term for deep chains).  The same model backs the kernel-side
    ``auto_chain_block`` walk and the planner's decline-to-fuse check, so
    the planner never approves a chain the kernel cannot stage.
    """
    dims = chain_stage_dims(h, w, c, chain, ocs)
    m, _, band, _, _ = chain_band_geometry(blk, chain, pool)
    last = len(chain) - 1
    weights = 0
    stage_peak = 0
    in_rows, in_w = band, w + 2 * chain[0][5]
    for i, ((kh, kw, sy, sx, py, px), (oh, ow, ci, oc)) in enumerate(
            zip(chain, dims)):
        if i == last and oc_block_final is not None:
            oc = min(oc_block_final, oc)
        weights += kh * kw * ci * oc
        patch_c = kh * kw * ci if im2col else ci
        stage_peak = max(stage_peak,
                         in_rows * in_w * ci     # incoming band
                         + m[i] * ow * patch_c   # patch staging
                         + m[i] * ow * oc)       # outgoing band
        if i + 1 < len(chain):
            in_rows, in_w = m[i], ow + 2 * chain[i + 1][5]
    oh_f, ow_f, _, oc_f = dims[-1]
    if oc_block_final is not None:
        oc_f = min(oc_block_final, oc_f)
    if pool is not None:
        pkh, pkw, psy, psx = pool
        out_stream = blk * ((ow_f - pkw) // psx + 1) * oc_f
    else:
        out_stream = blk * ow_f * oc_f
    in_stream = band * (w + 2 * chain[0][5]) * c
    return (weights + stage_peak + in_stream + out_stream) * itemsize


def auto_chain_block(target, h, w, c, chain, ocs, pool,
                     budget: int = None, im2col: bool = True,
                     oc_block_final=None) -> int:
    """Largest final-row band whose chain-cell live set fits ``budget``
    (default ``CHAIN_VMEM_BUDGET_BYTES``); floors at one final row —
    which may exceed the budget: the planner's job is to keep such chains
    un-fused (or shortened) in the first place."""
    budget = CHAIN_VMEM_BUDGET_BYTES if budget is None else budget
    candidates = [target] + [b for b in (512, 256, 128, 64, 32, 16, 8, 4,
                                         2, 1) if b < target]
    for blk in candidates:
        if chain_cell_bytes(blk, h, w, c, chain, ocs, pool, im2col=im2col,
                            oc_block_final=oc_block_final) <= budget:
            return blk
    return 1


def resolve_chain_block(h, w, c, chain, ocs, pool, oh_block,
                        im2col: bool = True, budget: int = None,
                        oc_block_final=None) -> tuple:
    """The equalized final-row band a chain cell will execute with, as
    ``(blk, n_tiles)`` — the ``auto_chain_block`` walk when ``oh_block``
    is None, else the explicit final-stage conv band (snapped down to
    whole pool windows when a pool tail is fused).  Public so the
    engine's geometry report shares the exact resolution the kernel
    runs."""
    dims = chain_stage_dims(h, w, c, chain, ocs)
    oh_f, ow_f = dims[-1][0], dims[-1][1]
    if pool is not None:
        pkh, pkw, psy, psx = pool
        target = (oh_f - pkh) // psy + 1
        if target < 1 or (ow_f - pkw) // psx + 1 < 1:
            raise ValueError(f"pool window ({pkh},{pkw}) larger than final "
                             f"conv output ({oh_f},{ow_f})")
    else:
        target = oh_f
    if oh_block is None:
        blk = auto_chain_block(target, h, w, c, chain, ocs, pool,
                               budget=budget, im2col=im2col,
                               oc_block_final=oc_block_final)
    elif pool is not None:
        ohb = max(1, min(oh_block, oh_f))
        blk = max(1, (ohb - pkh) // psy + 1) if ohb >= pkh else 1
    else:
        blk = oh_block
    return _equalize_bands(blk, target)


def _band_conv(x, w_ref, kh, kw, sy, sx, m, ow, im2col):
    """One chain stage's conv over an in-VMEM fp32 band: ``x`` is
    ``[rows, width, C]``, returns the pre-bias ``[m*ow, OC]`` product —
    the full im2col matmul (advanced) or the per-kernel-position channel
    dots (basic)."""
    c = x.shape[2]
    if im2col:
        cols = []
        for i in range(kh):
            for j in range(kw):
                cols.append(jax.lax.slice(
                    x, (i, j, 0),
                    (i + (m - 1) * sy + 1, j + (ow - 1) * sx + 1, c),
                    (sy, sx, 1),
                ).reshape(m * ow, -1))
        patches = jnp.concatenate(cols, axis=-1)  # [rows, KH*KW*C]
        return jnp.dot(patches, w_ref[...].astype(ACC_DTYPE),
                       preferred_element_type=jnp.float32)
    acc = jnp.zeros((m * ow, w_ref.shape[-1]), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (m - 1) * sy + 1, j + (ow - 1) * sx + 1, c),
                (sy, sx, 1),
            ).reshape(m * ow, -1)
            # vectorized dot over channels per kernel position (§4.3)
            acc = acc + jnp.dot(patch, w_ref[i, j].astype(ACC_DTYPE),
                                preferred_element_type=jnp.float32)
    return acc


def _chain_simd_kernel(x_ref, *refs, stages, pool, lrn, im2col):
    # x_ref: [1, BAND, WP0, C] (halo-widened chain-input band);
    # refs: (w0, b0, w1, b1, ..., o_ref); stages: per-stage static tuples
    # (kh, kw, sy, sx, px, m, ow, relu, oh_valid, A, B) where px is the
    # stage's own horizontal padding (0 for stage 0 — host-applied),
    # m/ow the stage's band geometry, oh_valid its true output height and
    # (A, B) the affine band-index→global-row map for the padding mask.
    o_ref = refs[-1]
    wb = refs[:-1]
    t = pl.program_id(1)
    band = x_ref[0].astype(ACC_DTYPE)
    last = len(stages) - 1
    for si, (kh, kw, sy, sx, px, m, ow, relu, oh_valid, a, b0) in enumerate(
            stages):
        if px:
            # this stage's horizontal padding, materialized in VMEM
            band = jnp.pad(band, ((0, 0), (px, px), (0, 0)))
        acc = _band_conv(band, wb[2 * si], kh, kw, sy, sx, m, ow, im2col)
        acc = acc + wb[2 * si + 1][...].astype(ACC_DTYPE)
        if si == last:
            if pool is not None:  # pool(/LRN) the final band in VMEM
                _pool_epilogue(acc, o_ref, pool, relu, lrn)
            else:
                if relu:
                    acc = jnp.maximum(acc, 0.0)
                o_ref[...] = acc.reshape(m, ow, -1).astype(o_ref.dtype)
            return
        if relu:
            acc = jnp.maximum(acc, 0.0)
        out = acc.reshape(m, ow, -1)
        # rows outside this stage's true output ARE the next stage's
        # vertical padding (activation zeros — NOT conv-of-pad-input,
        # which relu(bias) would corrupt): zero-mask them by global row
        rows = (a * t + b0 + jax.lax.broadcasted_iota(jnp.int32, (m, 1, 1),
                                                      0))
        band = jnp.where((rows >= 0) & (rows < oh_valid), out, 0.0)


def conv2d_chain_simd(x_nhwc, ws, bs, strides, paddings, relus,
                      im2col: bool = True, oh_block=None,
                      interpret: bool = False, pool_kernel=None,
                      pool_stride=None, pool_kind: str = "max",
                      pool_relu: bool = False, lrn=None,
                      oc_block_final=None):
    """A chain of consecutive convolutions as one fused dispatch.

    ``ws``: per-stage HWIO weights (channel-contiguous: stage i's input
    channels equal stage i-1's output channels); ``bs``/``strides``/
    ``paddings``/``relus`` parallel per-stage lists.  Each grid cell
    computes an output-row band of the FINAL stage — pooled rows when
    ``pool_kernel`` is set — staging every intermediate band (halo
    included) in VMEM; only the final band is written to HBM.
    Intermediate stages run at full output-channel width (stage N+1
    consumes every channel of stage N); ``oc_block_final`` restores
    oc-grid blocking on the FINAL stage, whose channels nothing inside
    the cell consumes — earlier stages recompute per oc tile, trading
    MACs for the dominant resident-weights term.  ``im2col`` selects the
    advanced (patch-matrix matmul) or basic (per-position channel dots)
    stage compute.
    """
    n, h, wd, c = x_nhwc.shape
    s = len(ws)
    if not (len(bs) == len(strides) == len(paddings) == len(relus) == s):
        raise ValueError("chain stage lists must have equal length")
    if lrn is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    if oc_block_final is not None and lrn is not None:
        raise ValueError("oc-blocked final stage requires no LRN epilogue "
                         "(the LRN window reads every output channel)")
    chain = tuple((w.shape[0], w.shape[1], st[0], st[1], pd[0], pd[1])
                  for w, st, pd in zip(ws, strides, paddings))
    ocs = tuple(w.shape[3] for w in ws)
    dims = chain_stage_dims(h, wd, c, chain, ocs)
    for oh_i, ow_i, _, _ in dims:
        if oh_i < 1 or ow_i < 1:
            raise ValueError("chain stage output collapsed to zero size")
    oh_f, ow_f, _, oc_f = dims[-1]
    if oc_block_final is not None and oc_block_final >= oc_f:
        oc_block_final = None  # full width already: classic dispatch
    if pool_kernel is not None:
        pkh, pkw = pool_kernel
        psy, psx = pool_stride if pool_stride is not None else pool_kernel
        pool_g = (pkh, pkw, psy, psx)
        target = (oh_f - pkh) // psy + 1
        out_cols = (ow_f - pkw) // psx + 1
        if target < 1 or out_cols < 1:
            raise ValueError(f"pool window {pool_kernel} larger than final "
                             f"conv output ({oh_f},{ow_f})")
        pool = (pkh, pkw, psy, psx, pool_kind, pool_relu, ow_f)
    else:
        pool_g, pool = None, None
        target, out_cols = oh_f, ow_f
    blk, n_tiles = resolve_chain_block(h, wd, c, chain, ocs, pool_g,
                                       oh_block, im2col=im2col,
                                       oc_block_final=oc_block_final)
    m, offs, band, in_step, in_base = chain_band_geometry(blk, chain, pool_g)
    # stage-0 padding host-side (+ the extra top rows the intermediate
    # vertical padding pulls the first band up into, all genuine zeros)
    py0, px0 = paddings[0]
    top = py0 + max(0, -in_base)
    base = in_base + max(0, -in_base)
    hp_need = (n_tiles - 1) * in_step + base + band
    bot = max(py0, hp_need - (h + top))
    xp = jnp.pad(x_nhwc, ((0, 0), (top, bot), (px0, px0), (0, 0)))
    wp0 = xp.shape[2]
    stages = tuple(
        (kh, kw, sy, sx, 0 if i == 0 else px, m[i], dims[i][1], relus[i],
         dims[i][0], offs[i][0], offs[i][1])
        for i, (kh, kw, sy, sx, py, px) in enumerate(chain))
    kern = functools.partial(_chain_simd_kernel, stages=stages, pool=pool,
                             lrn=lrn, im2col=im2col)
    if oc_block_final is not None:
        # oc-blocked final stage: the kernel body is unchanged (it derives
        # every stage width from its weight block), only the grid gains an
        # oc axis and the final stage's weight/bias/output specs block on
        # it — intermediate stages recompute their full-width bands per
        # oc tile
        ocb_f = oc_block_final
        pad_f = (-oc_f) % ocb_f
        wlast, blast = ws[-1], bs[-1]
        if pad_f:
            wlast = jnp.pad(wlast, ((0, 0), (0, 0), (0, 0), (0, pad_f)))
            blast = jnp.pad(blast, (0, pad_f))
        ocp_f = oc_f + pad_f
        in_specs = [
            pl.BlockSpec((1, band, wp0, c),
                         lambda i, t, o: (i, t * in_step + base, 0, 0),
                         indexing_mode=pl.Unblocked()),
        ]
        operands = [xp]
        last_w = s - 1
        for si, (w, b) in enumerate(zip(ws, bs)):
            if si == last_w:
                w, b = wlast, blast
            kh, kw, ci, oc = w.shape
            if im2col:
                operands.append(w.reshape(kh * kw * ci, oc))
                if si == last_w:
                    in_specs.append(pl.BlockSpec((kh * kw * ci, ocb_f),
                                                 lambda i, t, o: (0, o)))
                else:
                    in_specs.append(pl.BlockSpec((kh * kw * ci, oc),
                                                 lambda i, t, o: (0, 0)))
            else:
                operands.append(w)
                if si == last_w:
                    in_specs.append(pl.BlockSpec(
                        (kh, kw, ci, ocb_f), lambda i, t, o: (0, 0, 0, o)))
                else:
                    in_specs.append(pl.BlockSpec(
                        (kh, kw, ci, oc), lambda i, t, o: (0, 0, 0, 0)))
            operands.append(b)
            if si == last_w:
                in_specs.append(pl.BlockSpec((ocb_f,),
                                             lambda i, t, o: (o,)))
            else:
                in_specs.append(pl.BlockSpec((oc,), lambda i, t, o: (0,)))
        out = pl.pallas_call(
            kern,
            grid=(n, n_tiles, ocp_f // ocb_f),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((None, blk, out_cols, ocb_f),
                                   lambda i, t, o: (i, t, 0, o)),
            out_shape=jax.ShapeDtypeStruct(
                (n, n_tiles * blk, out_cols, ocp_f), x_nhwc.dtype),
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel")
            ),
            interpret=interpret,
        )(*operands)
        return out[:, :target, :, :oc_f]
    in_specs = [
        # element-offset indexing: chain bands overlap by the composed halo
        pl.BlockSpec((1, band, wp0, c),
                     lambda i, t: (i, t * in_step + base, 0, 0),
                     indexing_mode=pl.Unblocked()),
    ]
    operands = [xp]
    for w, b in zip(ws, bs):
        kh, kw, ci, oc = w.shape
        if im2col:
            operands.append(w.reshape(kh * kw * ci, oc))
            in_specs.append(pl.BlockSpec((kh * kw * ci, oc),
                                         lambda i, t: (0, 0)))
        else:
            operands.append(w)
            in_specs.append(pl.BlockSpec((kh, kw, ci, oc),
                                         lambda i, t: (0, 0, 0, 0)))
        operands.append(b)
        in_specs.append(pl.BlockSpec((oc,), lambda i, t: (0,)))
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, blk, out_cols, oc_f),
                               lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * blk, out_cols, oc_f),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :target]
