"""The CNNdroid conv ladder as Pallas TPU kernels.

Three kernels, one per paper method (§4.2–§4.4), sharing the grid-over-
frames structure (the paper launches one RenderScript kernel per frame
batch; we launch one grid cell per frame × tile):

* ``basic_parallel``  (§4.2) — NCHW, whole frame per grid cell, reduction
  loops over (c, kh, kw) with the *spatial* map vectorized — channels are
  NOT on the lane axis, mirroring the paper's un-swapped layout.  The MXU
  stays idle; only the VPU spatial lanes are used.
* ``basic_simd``      (§4.3) — NHWC after dimension swapping: channels on
  the 128-lane minor axis; per kernel position a [oh·ow, C] × [C, OC] dot
  — the vectorized channel dot product.
* ``advanced_simd``   (§4.4) — NHWC + output-channel blocking: grid cell
  (frame, oh-tile, oc-tile); an im2col patch matrix [rows, KH·KW·C] built
  once in VMEM is reused for the whole 128-wide oc tile (the paper's
  4/8-outputs-per-thread reuse at MXU width), with bias+ReLU fused in the
  epilogue.

VMEM budget: frames of the paper's CNNs (≤227×227×3, ≤27×27×256) fit in
VMEM whole; block shapes keep the minor dimension lane-aligned when the
channel count allows (ops.py pads channels — the paper's divisible-by-4
observation at lane width 128/8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _out_size(size, k, stride, pad):
    return (size + 2 * pad - k) // stride + 1


# ---------------------------------------------------------------------------
# §4.2 basic parallel — NCHW, no channel vectorization
# ---------------------------------------------------------------------------


def _basic_parallel_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                           relu):
    # x_ref: [C, H, W]; w_ref: [OC, C, KH, KW]; o_ref: [OC, OH, OW]
    oc, ohh, oww = o_ref.shape
    c = x_ref.shape[0]
    acc = jnp.zeros((oc, ohh, oww), jnp.float32)
    for ci in range(c):  # channels OUTER (un-swapped layout: no lane reuse)
        plane = x_ref[ci]  # [H, W]
        for i in range(kh):
            for j in range(kw):
                patch = jax.lax.slice(
                    plane, (i, j),
                    (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1),
                    (sy, sx),
                )  # [OH, OW] — spatial lanes only
                acc = acc + (patch.astype(jnp.float32)[None] *
                             w_ref[:, ci, i, j].astype(jnp.float32)
                             [:, None, None])
    acc = acc + b_ref[...].astype(jnp.float32)[:, None, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def conv2d_basic_parallel(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
                          interpret: bool = False):
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[2], xp.shape[3]
    kern = functools.partial(_basic_parallel_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, c, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((oc, c, kh, kw), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, oc, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oc, oh, ow), x.dtype),
        interpret=interpret,
    )(xp, w, b)


# ---------------------------------------------------------------------------
# §4.3 basic SIMD — NHWC, vectorized channel dot per kernel position
# ---------------------------------------------------------------------------


def _basic_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx, relu):
    # x_ref: [HP, WP, C]; w_ref: [KH, KW, C, OC]; o_ref: [OH, OW, OC]
    ohh, oww, oc = o_ref.shape
    acc = jnp.zeros((ohh * oww, oc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x_ref[...], (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x_ref.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1)  # [rows, C] — C on the lane axis
            acc = acc + jnp.dot(
                patch.astype(jnp.float32),
                w_ref[i, j].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )  # vectorized dot over channels (the paper's 4-wide, here 128)
    acc = acc + b_ref[...].astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, oc).astype(o_ref.dtype)


def conv2d_basic_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                      relu=False, interpret: bool = False):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[1], xp.shape[2]
    kern = functools.partial(_basic_simd_kernel, kh=kh, kw=kw, sy=sy, sx=sx,
                             relu=relu)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, hp, wp, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, oc), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((oc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, oh, ow, oc), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, oc), x_nhwc.dtype),
        interpret=interpret,
    )(xp, w_hwio, b)


# ---------------------------------------------------------------------------
# §4.4 advanced SIMD — im2col in VMEM + output-channel blocking + epilogue
# ---------------------------------------------------------------------------


def _advanced_simd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sy, sx,
                          relu):
    # x_ref: [HP, WP, C] (frame); w_ref: [KH*KW*C, OC_BLK]; o_ref: [OH, OW, OC_BLK]
    ohh, oww, ocb = o_ref.shape
    cols = []
    for i in range(kh):  # im2col built once per frame tile, reused for the
        for j in range(kw):  # whole 128-wide output-channel block (§4.4)
            cols.append(jax.lax.slice(
                x_ref[...], (i, j, 0),
                (i + (ohh - 1) * sy + 1, j + (oww - 1) * sx + 1,
                 x_ref.shape[2]),
                (sy, sx, 1),
            ).reshape(ohh * oww, -1))
    patches = jnp.concatenate(cols, axis=-1)  # [rows, KH*KW*C]
    acc = jnp.dot(patches.astype(jnp.float32), w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)  # one MXU matmul
    acc = acc + b_ref[...].astype(jnp.float32)
    if relu:  # fused epilogue in VMEM — zero-cost ReLU (Fig. 5)
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.reshape(ohh, oww, ocb).astype(o_ref.dtype)


def conv2d_advanced_simd(x_nhwc, w_hwio, b, stride=(1, 1), padding=(0, 0),
                         relu=False, oc_block: int = 128,
                         interpret: bool = False):
    n, h, wd, c = x_nhwc.shape
    kh, kw, _, oc = w_hwio.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x_nhwc, ((0, 0), (py, py), (px, px), (0, 0)))
    oh, ow = _out_size(h, kh, sy, py), _out_size(wd, kw, sx, px)
    hp, wp = xp.shape[1], xp.shape[2]
    ocb = min(oc_block, oc)
    pad_oc = (-oc) % ocb
    wmat = w_hwio.reshape(kh * kw * c, oc)
    if pad_oc:
        wmat = jnp.pad(wmat, ((0, 0), (0, pad_oc)))
        b = jnp.pad(b, (0, pad_oc))
    ocp = oc + pad_oc
    kern = functools.partial(_advanced_simd_kernel, kh=kh, kw=kw, sy=sy,
                             sx=sx, relu=relu)
    out = pl.pallas_call(
        kern,
        grid=(n, ocp // ocb),
        in_specs=[
            pl.BlockSpec((None, hp, wp, c), lambda i, o: (i, 0, 0, 0)),
            pl.BlockSpec((kh * kw * c, ocb), lambda i, o: (0, o)),
            pl.BlockSpec((ocb,), lambda i, o: (o,)),
        ],
        out_specs=pl.BlockSpec((None, oh, ow, ocb), lambda i, o: (i, 0, 0, o)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, ocp), x_nhwc.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(xp, wmat, b)
    return out[..., :oc]
