"""jit'd public wrapper for the conv2d kernel ladder.

Accepts NCHW/OIHW (the deploy format), performs the dimension swap +
channel padding host-side (the Fig. 5 "CPU idle time" work), dispatches to
the method's Pallas kernel, and swaps back.

``oh_block`` (SIMD methods only) sets the spatial tile: the output height
is processed in bands of ``oh_block`` rows so each grid cell stages only
the input-row band it needs (halo included) instead of the whole padded
frame.  ``None`` lets ``kernels.auto_oh_block`` pick the largest band that
fits the VMEM budget — required for frames (e.g. 512×512) whose padded
activations exceed VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.layout import (
    nchw_to_nhwc,
    nhwc_to_nchw,
    oihw_to_hwio,
    pad_axis,
)
from repro.kernels.conv2d import kernels as K
from repro.kernels.conv2d.ref import conv2d_ref

SUBLANES = 8  # channel padding multiple (paper's "divisible by 4", on TPU 8/128)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("stride", "padding", "relu", "method",
                                   "oh_block", "interpret", "pool_kernel",
                                   "pool_stride", "pool_kind", "pool_relu",
                                   "lrn_n", "lrn_alpha", "lrn_beta", "lrn_k",
                                   "pool_carry", "lrn_oc_block"))
def conv2d(x, w, b, stride=(1, 1), padding=(0, 0), relu=False,
           method: str = "advanced_simd_128", oh_block: int = None,
           interpret: bool = None, pool_kernel=None, pool_stride=None,
           pool_kind: str = "max", pool_relu: bool = False,
           lrn_n: int = None, lrn_alpha: float = 1e-4,
           lrn_beta: float = 0.75, lrn_k: float = 1.0,
           pool_carry: bool = None, lrn_oc_block: bool = None):
    """x: [N, C, H, W]; w: [OC, C, KH, KW]; b: [OC].

    ``pool_kernel``/``pool_stride`` (SIMD methods only) fuse a VALID
    max/avg pooling epilogue into the conv kernel — the super-layer path:
    the conv activation never leaves VMEM and only the pooled band is
    written.  ``relu`` applies between conv and pool, ``pool_relu`` after
    the pool.  ``lrn_n`` (requires ``pool_kernel``) extends the epilogue
    with channel-axis LRN over the in-VMEM pooled band
    (``engine._lrn`` semantics, asymmetric padding for even ``lrn_n``) so
    only the *normalized* band is written — AlexNet's conv→relu→pool→norm
    in one dispatch.

    ``pool_carry`` / ``lrn_oc_block`` (advanced SIMD only) select the
    second-generation fused cells: the sliding-window pool accumulator
    (carry the pool-halo conv rows between bands in VMEM scratch) and the
    two-pass channel-halo LRN cell (oc blocking with window-widened
    weight tiles).  ``None`` = the kernel resolvers decide.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    if method == "basic_parallel":
        if pool_kernel is not None or lrn_n is not None:
            raise ValueError("fused pooling epilogue requires a SIMD method")
        return K.conv2d_basic_parallel(x, w, b, stride, padding, relu,
                                       interpret=interp)
    if lrn_n is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    lrn = (lrn_n, lrn_alpha, lrn_beta, lrn_k) if lrn_n is not None else None
    # SIMD methods: dimension swapping + channel padding (§4.3)
    xh = nchw_to_nhwc(x)
    wh = oihw_to_hwio(w)
    xh, _ = pad_axis(xh, 3, SUBLANES)
    wh, _ = pad_axis(wh, 2, SUBLANES)
    if method == "basic_simd":
        out = K.conv2d_basic_simd(xh, wh, b, stride, padding, relu,
                                  oh_block=oh_block, interpret=interp,
                                  pool_kernel=pool_kernel,
                                  pool_stride=pool_stride,
                                  pool_kind=pool_kind, pool_relu=pool_relu,
                                  lrn=lrn)
    elif method.startswith("advanced_simd"):
        blk = int(method.rsplit("_", 1)[1]) if method[-1].isdigit() else 128
        out = K.conv2d_advanced_simd(xh, wh, b, stride, padding, relu,
                                     oc_block=blk, oh_block=oh_block,
                                     interpret=interp,
                                     pool_kernel=pool_kernel,
                                     pool_stride=pool_stride,
                                     pool_kind=pool_kind,
                                     pool_relu=pool_relu, lrn=lrn,
                                     pool_carry=pool_carry,
                                     lrn_oc_block=lrn_oc_block)
    else:
        raise ValueError(method)
    return nhwc_to_nchw(out)


@partial(jax.jit, static_argnames=("strides", "paddings", "relus", "method",
                                   "oh_block", "interpret", "pool_kernel",
                                   "pool_stride", "pool_kind", "pool_relu",
                                   "lrn_n", "lrn_alpha", "lrn_beta", "lrn_k",
                                   "oc_block_final"))
def conv2d_chain(x, ws, bs, strides, paddings, relus,
                 method: str = "advanced_simd_128", oh_block: int = None,
                 interpret: bool = None, pool_kernel=None, pool_stride=None,
                 pool_kind: str = "max", pool_relu: bool = False,
                 lrn_n: int = None, lrn_alpha: float = 1e-4,
                 lrn_beta: float = 0.75, lrn_k: float = 1.0,
                 oc_block_final: int = None):
    """A chain of consecutive convolutions as ONE fused dispatch.

    ``x``: [N, C, H, W]; ``ws``/``bs``: per-stage OIHW weights and biases
    (stage i's input channels = stage i-1's output channels); ``strides``/
    ``paddings``/``relus``: parallel static per-stage tuples.  SIMD
    methods only — the chain cell computes an output-row band of the
    final stage with every intermediate activation (halo included)
    VMEM-resident; ``pool_kernel``(+``lrn_n``) fuse the usual pool/LRN
    tail onto the last stage.  The dimension swap happens once for the
    whole chain, and inter-stage channel padding composes: a stage's
    zero-padded output channels are exact zeros (zero weight columns,
    zero bias), so the next stage's zero-padded input rows consume them
    harmlessly.
    """
    if not method.startswith(("basic_simd", "advanced_simd")):
        raise ValueError("fused conv chain requires a SIMD method")
    if lrn_n is not None and pool_kernel is None:
        raise ValueError("fused LRN epilogue requires a fused pool epilogue")
    lrn = (lrn_n, lrn_alpha, lrn_beta, lrn_k) if lrn_n is not None else None
    interp = (not _on_tpu()) if interpret is None else interpret
    im2col = method.startswith("advanced_simd")
    xh = nchw_to_nhwc(x)
    xh, _ = pad_axis(xh, 3, SUBLANES)
    cp = xh.shape[3]
    whs, bps = [], []
    oc_f = ws[-1].shape[0]
    for w, b in zip(ws, bs):
        wh = oihw_to_hwio(w)  # [kh, kw, ci, oc]
        pad_in = cp - wh.shape[2]
        ocp = -(-wh.shape[3] // SUBLANES) * SUBLANES
        wh = jnp.pad(wh, ((0, 0), (0, 0), (0, pad_in),
                          (0, ocp - wh.shape[3])))
        whs.append(wh)
        bps.append(jnp.pad(b, (0, ocp - b.shape[0])))
        cp = ocp
    out = K.conv2d_chain_simd(xh, whs, bps, strides, paddings, relus,
                              im2col=im2col, oh_block=oh_block,
                              interpret=interp, pool_kernel=pool_kernel,
                              pool_stride=pool_stride, pool_kind=pool_kind,
                              pool_relu=pool_relu, lrn=lrn,
                              oc_block_final=oc_block_final)
    return nhwc_to_nchw(out[..., :oc_f])


conv2d_reference = conv2d_ref
