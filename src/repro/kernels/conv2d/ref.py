"""Pure-jnp oracle for the conv2d kernel ladder.

Direct NCHW convolution via explicit kernel-position accumulation (no
lax.conv), fp32 accumulation — the §4.1 sequential semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b, stride=(1, 1), padding=(0, 0), relu=False):
    """x: [N, C, H, W]; w: [OC, C, KH, KW]; b: [OC] -> [N, OC, OH, OW]."""
    n, c, h, wd = x.shape
    oc, _, kh, kw = w.shape
    sy, sx = stride
    py, px = padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (py, py), (px, px)))
    oh = (h + 2 * py - kh) // sy + 1
    ow = (wd + 2 * px - kw) // sx + 1
    out = jnp.zeros((n, oc, oh, ow), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp, (0, 0, i, j),
                (n, c, i + (oh - 1) * sy + 1, j + (ow - 1) * sx + 1),
                (1, 1, sy, sx),
            )
            out = out + jnp.einsum(
                "nchw,oc->nohw", patch.astype(jnp.float32),
                w[:, :, i, j].astype(jnp.float32),
            )
    out = out + b[None, :, None, None].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)
