"""XLA reference pooling (NCHW reduce_window) — the pre-fusion engine path
and the correctness oracle for the Pallas pool kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pool2d_ref(x, kernel=(2, 2), stride=(2, 2), kind: str = "max",
               relu: bool = False):
    """x: [N, C, H, W]; VALID window semantics (the engine's pools)."""
    kh, kw = kernel
    sy, sx = stride
    if kind == "max":
        out = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 1, kh, kw), (1, 1, sy, sx), "VALID"
        )
    elif kind == "avg":
        out = jax.lax.reduce_window(
            x.astype(jnp.float32), 0.0, jax.lax.add,
            (1, 1, kh, kw), (1, 1, sy, sx), "VALID"
        ) / float(kh * kw)
    else:
        raise ValueError(kind)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)
