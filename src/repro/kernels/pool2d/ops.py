"""jit'd public wrapper for the Pallas pooling kernels.

Accepts NCHW (the deploy format), swaps to NHWC so channels sit on the
128-lane minor axis (same dimension swapping as the SIMD conv methods),
pads channels to the sublane multiple, dispatches to the oh-band-tiled
Pallas kernel, and swaps back.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.core.layout import nchw_to_nhwc, nhwc_to_nchw, pad_axis
from repro.kernels.pool2d import kernels as K

SUBLANES = 8  # channel padding multiple (mirrors conv2d.ops)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("kernel", "stride", "kind", "relu",
                                   "oh_block", "interpret"))
def pool2d(x, kernel=(2, 2), stride=(2, 2), kind: str = "max",
           relu: bool = False, oh_block: int = None,
           interpret: bool = None):
    """x: [N, C, H, W]; VALID window semantics."""
    interp = (not _on_tpu()) if interpret is None else interpret
    xh = nchw_to_nhwc(x)
    xh, orig_c = pad_axis(xh, 3, SUBLANES)  # pad value 0 never crosses
    out = K.pool2d_nhwc(xh, kernel, stride, kind, relu,  # channel lanes
                        oh_block=oh_block, interpret=interp)
    return nhwc_to_nchw(out[..., :orig_c])
