"""Pallas pooling kernels (max/avg, oh-band tiled) — the pooling half of
the fused conv→ReLU→pool super-layers."""
