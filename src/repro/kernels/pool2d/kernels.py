"""Pallas TPU pooling kernels: max/avg over NHWC, oh-band tiled.

Same grid-over-frames structure as the conv ladder: grid cell
``(frame, oh-tile)``; each cell loads only the input-row band its output
band needs — ``(oh_block-1)*stride + KH`` rows including the halo — via a
stride-aware element-offset (``pl.Unblocked``) BlockSpec, exactly the
PR 1 conv plumbing.  This replaces the engine's bare ``reduce_window``
("accelerated on mobile CPU" in the paper) with a VMEM-resident kernel so
pooling joins the ladder and can be fused as a conv epilogue.

``pool_band`` is the shared in-VMEM pooling primitive: it reduces an
fp32 ``[H, W, C]`` band to ``[ph, pw, C]`` with unrolled window loops.
The fused conv kernels in ``repro.kernels.conv2d.kernels`` call it on
their conv accumulator so the intermediate activation never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ACC_DTYPE

from repro.kernels.conv2d.kernels import (
    VMEM_BUDGET_BYTES,
    _band_rows,
    auto_oh_block,
    band_intervals,
)


def _out_size(size, k, stride):
    return (size - k) // stride + 1


def pool_band(x, ph, pw, pkh, pkw, psy, psx, kind: str):
    """Pool an fp32 ``[H, W, C]`` band down to ``[ph, pw, C]``.

    Unrolled over the (small, static) pool window; strided
    ``jax.lax.slice`` picks each window position's contribution, so the
    reduction is pure VPU work on data already in VMEM.
    """
    c = x.shape[2]
    if kind == "max":
        acc = jnp.full((ph, pw, c), -jnp.inf, jnp.float32)
    elif kind == "avg":
        acc = jnp.zeros((ph, pw, c), jnp.float32)
    else:
        raise ValueError(kind)
    for i in range(pkh):
        for j in range(pkw):
            win = jax.lax.slice(
                x, (i, j, 0),
                (i + (ph - 1) * psy + 1, j + (pw - 1) * psx + 1, c),
                (psy, psx, 1),
            )  # [ph, pw, C]
            if kind == "max":
                acc = jnp.maximum(acc, win)
            else:
                acc = acc + win
    if kind == "avg":
        acc = acc / float(pkh * pkw)
    return acc


def auto_oh_block_pool(oh, ow, wp, c, kh, sy,
                       budget: int = VMEM_BUDGET_BYTES,
                       itemsize: int = 4) -> int:
    """Largest pooled-output row band whose working set (input band +
    output block, fp32) fits ``budget``.

    Delegates to the conv tiler's candidate walk with the weight and
    oc-block terms zeroed (``oc_block=0``) and the single ``[rows, C]``
    staging slice (``im2col=False``) standing in for the pooled output —
    one copy of the VMEM-fitting heuristic for the whole ladder.
    """
    return auto_oh_block(oh, ow, wp, c, kh, 1, sy, oc_block=0,
                         budget=budget, itemsize=itemsize, im2col=False)


def _pool2d_kernel(x_ref, o_ref, *, kh, kw, sy, sx, kind, relu):
    # x_ref: [1, BAND, WP, C] (input-row band); o_ref: [OH_BLK, OW, C]
    ohh, oww, _ = o_ref.shape
    acc = pool_band(x_ref[0].astype(ACC_DTYPE), ohh, oww, kh, kw, sy, sx,
                    kind)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def pool2d_nhwc(x_nhwc, kernel=(2, 2), stride=(2, 2), kind: str = "max",
                relu: bool = False, oh_block=None, interpret: bool = False):
    """VALID pooling over [N, H, W, C], output-row-band grid."""
    n, h, wd, c = x_nhwc.shape
    kh, kw = kernel
    sy, sx = stride
    oh, ow = _out_size(h, kh, sy), _out_size(wd, kw, sx)
    if oh < 1 or ow < 1:
        raise ValueError(f"pool window {kernel} larger than input {h}x{wd}")
    if oh_block is None:
        ohb = auto_oh_block_pool(oh, ow, wd, c, kh, sy)
    else:
        ohb = max(1, min(oh_block, oh))
    n_tiles = -(-oh // ohb)
    band = _band_rows(ohb, kh, sy)
    row_step = ohb * sy
    # pad the bottom so the last (possibly ragged) band stays in bounds;
    # the surplus pooled rows only read pad and are sliced off below
    _, in_iv = band_intervals(n_tiles, ohb, oh, row_step, band)
    hp_need = in_iv[-1][0] + band
    if hp_need > h:
        x_nhwc = jnp.pad(x_nhwc, ((0, 0), (0, hp_need - h), (0, 0), (0, 0)))
    kern = functools.partial(_pool2d_kernel, kh=kh, kw=kw, sy=sy, sx=sx,
                             kind=kind, relu=relu)
    out = pl.pallas_call(
        kern,
        grid=(n, n_tiles),
        in_specs=[
            # element-offset indexing: bands overlap by the KH-sy halo rows
            pl.BlockSpec((1, band, wd, c),
                         lambda i, t: (i, t * row_step, 0, 0),
                         indexing_mode=pl.Unblocked()),
        ],
        out_specs=pl.BlockSpec((None, ohb, ow, c),
                               lambda i, t: (i, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * ohb, ow, c),
                                       x_nhwc.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(x_nhwc)
    return out[:, :oh]
