"""jit'd wrapper for the WKV6 chunked kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, chunk: int = 32, interpret: bool = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=interp)
