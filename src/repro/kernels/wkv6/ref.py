"""Oracle for the WKV6 kernel: the per-timestep recurrence from
``repro.nn.rwkv`` (fp32)."""
from __future__ import annotations

from repro.nn.rwkv import wkv6_reference

__all__ = ["wkv6_reference"]
