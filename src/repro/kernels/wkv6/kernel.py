"""WKV6 (RWKV "Finch") chunked linear-attention Pallas TPU kernel.

The paper's §4.4 argument — load an operand tile once and reuse it across a
whole output block — applied to the *time* axis of an attention-free mixer:
each grid cell owns one (batch·head) stream; the kv-state [e, e] lives in
VMEM scratch across the sequential chunk axis, and each chunk's r/k/v/w
tiles are loaded exactly once for both the intra-chunk pairwise form and
the state update.

  grid = (batch·heads, n_chunks)   chunks sequential
  r/k/v/w blocks [L, e] VMEM;  state scratch [e, e] fp32
  intra-chunk pairwise decay tensor [L, L, e] stays in VMEM (L=32, e=64
  -> 256 KiB fp32)

Matches ``repro.nn.rwkv._wkv6_chunked`` / ``wkv6_reference`` semantics:
  S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t (S_{t-1} + u ⊙ k_t v_t^T)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ACC_DTYPE


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, L):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[...].astype(ACC_DTYPE)  # [L, e]
    k = k_ref[...].astype(ACC_DTYPE)
    v = v_ref[...].astype(ACC_DTYPE)
    w = w_ref[...].astype(ACC_DTYPE)  # log decay, < 0
    u = u_ref[...].astype(ACC_DTYPE)  # [e]
    S = state_ref[...]  # [e_k, e_v]

    cw = jnp.cumsum(w, axis=0)  # inclusive
    cw_prev = cw - w
    # intra-chunk: A[i,j] = sum_e r_i[e] k_j[e] exp(cw_prev_i - cw_j), j < i
    decay = jnp.exp(cw_prev[:, None, :] - cw[None, :, :])  # [L, L, e]
    A = jnp.einsum("ie,ije,je->ij", r, decay, k)
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(li > lj, A, 0.0)
    o = jax.lax.dot(A, v, preferred_element_type=jnp.float32)
    # diagonal bonus: (r_i ⊙ u ⊙ k_i) · v_i
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # [L]
    o = o + diag[:, None] * v
    # inter-chunk: o_i += (r_i ⊙ exp(cw_prev_i)) @ S
    o = o + jax.lax.dot(r * jnp.exp(cw_prev), S,
                        preferred_element_type=jnp.float32)
    # state update: S' = diag(exp(cw_L)) S + sum_j exp(cw_L - cw_j) k_j v_j^T
    total = cw[-1]  # [e]
    Sc = jax.lax.dot((k * jnp.exp(total[None, :] - cw)).T, v,
                     preferred_element_type=jnp.float32)
    state_ref[...] = S * jnp.exp(total)[:, None] + Sc
    o_ref[...] = o.astype(o_ref.dtype)


def wkv6_pallas(r, k, v, logw, u, *, chunk: int = 32,
                interpret: bool = False):
    """r/k/v/logw: [b, s, h, e]; u: [h, e] -> o [b, s, h, e]."""
    b, s, h, e = r.shape
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        # padded steps must not change the state: log decay 0 (=> decay 1)
        # and k = 0 give S' = S
        logw = jnp.pad(logw, z)
    sp = s + pad
    nc = sp // L

    def fold(x):  # [b, s, h, e] -> [b*h, s, e]
        return x.transpose(0, 2, 1, 3).reshape(b * h, sp, e)

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(logw)
    uf = jnp.broadcast_to(u[None], (b, h, e)).reshape(b * h, e)

    out = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((None, L, e), lambda g, c: (g, c, 0)),
            pl.BlockSpec((None, L, e), lambda g, c: (g, c, 0)),
            pl.BlockSpec((None, L, e), lambda g, c: (g, c, 0)),
            pl.BlockSpec((None, L, e), lambda g, c: (g, c, 0)),
            pl.BlockSpec((None, e), lambda g, c: (g, 0)),
        ],
        out_specs=pl.BlockSpec((None, L, e), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, e), r.dtype),
        scratch_shapes=[pltpu.VMEM((e, e), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    out = out[:, :s].reshape(b, h, s, e).transpose(0, 2, 1, 3)
    return out
