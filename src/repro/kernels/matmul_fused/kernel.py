"""Fused bias+activation matmul Pallas kernel (the paper's FC acceleration).

CNNdroid §4.4 computes several output elements per thread so each loaded
operand is reused; on the MXU the analogue is a [bm, bn] output tile per
grid cell — one loaded x-tile is reused across the whole 128-wide output
block, and the bias+activation epilogue runs while the tile is still in
VMEM (the zero-cost ReLU of Fig. 5).

Grid: (M/bm, N/bn, K/bk) with K innermost-sequential; the output BlockSpec
ignores the K index so the same VMEM tile accumulates across K steps
(canonical Pallas accumulation idiom).  fp32 accumulation regardless of
input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import ACC_DTYPE


def _act(y, act: str):
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "silu":
        return y * (1.0 / (1.0 + jnp.exp(-y)))
    if act == "gelu":
        return 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 *
                                         (y + 0.044715 * y ** 3)))
    return y


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(ACC_DTYPE), w_ref[...].astype(ACC_DTYPE),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():  # fused bias + activation — no extra HBM pass
        y = o_ref[...]
        if b_ref is not None:
            y = y + b_ref[...].astype(ACC_DTYPE)
        o_ref[...] = _act(y, act)


def matmul_fused_pallas(
    x, w, b=None, act: str = "none",
    bm: int = 128, bn: int = 128, bk: int = 512,
    interpret: bool = False,
):
    """x: [M, K]; w: [K, N]; b: [N] or None -> [M, N] fp32."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if b is not None and pn:
        b = jnp.pad(b, (0, pn))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    nk = Kp // bk

    kernel = functools.partial(_kernel, act=act, nk=nk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, k: (j,)))
        args.append(b)
    else:
        kernel = functools.partial(_kernel, act=act, nk=nk)

        def kernel2(x_ref, w_ref, o_ref, *, act=act, nk=nk):
            _kernel(x_ref, w_ref, None, o_ref, act=act, nk=nk)

        kernel = kernel2

    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)
    return out[:M, :N]
