"""jit'd public wrapper for the fused matmul kernel.

On TPU the Pallas kernel runs natively; elsewhere (this CPU container)
``interpret=True`` executes the same kernel body op-by-op, and tests assert
allclose against ``ref.matmul_fused_ref``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.matmul_fused.kernel import matmul_fused_pallas
from repro.kernels.matmul_fused.ref import matmul_fused_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("act", "interpret"))
def matmul_fused(x, w, b=None, act: str = "none", interpret: bool = None):
    """y = act(x @ w + b).  Leading dims of x are flattened to M."""
    interp = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = matmul_fused_pallas(x2, w, b, act=act, interpret=interp)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)


matmul_fused_reference = matmul_fused_ref
