"""Pure-jnp oracle for the fused bias+activation matmul."""
from __future__ import annotations

import jax.numpy as jnp

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * (1.0 / (1.0 + jnp.exp(-x))),
    "gelu": lambda x: 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 *
                                                (x + 0.044715 * x ** 3))),
    "none": lambda x: x,
}


def matmul_fused_ref(x, w, b=None, act: str = "none"):
    """y = act(x @ w + b) with fp32 accumulation.  x: [M, K]; w: [K, N]."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return _ACTS[act](y).astype(x.dtype)
