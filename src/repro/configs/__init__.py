"""Assigned-architecture configs (one module per ``--arch`` id) plus the
paper's own CNN benchmark networks.  Importing this package registers all
architectures with ``repro.core.config``."""
from repro.configs import (  # noqa: F401
    llama_3_2_vision_11b,
    seamless_m4t_large_v2,
    grok_1_314b,
    gemma2_2b,
    rwkv6_1_6b,
    starcoder2_15b,
    internlm2_20b,
    qwen1_5_32b,
    zamba2_1_2b,
    qwen3_moe_30b_a3b,
)
