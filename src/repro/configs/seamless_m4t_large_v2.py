"""seamless-m4t-large-v2 [arXiv:2308.11596].

Encoder-decoder multimodal backbone: 24 encoder + 24 decoder layers (model
card reading of "24L"), d_model=1024, 16 heads, d_ff=8192, vocab=256206
(padded to 256256 for the 16-way model axis).  The speech frontend
(mel + conv) is stubbed: ``input_specs`` provides 1024-dim frame embeddings
(4096 frames ~ 82s of 20ms-stride speech).
"""
from repro.core.config import ModelConfig, CrossAttnConfig, register_arch


@register_arch("seamless-m4t-large-v2")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        norm_kind="layernorm",
        act="gelu",
        mlp_gated=False,
        cross_attn=CrossAttnConfig(interval=0, num_media_tokens=4096,
                                   media_dim=1024),
        source="arXiv:2308.11596",
    )
