"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision].

40-layer language decoder, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=128256; gated cross-attention image layers every 5th layer.  The ViT
vision tower is stubbed per the assignment carve-out: ``input_specs``
provides 4096-dim patch embeddings (1601 patches x up to 4 tiles ~ 6404,
rounded to 6400).
"""
from repro.core.config import ModelConfig, CrossAttnConfig, register_arch


@register_arch("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500000.0,
        act="silu",
        cross_attn=CrossAttnConfig(interval=5, num_media_tokens=6400,
                                   media_dim=4096),
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
