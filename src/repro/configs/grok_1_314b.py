"""grok-1-314b [hf:xai-org/grok-1].

64 layers, d_model=6144, 48 heads (GQA kv=8), MoE with 8 experts / top-2,
expert d_ff=32768, vocab=131072.  Attention and final logits use tanh
softcaps (30.0) per the released implementation.  Experts are sharded in
"tensor" mode (ff dim over the model axis) since 8 experts < 16-way axis.
"""
from repro.core.config import ModelConfig, MoEConfig, register_arch


@register_arch("grok-1-314b")
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        attn_softcap=30.0,
        logit_softcap=30.0,
        act="gelu",
        moe=MoEConfig(num_experts=8, num_experts_per_token=2,
                      d_ff_expert=32768, shard_mode="tensor"),
        source="hf:xai-org/grok-1",
    )
