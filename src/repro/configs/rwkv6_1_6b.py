"""rwkv6-1.6b (Finch) [arXiv:2404.05892].

24 layers, d_model=2048, attention-free (WKV6 data-dependent decay,
64-wide heads), channel-mix d_ff=7168, vocab=65536.  O(1)-state decode;
long_500k runs natively (DESIGN.md §Arch-applicability).
"""
from repro.core.config import ModelConfig, RWKVConfig, register_arch


@register_arch("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,          # 2048 / 64-wide WKV heads
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, tokenshift_lora=32),
        source="arXiv:2404.05892",
    )
