"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B].

48 layers, d_model=2048, 32 heads (GQA kv=4, head_dim=128), MoE with 128
experts / top-8, expert d_ff=768, vocab=151936.  QK-norm per qwen3.
Experts shard in "expert" mode (128 experts over the 16-way model axis).
"""
from repro.core.config import ModelConfig, MoEConfig, register_arch


@register_arch("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        moe=MoEConfig(num_experts=128, num_experts_per_token=8,
                      d_ff_expert=768, shard_mode="expert"),
        source="hf:Qwen/Qwen3-30B-A3B",
    )
