"""starcoder2-15b [arXiv:2402.19173].

40 layers, d_model=6144, 48 heads (GQA kv=4), d_ff=24576, vocab=49152.
RoPE theta 1e5, QKV bias, plain (non-gated) gelu MLP, native sliding
window 4096 -- long_500k runs with the native window.
"""
from repro.core.config import ModelConfig, register_arch


@register_arch("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        rope_theta=100000.0,
        use_qkv_bias=True,
        mlp_gated=False,
        act="gelu",
        sliding_window=4096,
        norm_kind="layernorm",
        source="arXiv:2402.19173",
    )
