"""zamba2-1.2b [arXiv:2411.15242].

38 Mamba2 blocks (d_model=2048, ssm_state=64) + one shared attention block
(32 heads, kv=32, head_dim=128 at concat width 4096, d_ff=8192) applied
every 6 blocks, vocab=32000.  Hybrid: long_500k runs natively with the
shared attention using a 4096 sliding window in long-context mode.
"""
from repro.core.config import ModelConfig, SSMConfig, register_arch


@register_arch("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=8192,
        vocab_size=32000,
        shared_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
        long_context_window=4096,
        source="arXiv:2411.15242",
    )
