"""gemma2-2b [arXiv:2408.00118].

26 layers alternating local (sliding-window 4096) / global attention,
d_model=2304, 8 heads (GQA kv=4, head_dim=256), d_ff=9216, vocab=256000.
Logit softcap 30, attention softcap 50, (1+w) RMSNorm, post-block norms,
tied embeddings scaled by sqrt(d).  long_500k runs with global layers
falling back to an 8192 window (DESIGN.md §Arch-applicability).
"""
from repro.core.config import ModelConfig, register_arch


@register_arch("gemma2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        act="gelu",
        attn_softcap=50.0,
        logit_softcap=30.0,
        sliding_window=4096,
        local_global_interval=2,
        post_block_norms=True,
        rms_plus_one=True,
        tie_embeddings=True,
        long_context_window=8192,
        source="arXiv:2408.00118",
    )
