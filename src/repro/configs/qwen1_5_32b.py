"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B family].

64 layers, d_model=5120, 40 heads (kv=40, MHA), d_ff=27392, vocab=152064.
QKV bias (the fused bias+act epilogue is exactly the paper's FC technique).
40 heads are not divisible by the 16-way model axis; the auto sharding
rules replicate attention heads and shard only the MLP (a head-padding
variant is evaluated in EXPERIMENTS.md SPerf).
"""
from repro.core.config import ModelConfig, register_arch


@register_arch("qwen1.5-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        use_qkv_bias=True,
        rope_theta=1000000.0,
        source="hf:Qwen/Qwen1.5-0.5B (scaled per 32B card)",
    )
