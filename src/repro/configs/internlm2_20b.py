"""internlm2-20b [arXiv:2403.17297].

48 layers, d_model=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92544.
Llama-like: RMSNorm, RoPE (theta 1e6), gated silu MLP.
"""
from repro.core.config import ModelConfig, register_arch


@register_arch("internlm2-20b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        rope_theta=1000000.0,
        source="arXiv:2403.17297",
    )
