"""Paper Table 4 analogue: heaviest conv layer × execution-method ladder.

For each benchmark CNN, times the heaviest convolution layer under every
ladder method on this host (XLA:CPU wall time — the *relative* ladder
ordering is the reproduction target; absolute mobile-GPU numbers are not
reproducible off-device) and derives per-method HLO bytes/FLOPs to model
the TPU roofline effect of each layout/blocking choice.

``run_tile_sweep`` additionally sweeps the spatial ``oh_block`` tile of the
Pallas advanced-SIMD kernel over large-frame shapes (512×512 inputs the
untiled seed kernel could not stage in VMEM), reporting one row per
(shape, oh_block) with the resolved band geometry.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import CNNEngine
from repro.core.methods import Method, LADDER
from repro.core.netdefs import NETWORKS
from repro.kernels.conv2d.kernels import (
    _band_rows,
    _out_size,
    resolve_oh_block,
)
from repro.kernels.conv2d.ops import SUBLANES, conv2d as conv2d_pallas
from repro.launch.hlo_analysis import analyze_hlo_text

BATCH = 16  # the paper's batch of 16 frames (§6.2)

# (name, x-shape NCHW, oc, k, stride, pad) — large_512 is the frame class
# whose padded activations (~34–67 MB) exceed the per-cell VMEM budget
TILE_SWEEP_SHAPES = (
    ("large_512", (1, 32, 512, 512), 16, 3, (1, 1), (1, 1)),
    ("alexnet_conv2", (2, 96, 27, 27), 128, 5, (1, 1), (2, 2)),
)
OH_BLOCKS = (8, 32, None)  # None = auto heuristic from the VMEM budget


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run_tile_sweep(shapes=TILE_SWEEP_SHAPES, oh_blocks=OH_BLOCKS):
    """One row per (shape, oh_block): the spatially-tiled advanced-SIMD
    kernel in interpret mode, with the resolved band geometry derived."""
    rows = []
    for name, xshape, oc, k, stride, pad in shapes:
        n, c, h, wd = xshape
        x = jax.random.normal(jax.random.PRNGKey(0), xshape, jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (oc, c, k, k)) * 0.1
        b = jnp.zeros((oc,), jnp.float32)
        oh = _out_size(h, k, stride[0], pad[0])
        ow = _out_size(wd, k, stride[1], pad[1])
        # the geometry the kernel itself resolves: padded channels, and
        # an oc block clamped to the actual output-channel count
        cp = c + (-c) % SUBLANES
        ocb = min(128, oc)
        for ohb in oh_blocks:
            fn = partial(conv2d_pallas, stride=stride, padding=pad,
                         relu=True, method="advanced_simd_128", oh_block=ohb,
                         interpret=True)
            us = _time(fn, x, w, b, iters=2)
            resolved = resolve_oh_block(oh, ow, wd + 2 * pad[1], cp, k, k,
                                        stride[0], ocb, ohb)
            n_tiles = -(-oh // resolved)
            band = _band_rows(resolved, k, stride[0])
            label = "auto" if ohb is None else str(ohb)
            rows.append({
                "bench": f"conv_tile_sweep/{name}/oh_block_{label}",
                "us_per_call": us,
                "derived": (f"oh_block={resolved} n_tiles={n_tiles} "
                            f"band_rows={band} oh={oh} ow={ow}"),
            })
    return rows


def run(nets=("lenet5", "cifar10", "alexnet"), batch=BATCH):
    rows = []
    for name in nets:
        net = NETWORKS[name]()
        b = batch if name != "alexnet" else 4  # CPU-budget batch for alexnet
        eng = CNNEngine(net, method=Method.SEQ_REF)
        params = eng.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, *net.input_shape),
                              jnp.float32)
        layer, layer_in = eng.heaviest_conv(params, x)
        base_us = None
        for method in LADDER:
            fn = jax.jit(eng.conv_layer_fn(layer, method))
            us = _time(fn, params, layer_in)
            compiled = fn.lower(params, layer_in).compile()
            costs = analyze_hlo_text(compiled.as_text())
            if method == Method.SEQ_REF:
                base_us = us
            rows.append({
                "bench": f"conv_ladder/{name}/{layer}/{method.value}",
                "us_per_call": us,
                "derived": (f"speedup={base_us/us:.2f}x "
                            f"flops={costs.flops:.3e} bytes={costs.bytes:.3e} "
                            f"ai={costs.flops/max(costs.bytes,1):.2f}"),
            })
    rows.extend(run_tile_sweep())
    return rows
