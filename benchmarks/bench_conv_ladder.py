"""Paper Table 4 analogue: heaviest conv layer × execution-method ladder.

For each benchmark CNN, times the heaviest convolution layer under every
ladder method on this host (XLA:CPU wall time — the *relative* ladder
ordering is the reproduction target; absolute mobile-GPU numbers are not
reproducible off-device) and derives per-method HLO bytes/FLOPs to model
the TPU roofline effect of each layout/blocking choice.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.engine import CNNEngine
from repro.core.methods import Method, LADDER
from repro.core.netdefs import NETWORKS
from repro.launch.hlo_analysis import analyze_hlo_text

BATCH = 16  # the paper's batch of 16 frames (§6.2)


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(nets=("lenet5", "cifar10", "alexnet"), batch=BATCH):
    rows = []
    for name in nets:
        net = NETWORKS[name]()
        b = batch if name != "alexnet" else 4  # CPU-budget batch for alexnet
        eng = CNNEngine(net, method=Method.SEQ_REF)
        params = eng.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, *net.input_shape),
                              jnp.float32)
        layer, layer_in = eng.heaviest_conv(params, x)
        base_us = None
        for method in LADDER:
            fn = jax.jit(eng.conv_layer_fn(layer, method))
            us = _time(fn, params, layer_in)
            compiled = fn.lower(params, layer_in).compile()
            costs = analyze_hlo_text(compiled.as_text())
            if method == Method.SEQ_REF:
                base_us = us
            rows.append({
                "bench": f"conv_ladder/{name}/{layer}/{method.value}",
                "us_per_call": us,
                "derived": (f"speedup={base_us/us:.2f}x "
                            f"flops={costs.flops:.3e} bytes={costs.bytes:.3e} "
                            f"ai={costs.flops/max(costs.bytes,1):.2f}"),
            })
    return rows
