"""Serving throughput (the paper's end-to-end deployment scenario, scaled
to the assigned architectures): tokens/s of the batched engine on reduced
configs, plus decode-step wall time."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.config import get_arch
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine

ARCHS = ("gemma2-2b", "internlm2-20b", "rwkv6-1.6b")


def run(archs=ARCHS):
    rows = []
    for arch in archs:
        cfg = get_arch(arch).reduced()
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_batch=4, max_len=96)
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for rid in range(6):
            eng.submit(Request(rid, rng.integers(
                0, cfg.vocab_size, size=8).tolist(), max_new_tokens=12))
        done = eng.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in done.values())
        rows.append({
            "bench": f"serving/{arch}(reduced)",
            "us_per_call": dt / max(toks, 1) * 1e6,
            "derived": f"tok_s={toks/dt:.1f} requests={len(done)}",
        })
    return rows
