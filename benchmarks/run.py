"""Benchmark harness — one module per paper table / deliverable figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract):
  * bench_conv_ladder    — paper Table 4 (heaviest conv layer × method)
  * bench_network_ladder — paper Table 3 (whole network × method, + FPS)
  * bench_fc_fused       — paper §4 FC fusion (bias+act epilogue)
  * bench_serving        — deployment scenario throughput
  * roofline             — §Roofline terms from the dry-run artifacts
                           (rows appear when results/dryrun/ is populated)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    suites = []
    from benchmarks import (  # noqa: E402
        bench_conv_ladder,
        bench_network_ladder,
        bench_fc_fused,
        bench_serving,
    )

    suites = [
        ("conv_ladder", bench_conv_ladder.run),
        ("network_ladder", bench_network_ladder.run),
        ("fc_fused", bench_fc_fused.run),
        ("serving", bench_serving.run),
    ]
    for name, fn in suites:
        try:
            for row in fn():
                print(f"{row['bench']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name},ERROR,\"{type(e).__name__}: {e}\"", flush=True)
            traceback.print_exc(file=sys.stderr)

    # roofline rows (dry-run artifacts; baseline table lives in EXPERIMENTS.md)
    try:
        from pathlib import Path

        from benchmarks.roofline import load_all

        rows = load_all(Path("results/dryrun"), mesh="16x16")
        for r in rows:
            if "error" in r:
                continue
            print(f"roofline/{r['arch']}/{r['shape']},"
                  f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
                  f"\"dominant={r['dominant']} useful={r['useful_ratio']:.2f}"
                  f" fits={r['fits_16gb']}\"", flush=True)
    except Exception as e:  # noqa: BLE001
        print(f"roofline,SKIPPED,\"{e}\"", flush=True)


if __name__ == "__main__":
    main()
