"""Benchmark harness — one module per paper table / deliverable figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract):
  * bench_conv_ladder    — paper Table 4 (heaviest conv layer × method)
  * bench_network_ladder — paper Table 3 (whole network × method, + FPS,
                           + fused super-layer vs unfused ladder rows)
  * bench_fc_fused       — paper §4 FC fusion (bias+act epilogue)
  * bench_serving        — deployment scenario throughput
  * roofline             — §Roofline terms from the dry-run artifacts
                           (rows appear when results/dryrun/ is populated)

``--json`` switches to the machine-readable path: the network ladder
runs, its per-network, per-method fused-vs-unfused numbers (us_per_call,
FPS, fused_speedup) are written to ``BENCH_network.json``, and batched
CNN-serving rows (``CNNServer`` throughput + p50/p95 latency at request
batches 1/8/16, ``--serving-batches``/``--serving-requests``;
``--no-serving`` skips) ride along under each network's ``serving`` key
so the perf trajectory records serving-scale numbers across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def _run_csv() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (  # noqa: E402
        bench_cnn_serving,
        bench_conv_ladder,
        bench_network_ladder,
        bench_fc_fused,
        bench_serving,
    )

    suites = [
        ("conv_ladder", bench_conv_ladder.run),
        ("network_ladder", bench_network_ladder.run),
        ("fc_fused", bench_fc_fused.run),
        ("serving", bench_serving.run),
        ("cnn_serving", bench_cnn_serving.run),
    ]
    for name, fn in suites:
        try:
            for row in fn():
                print(f"{row['bench']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            print(f"{name},ERROR,\"{type(e).__name__}: {e}\"", flush=True)
            traceback.print_exc(file=sys.stderr)

    # roofline rows (dry-run artifacts; baseline table lives in EXPERIMENTS.md)
    try:
        from pathlib import Path

        from benchmarks.roofline import load_all

        rows = load_all(Path("results/dryrun"), mesh="16x16")
        for r in rows:
            if "error" in r:
                continue
            print(f"roofline/{r['arch']}/{r['shape']},"
                  f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
                  f"\"dominant={r['dominant']} useful={r['useful_ratio']:.2f}"
                  f" fits={r['fits_16gb']}\"", flush=True)
    except Exception as e:  # noqa: BLE001
        print(f"roofline,SKIPPED,\"{e}\"", flush=True)


def _run_json(nets, out_path: str, batch: int, iters: int,
              serving_batches, serving_requests: int) -> None:
    from benchmarks import bench_cnn_serving, bench_network_ladder

    data = bench_network_ladder.run_json(nets=nets, batch=batch, iters=iters)
    if serving_batches:
        bench_cnn_serving.add_serving_rows(
            data, nets, batches=serving_batches, requests=serving_requests)
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"wrote {out_path}", flush=True)
    for name, nd in data["networks"].items():
        for row in nd["rows"]:
            ratio = row.get("fused_speedup")
            print(f"  {name}/{row['method']}: "
                  f"unfused={row['unfused']['us_per_call']:.0f}us"
                  + (f" fused={row['fused']['us_per_call']:.0f}us"
                     f" fused_vs_unfused={ratio:.2f}x" if ratio else ""),
                  flush=True)
        for srow in nd.get("serving", []):
            mode = srow.get("mode", "normal")
            tag = f"batch{srow['batch']}" + (
                "" if mode == "normal" else f"-{mode}")
            line = (f"  {name}/cnn_server/{tag}: "
                    f"rps={srow['throughput_rps']:.1f} "
                    f"p50={srow['p50_us']:.0f}us p95={srow['p95_us']:.0f}us")
            if mode != "normal":
                line += (f" shed={srow['shed']} degraded={srow['degraded']}"
                         f" final={srow['final_method']}")
            print(line, flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_network.json instead of the CSV sweep")
    ap.add_argument("--nets", default="lenet5,cifar10",
                    help="comma-separated network names (json path)")
    ap.add_argument("--out", default="BENCH_network.json",
                    help="output path for --json")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--serving-batches", default="1,8,16",
                    help="comma-separated CNNServer max_batch sweep for the "
                         "json path (batched-serving rows)")
    ap.add_argument("--serving-requests", type=int, default=16,
                    help="requests per serving row (after bucket warm-up)")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the batched-serving rows on the json path")
    args = ap.parse_args(argv)
    if args.json:
        serving_batches = () if args.no_serving else tuple(
            int(b) for b in args.serving_batches.split(",") if b.strip())
        _run_json(tuple(n.strip() for n in args.nets.split(",") if n.strip()),
                  args.out, args.batch, args.iters,
                  serving_batches, args.serving_requests)
    else:
        _run_csv()


if __name__ == "__main__":
    main()
